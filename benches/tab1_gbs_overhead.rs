//! Bench: regenerate Table 1 (compute vs schedule vs solver time over
//! GBS) and micro-time the solver at each GBS.

use dhp::experiments::overhead;
use dhp::util::bench::BenchReport;
use dhp::util::cli::Args;

fn main() {
    let mut args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))
        .expect("args");
    args.options.entry("warmup".into()).or_insert("1".into());
    args.options.entry("measure".into()).or_insert("3".into());
    println!("=== tab1: overhead vs GBS ===");
    overhead::run_gbs(&args).expect("tab1");

    let mut report = BenchReport::new("tab1");
    for gbs in [128usize, 256, 512] {
        report.bench(&format!("protocol_gbs{gbs}_npus64"), 0, 3, || {
            std::hint::black_box(overhead::compute_row(gbs, 64, 0, 2, 11));
        });
    }
    report.finish();
}
