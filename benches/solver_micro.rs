//! Micro-benchmarks of the scheduler hot path (the §Perf targets): BFD
//! packing, 2D-DP allocation, and the full schedule() pipeline at the
//! paper's scales — with before/after pairs so one run measures the
//! ISSUE-1 overhaul against the retained pre-overhaul reference path
//! (`Scheduler::schedule_reference`, `dp::allocate_degrees_reference`).
//!
//! Usage:
//!   cargo bench --bench solver_micro              # full repetitions
//!   cargo bench --bench solver_micro -- --quick   # CI smoke (fewer reps)
//!
//! Both modes persist machine-readable per-case mean/p50/p90 latencies to
//! `BENCH_solver_micro.json` at the repo root (see scripts/bench_smoke.sh)
//! so future PRs can track the solver-latency trajectory. The ISSUE-7
//! scale tier (npus=1024 and npus=4096) benches `schedule()` alone — the
//! reference path is quadratic in N and would run for minutes there.

use std::path::Path;

use dhp::config::presets::by_name;
use dhp::config::TrainStage;
use dhp::data::datasets::DatasetKind;
use dhp::experiments::harness::ExpContext;
use dhp::scheduler::{packing, solver_threads, SolverScratch};
use dhp::util::bench::BenchReport;
use dhp::util::json::{self, Json};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    // (warmup, reps) per tier: full mode mirrors the seed counts.
    let (pack_w, pack_r) = if quick { (1, 5) } else { (2, 20) };
    let (sch_w, sch_r) = if quick { (1, 3) } else { (2, 10) };
    let (dp_w, dp_r) = if quick { (1, 10) } else { (2, 50) };

    let mut report = BenchReport::new("solver_micro");
    for (npus, gbs) in [(16usize, 512usize), (32, 512), (64, 512), (64, 128)] {
        let ctx = ExpContext::new(
            by_name("InternVL3-8B").unwrap(),
            DatasetKind::OpenVid,
            npus,
            TrainStage::Full,
        );
        let mut sampler = ctx.sampler();
        let seqs = sampler.sample_batch(gbs);
        // Reuse OFF: these cases re-solve one fixed batch, which the
        // ISSUE-9 schedule cache would short-circuit after the first
        // rep — the search, not the cache probe, is what they measure.
        let sch = ctx.dhp().with_solver_reuse(false);
        let memory = ctx.memory();
        let n = ctx.replicas();

        report.bench(&format!("pack_gbs{gbs}_n{n}"), pack_w, pack_r, || {
            std::hint::black_box(packing::pack(&seqs, &memory, n));
        });
        // Single-target pass through the scratch arena (pack + waves +
        // DP with reused buffers and memoized costs).
        {
            let mut scratch = SolverScratch::acquire();
            report.bench(
                &format!("target_pass_scratch_gbs{gbs}_n{n}"),
                pack_w,
                pack_r,
                || {
                    std::hint::black_box(
                        sch.schedule_with_target_in(&seqs, n, &mut scratch),
                    );
                },
            );
            scratch.release();
        }
        // AFTER: the overhauled solver (parallel pruned search, at-most-j
        // DP, scratch arena, memoized costs).
        report.bench(&format!("schedule_gbs{gbs}_npus{npus}"), sch_w, sch_r, || {
            std::hint::black_box(sch.schedule(&seqs));
        });
        // BEFORE: the seed's sequential exact-j path, retained verbatim.
        report.bench(
            &format!("schedule_reference_gbs{gbs}_npus{npus}"),
            sch_w,
            sch_r,
            || {
                std::hint::black_box(sch.schedule_reference(&seqs));
            },
        );
    }

    // ISSUE-7 scale tier: the paper's large-cluster regimes. No
    // `schedule_reference` pair here — the seed's O(K'·N²) exact-j DP
    // takes minutes at N=4096, while the monotone-sweep solver on the
    // persistent pool is the sub-millisecond claim under test
    // (scripts/bench_smoke.sh gates the npus=1024 case on a 1 ms p90
    // budget).
    for (npus, gbs) in [(1024usize, 2048usize), (4096, 8192)] {
        let ctx = ExpContext::new(
            by_name("InternVL3-8B").unwrap(),
            DatasetKind::OpenVid,
            npus,
            TrainStage::Full,
        );
        let mut sampler = ctx.sampler();
        let seqs = sampler.sample_batch(gbs);
        // Reuse OFF here too: repeated reps of one batch must keep
        // measuring the cold search (the 1 ms p90 budget's subject).
        let sch = ctx.dhp().with_solver_reuse(false);
        report.bench(&format!("schedule_gbs{gbs}_npus{npus}"), sch_w, sch_r, || {
            std::hint::black_box(sch.schedule(&seqs));
        });
    }

    // ISSUE-9 steady-state tier: a correlated 32-batch stream through ONE
    // reuse-enabled scheduler — the number the cross-step reuse layers
    // exist to move. Three of every four steps replay the base batch
    // (exact-hit cache territory); every fourth draws a fresh same-size
    // batch from the same distribution (cache miss, warm-start-seeded
    // search). Per-step wall times are partitioned by reuse provenance
    // and reported alongside a reuse-disabled twin replaying the
    // identical stream (the cold baseline the ≥5× exact-hit acceptance
    // criterion compares against).
    {
        let npus = 1024usize;
        let gbs = 2048usize;
        let steps = if quick { 12 } else { 32 };
        let ctx = ExpContext::new(
            by_name("InternVL3-8B").unwrap(),
            DatasetKind::OpenVid,
            npus,
            TrainStage::Full,
        );
        let mut sampler = ctx.sampler();
        let base = sampler.sample_batch(gbs);
        let stream: Vec<_> = (0..steps)
            .map(|step| {
                if step > 0 && step % 4 == 0 {
                    sampler.sample_batch(gbs)
                } else {
                    base.clone()
                }
            })
            .collect();
        let sch = ctx.dhp();
        let cold_twin = ctx.dhp().with_solver_reuse(false);
        let mut all = Vec::with_capacity(steps);
        let mut hit = Vec::new();
        let mut warm = Vec::new();
        let mut cold = Vec::new();
        let mut twin = Vec::with_capacity(steps);
        let (mut warm_pruned, mut cold_pruned) = (Vec::new(), Vec::new());
        for batch in &stream {
            let out = std::hint::black_box(sch.schedule(batch));
            all.push(out.solve_time_s);
            match out.stats.label() {
                "hit" => hit.push(out.solve_time_s),
                "warm" => {
                    warm.push(out.solve_time_s);
                    warm_pruned.push(out.stats.pruned_frac());
                }
                _ => {
                    cold.push(out.solve_time_s);
                    cold_pruned.push(out.stats.pruned_frac());
                }
            }
            let ref_out = std::hint::black_box(cold_twin.schedule(batch));
            twin.push(ref_out.solve_time_s);
        }
        report.record_samples(&format!("schedule_steady_stream_npus{npus}"), &all);
        report.record_samples(
            &format!("schedule_steady_stream_npus{npus}_hit"),
            &hit,
        );
        report.record_samples(
            &format!("schedule_steady_stream_npus{npus}_warm"),
            &warm,
        );
        report.record_samples(
            &format!("schedule_steady_stream_npus{npus}_coldref"),
            &twin,
        );
        let mean = |v: &[f64]| {
            if v.is_empty() {
                0.0
            } else {
                v.iter().sum::<f64>() / v.len() as f64
            }
        };
        println!(
            "  steady-stream provenance: {} hit / {} warm / {} cold; \
             mean pruned frac warm {:.3} vs cold {:.3}",
            hit.len(),
            warm.len(),
            cold.len(),
            mean(&warm_pruned),
            mean(&cold_pruned),
        );
    }

    // Pure DP at K'=64 groups / N=16 ranks (the O(K'N²) → O(K'N log N)
    // core), optimized vs reference over identical inputs.
    let ctx = ExpContext::new(
        by_name("InternVL3-8B").unwrap(),
        DatasetKind::OpenVid,
        64,
        TrainStage::Full,
    );
    let mut sampler = ctx.sampler();
    let seqs = sampler.sample_batch(512);
    let groups = packing::pack_with_target(&seqs, &ctx.memory(), 16, 64);
    let wave = packing::waves(groups, 16).into_iter().next().unwrap();
    let cost = ctx.cost_model();
    report.bench(&format!("dp_allocate_k{}_n16", wave.len()), dp_w, dp_r, || {
        std::hint::black_box(dhp::scheduler::dp::allocate_degrees(
            &wave,
            16,
            |i, d| cost.t_total(&wave[i].agg, d, 12.5e9),
            dhp::scheduler::any_degree,
        ));
    });
    report.bench(
        &format!("dp_allocate_reference_k{}_n16", wave.len()),
        dp_w,
        dp_r,
        || {
            std::hint::black_box(dhp::scheduler::dp::allocate_degrees_reference(
                &wave,
                16,
                |i, d| cost.t_total(&wave[i].agg, d, 12.5e9),
                dhp::scheduler::any_degree,
            ));
        },
    );

    // Persist the trajectory record at the repo root (the package lives
    // in rust/, so the root is one level up from the manifest).
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap_or_else(|| Path::new("."))
        .to_path_buf();
    let out = root.join("BENCH_solver_micro.json");
    let meta = vec![
        ("quick", Json::Bool(quick)),
        ("solver_threads", json::num(solver_threads() as f64)),
    ];
    match report.write_json(&out, meta) {
        Ok(()) => println!("[bench] wrote {}", out.display()),
        Err(e) => eprintln!("[bench] failed to write {}: {e}", out.display()),
    }
    report.finish();
}
