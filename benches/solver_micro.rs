//! Micro-benchmarks of the scheduler hot path (the §Perf targets): BFD
//! packing, 2D-DP allocation, and the full schedule() pipeline at the
//! paper's scales — with before/after pairs so one run measures the
//! ISSUE-1 overhaul against the retained pre-overhaul reference path
//! (`Scheduler::schedule_reference`, `dp::allocate_degrees_reference`).
//!
//! Usage:
//!   cargo bench --bench solver_micro              # full repetitions
//!   cargo bench --bench solver_micro -- --quick   # CI smoke (fewer reps)
//!
//! Both modes persist machine-readable per-case mean/p50/p90 latencies to
//! `BENCH_solver_micro.json` at the repo root (see scripts/bench_smoke.sh)
//! so future PRs can track the solver-latency trajectory. The ISSUE-7
//! scale tier (npus=1024 and npus=4096) benches `schedule()` alone — the
//! reference path is quadratic in N and would run for minutes there.

use std::path::Path;

use dhp::config::presets::by_name;
use dhp::config::TrainStage;
use dhp::data::datasets::DatasetKind;
use dhp::experiments::harness::ExpContext;
use dhp::scheduler::{packing, solver_threads, SolverScratch};
use dhp::util::bench::BenchReport;
use dhp::util::json::{self, Json};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    // (warmup, reps) per tier: full mode mirrors the seed counts.
    let (pack_w, pack_r) = if quick { (1, 5) } else { (2, 20) };
    let (sch_w, sch_r) = if quick { (1, 3) } else { (2, 10) };
    let (dp_w, dp_r) = if quick { (1, 10) } else { (2, 50) };

    let mut report = BenchReport::new("solver_micro");
    for (npus, gbs) in [(16usize, 512usize), (32, 512), (64, 512), (64, 128)] {
        let ctx = ExpContext::new(
            by_name("InternVL3-8B").unwrap(),
            DatasetKind::OpenVid,
            npus,
            TrainStage::Full,
        );
        let mut sampler = ctx.sampler();
        let seqs = sampler.sample_batch(gbs);
        let sch = ctx.dhp();
        let memory = ctx.memory();
        let n = ctx.replicas();

        report.bench(&format!("pack_gbs{gbs}_n{n}"), pack_w, pack_r, || {
            std::hint::black_box(packing::pack(&seqs, &memory, n));
        });
        // Single-target pass through the scratch arena (pack + waves +
        // DP with reused buffers and memoized costs).
        {
            let mut scratch = SolverScratch::acquire();
            report.bench(
                &format!("target_pass_scratch_gbs{gbs}_n{n}"),
                pack_w,
                pack_r,
                || {
                    std::hint::black_box(
                        sch.schedule_with_target_in(&seqs, n, &mut scratch),
                    );
                },
            );
            scratch.release();
        }
        // AFTER: the overhauled solver (parallel pruned search, at-most-j
        // DP, scratch arena, memoized costs).
        report.bench(&format!("schedule_gbs{gbs}_npus{npus}"), sch_w, sch_r, || {
            std::hint::black_box(sch.schedule(&seqs));
        });
        // BEFORE: the seed's sequential exact-j path, retained verbatim.
        report.bench(
            &format!("schedule_reference_gbs{gbs}_npus{npus}"),
            sch_w,
            sch_r,
            || {
                std::hint::black_box(sch.schedule_reference(&seqs));
            },
        );
    }

    // ISSUE-7 scale tier: the paper's large-cluster regimes. No
    // `schedule_reference` pair here — the seed's O(K'·N²) exact-j DP
    // takes minutes at N=4096, while the monotone-sweep solver on the
    // persistent pool is the sub-millisecond claim under test
    // (scripts/bench_smoke.sh gates the npus=1024 case on a 1 ms p90
    // budget).
    for (npus, gbs) in [(1024usize, 2048usize), (4096, 8192)] {
        let ctx = ExpContext::new(
            by_name("InternVL3-8B").unwrap(),
            DatasetKind::OpenVid,
            npus,
            TrainStage::Full,
        );
        let mut sampler = ctx.sampler();
        let seqs = sampler.sample_batch(gbs);
        let sch = ctx.dhp();
        report.bench(&format!("schedule_gbs{gbs}_npus{npus}"), sch_w, sch_r, || {
            std::hint::black_box(sch.schedule(&seqs));
        });
    }

    // Pure DP at K'=64 groups / N=16 ranks (the O(K'N²) → O(K'N log N)
    // core), optimized vs reference over identical inputs.
    let ctx = ExpContext::new(
        by_name("InternVL3-8B").unwrap(),
        DatasetKind::OpenVid,
        64,
        TrainStage::Full,
    );
    let mut sampler = ctx.sampler();
    let seqs = sampler.sample_batch(512);
    let groups = packing::pack_with_target(&seqs, &ctx.memory(), 16, 64);
    let wave = packing::waves(groups, 16).into_iter().next().unwrap();
    let cost = ctx.cost_model();
    report.bench(&format!("dp_allocate_k{}_n16", wave.len()), dp_w, dp_r, || {
        std::hint::black_box(dhp::scheduler::dp::allocate_degrees(
            &wave,
            16,
            |i, d| cost.t_total(&wave[i].agg, d, 12.5e9),
            dhp::scheduler::any_degree,
        ));
    });
    report.bench(
        &format!("dp_allocate_reference_k{}_n16", wave.len()),
        dp_w,
        dp_r,
        || {
            std::hint::black_box(dhp::scheduler::dp::allocate_degrees_reference(
                &wave,
                16,
                |i, d| cost.t_total(&wave[i].agg, d, 12.5e9),
                dhp::scheduler::any_degree,
            ));
        },
    );

    // Persist the trajectory record at the repo root (the package lives
    // in rust/, so the root is one level up from the manifest).
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap_or_else(|| Path::new("."))
        .to_path_buf();
    let out = root.join("BENCH_solver_micro.json");
    let meta = vec![
        ("quick", Json::Bool(quick)),
        ("solver_threads", json::num(solver_threads() as f64)),
    ];
    match report.write_json(&out, meta) {
        Ok(()) => println!("[bench] wrote {}", out.display()),
        Err(e) => eprintln!("[bench] failed to write {}: {e}", out.display()),
    }
    report.finish();
}
