//! Micro-benchmarks of the scheduler hot path (the §Perf targets): BFD
//! packing, 2D-DP allocation, and the full schedule() pipeline at the
//! paper's scales.

use dhp::config::presets::by_name;
use dhp::config::TrainStage;
use dhp::data::datasets::DatasetKind;
use dhp::experiments::harness::ExpContext;
use dhp::scheduler::packing;
use dhp::util::bench::BenchReport;

fn main() {
    let mut report = BenchReport::new("solver_micro");
    for (npus, gbs) in [(16usize, 512usize), (32, 512), (64, 512), (64, 128)] {
        let ctx = ExpContext::new(
            by_name("InternVL3-8B").unwrap(),
            DatasetKind::OpenVid,
            npus,
            TrainStage::Full,
        );
        let mut sampler = ctx.sampler();
        let seqs = sampler.sample_batch(gbs);
        let sch = ctx.dhp();
        let memory = ctx.memory();
        let n = ctx.replicas();

        report.bench(&format!("pack_gbs{gbs}_n{n}"), 2, 20, || {
            std::hint::black_box(packing::pack(&seqs, &memory, n));
        });
        report.bench(&format!("schedule_gbs{gbs}_npus{npus}"), 2, 10, || {
            std::hint::black_box(sch.schedule(&seqs));
        });
    }

    // Pure DP at K'=64 groups / N=64 ranks (the O(K'N²) core).
    let ctx = ExpContext::new(
        by_name("InternVL3-8B").unwrap(),
        DatasetKind::OpenVid,
        64,
        TrainStage::Full,
    );
    let mut sampler = ctx.sampler();
    let seqs = sampler.sample_batch(512);
    let groups = packing::pack_with_target(&seqs, &ctx.memory(), 16, 64);
    let wave = packing::waves(groups, 16).into_iter().next().unwrap();
    let cost = ctx.cost_model();
    report.bench(&format!("dp_allocate_k{}_n16", wave.len()), 2, 50, || {
        std::hint::black_box(dhp::scheduler::dp::allocate_degrees(
            &wave,
            16,
            |i, d| cost.t_total(&wave[i].agg, d, 12.5e9),
            dhp::scheduler::any_degree,
        ));
    });
    report.finish();
}
