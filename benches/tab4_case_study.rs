//! Bench: regenerate Table 4 (per-micro-batch CP-group case study) and
//! time a full case computation.

use dhp::data::datasets::DatasetKind;
use dhp::experiments::case_study;
use dhp::util::bench::BenchReport;
use dhp::util::cli::Args;

fn main() {
    let mut args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))
        .expect("args");
    args.options.entry("gbs".into()).or_insert("128".into());
    println!("=== tab4: case study ===");
    case_study::run(&args).expect("tab4");

    let mut report = BenchReport::new("tab4");
    report.bench("case_openvid_gbs128", 0, 5, || {
        std::hint::black_box(case_study::compute_case(
            DatasetKind::OpenVid,
            32,
            128,
            21,
        ));
    });
    report.finish();
}
