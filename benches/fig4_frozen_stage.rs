//! Bench: regenerate Fig. 4 (frozen-vision-encoder iteration times, all 18
//! configs) and time one configuration's full protocol.

use dhp::config::TrainStage;
use dhp::experiments::end_to_end;
use dhp::util::bench::BenchReport;
use dhp::util::cli::Args;

fn main() {
    let mut args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))
        .expect("args");
    // Keep the bench run bounded.
    args.options.entry("warmup".into()).or_insert("1".into());
    args.options.entry("measure".into()).or_insert("3".into());
    println!("=== fig4: frozen vision encoder ===");
    end_to_end::run(&args, TrainStage::FrozenVision).expect("fig4");

    let mut report = BenchReport::new("fig4");
    report.bench("one_config_protocol_frozen", 0, 3, || {
        std::hint::black_box(end_to_end::compute(
            TrainStage::FrozenVision,
            32,
            128,
            0,
            2,
            7,
        ));
    });
    report.finish();
}
