//! Bench: regenerate Fig. 1 (dataset duration distributions) and time the
//! generators.

use dhp::experiments::distributions;
use dhp::util::bench::BenchReport;
use dhp::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))
        .expect("args");
    println!("=== fig1: dataset distributions ===");
    distributions::run(&args).expect("fig1");

    let mut report = BenchReport::new("fig1");
    report.bench("sample_10k_per_dataset", 1, 5, || {
        std::hint::black_box(distributions::compute(10_000, 1));
    });
    report.finish();
}
