//! Bench: regenerate Table 3 (cost-estimator error per model/scale); if
//! AOT artifacts are present, also fit the cost model from REAL PJRT-CPU
//! executions of the lowered model and report the fit quality.

use dhp::experiments::estimator;
use dhp::util::bench::BenchReport;
use dhp::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))
        .expect("args");
    println!("=== tab3: estimator error ===");
    estimator::run(&args).expect("tab3");

    // Real-runtime calibration path (DESIGN.md §2): needs `make artifacts`.
    let artifacts = std::path::Path::new("artifacts");
    if artifacts.join("manifest.json").exists() {
        match estimator::fit_from_runtime(artifacts, 3) {
            Ok((coeffs, fit)) => {
                println!(
                    "real-PJRT profiler fit: alpha1={:.3e} alpha2={:.3e} \
                     beta1={:.3e}  (MAPE {:.2}%, R2 {:.4}, n={})",
                    coeffs.alpha1, coeffs.alpha2, coeffs.beta1, fit.mape,
                    fit.r_squared, fit.n
                );
            }
            Err(e) => println!("real-PJRT profiling skipped: {e}"),
        }
    } else {
        println!("artifacts/ missing — run `make artifacts` for the real-PJRT fit");
    }

    let mut report = BenchReport::new("tab3");
    report.bench("calibrate_and_evaluate_6_presets", 0, 3, || {
        std::hint::black_box(estimator::compute(11));
    });
    report.finish();
}
