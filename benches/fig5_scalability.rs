//! Bench: regenerate Fig. 5 (throughput scaling over 8→64 NPUs) and time
//! the sweep.

use dhp::experiments::scalability;
use dhp::util::bench::BenchReport;
use dhp::util::cli::Args;

fn main() {
    let mut args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))
        .expect("args");
    args.options.entry("warmup".into()).or_insert("1".into());
    args.options.entry("measure".into()).or_insert("3".into());
    println!("=== fig5: scalability ===");
    scalability::run(&args).expect("fig5");

    let mut report = BenchReport::new("fig5");
    report.bench("npus_sweep_8_to_64", 0, 3, || {
        std::hint::black_box(scalability::compute(&[8, 16, 32, 64], 128, 0, 2, 5));
    });
    report.finish();
}
