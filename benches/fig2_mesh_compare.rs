//! Bench: regenerate Fig. 2 (static vs dynamic mesh) and time one full
//! schedule+simulate round trip.

use dhp::experiments::mesh_compare;
use dhp::util::bench::BenchReport;
use dhp::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))
        .expect("args");
    println!("=== fig2: static vs dynamic mesh ===");
    mesh_compare::run(&args).expect("fig2");

    let mut report = BenchReport::new("fig2");
    report.bench("schedule_and_simulate_24seq_32npu", 1, 10, || {
        std::hint::black_box(mesh_compare::compute(32, 24, 7));
    });
    report.finish();
}
