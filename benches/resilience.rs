//! Resilience bench: MTBF-sweep goodput for DHP and the baselines, plus
//! the zero-drift gate — a zero-fault (quiet-injector) run must be
//! bit-identical to a session with no injector at all. Any drift means
//! the fault machinery leaks into the fault-free path, and the bench
//! exits non-zero so CI catches it.
//!
//! Usage:
//!   cargo bench --bench resilience              # full sweep
//!   cargo bench --bench resilience -- --quick   # CI smoke (small sweep)
//!
//! Both modes persist per-cell goodput to `BENCH_resilience.json` at the
//! repo root (see scripts/bench_smoke.sh).

use std::path::Path;

use dhp::cluster::FaultConfig;
use dhp::config::presets::by_name;
use dhp::config::TrainStage;
use dhp::data::datasets::DatasetKind;
use dhp::experiments::harness::ExpContext;
use dhp::experiments::resilience::{compute, run_policy_under_faults};
use dhp::util::json::{self, Json};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (npus, gbs, steps) = if quick { (16, 24, 6) } else { (32, 64, 30) };
    let seed = 0xFA17u64;
    let mut ctx = ExpContext::new(
        by_name(if quick { "InternVL3-2B" } else { "InternVL3-8B" }).unwrap(),
        DatasetKind::OpenVid,
        npus,
        TrainStage::Full,
    )
    .with_gbs(gbs);
    ctx.seed = seed;

    // Zero-drift gate: quiet injector vs no injector, digest-for-digest.
    let dhp = ctx.dhp();
    let quiet = run_policy_under_faults(
        &ctx,
        &dhp,
        FaultConfig::quiet(seed),
        steps.min(4),
    );
    let mut bare = ctx.session_for(Box::new(ctx.dhp()));
    let mut sampler = ctx.sampler();
    let mut bare_digest: u64 = 0;
    for _ in 0..steps.min(4) {
        let report = bare.step(&sampler.sample_batch(ctx.gbs));
        bare_digest = bare_digest.rotate_left(1) ^ report.digest();
    }
    if quiet.digest != bare_digest {
        eprintln!(
            "[bench] ZERO-DRIFT VIOLATION: quiet-injector digest {:#018x} != \
             injector-free digest {:#018x}",
            quiet.digest, bare_digest
        );
        std::process::exit(1);
    }
    println!("[bench] zero-fault path is bit-identical to the fault-free path");

    let mtbfs: &[f64] = if quick { &[0.0, 8.0] } else { &[0.0, 50.0, 20.0, 8.0] };
    let rows = compute(&ctx, mtbfs, steps, seed);
    println!(
        "{:<14} {:>12} {:>8} {:>8} {:>13} {:>18}",
        "policy", "mtbf", "useful", "failed", "recovery (s)", "goodput (steps/s)"
    );
    for r in &rows {
        println!(
            "{:<14} {:>12} {:>8} {:>8} {:>13.1} {:>18.4}",
            r.policy,
            if r.mtbf_steps <= 0.0 {
                "none".to_string()
            } else {
                format!("{:.0}", r.mtbf_steps)
            },
            r.useful_steps,
            r.failed_steps,
            r.recovery_s,
            r.goodput_steps_per_s
        );
    }

    // Persist the trajectory record at the repo root.
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap_or_else(|| Path::new("."))
        .to_path_buf();
    let out = root.join("BENCH_resilience.json");
    let cells: Vec<Json> = rows
        .iter()
        .map(|r| {
            json::obj(vec![
                ("policy", json::s(&r.policy)),
                ("mtbf_steps", json::num(r.mtbf_steps)),
                ("useful_steps", json::num(r.useful_steps as f64)),
                ("failed_steps", json::num(r.failed_steps as f64)),
                ("recovery_s", json::num(r.recovery_s)),
                ("straggle_s", json::num(r.straggle_s)),
                ("goodput_steps_per_s", json::num(r.goodput_steps_per_s)),
            ])
        })
        .collect();
    let doc = json::obj(vec![
        ("bench", json::s("resilience")),
        ("quick", Json::Bool(quick)),
        ("steps", json::num(steps as f64)),
        ("zero_drift_ok", Json::Bool(true)),
        ("cells", json::arr(cells)),
    ]);
    match std::fs::write(&out, doc.to_string_pretty()) {
        Ok(()) => println!("[bench] wrote {}", out.display()),
        Err(e) => eprintln!("[bench] failed to write {}: {e}", out.display()),
    }
}
