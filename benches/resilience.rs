//! Resilience bench: MTBF-sweep goodput for DHP and the baselines, plus
//! three self-gating invariant checks — any violation exits non-zero so
//! CI catches it:
//!
//! 1. Zero-drift (boundary): a zero-fault (quiet-injector) run must be
//!    bit-identical to a session with no injector at all.
//! 2. Zero-drift (event kernel): the same quiet run on the
//!    discrete-event kernel (`within_step_faults(true)`) must also be
//!    bit-identical — the kernel is a pure re-ordering of the same
//!    arithmetic when no fault arrives.
//! 3. Mid-wave charging: a scripted mid-wave `RankFailure`, replayed on
//!    both paths, must charge strictly less lost work on the event
//!    kernel (partial-wave re-execution) than on the boundary path
//!    (whole `work_since_ckpt` replay).
//!
//! Usage:
//!   cargo bench --bench resilience              # full sweep
//!   cargo bench --bench resilience -- --quick   # CI smoke (small sweep)
//!
//! Both modes persist per-cell goodput to `BENCH_resilience.json` at the
//! repo root (see scripts/bench_smoke.sh).

use std::path::Path;

use dhp::cluster::{FaultConfig, FaultEvent, FaultInjector, TimedFault};
use dhp::config::presets::by_name;
use dhp::config::TrainStage;
use dhp::data::datasets::DatasetKind;
use dhp::experiments::harness::ExpContext;
use dhp::experiments::resilience::{
    compute, run_policy_under_faults, run_policy_under_faults_within_step,
};
use dhp::util::json::{self, Json};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (npus, gbs, steps) = if quick { (16, 24, 6) } else { (32, 64, 30) };
    let seed = 0xFA17u64;
    let mut ctx = ExpContext::new(
        by_name(if quick { "InternVL3-2B" } else { "InternVL3-8B" }).unwrap(),
        DatasetKind::OpenVid,
        npus,
        TrainStage::Full,
    )
    .with_gbs(gbs);
    ctx.seed = seed;

    // Gate 1 — zero-drift: quiet injector vs no injector, digest-for-digest.
    let dhp = ctx.dhp();
    let quiet = run_policy_under_faults(
        &ctx,
        &dhp,
        FaultConfig::quiet(seed),
        steps.min(4),
    );
    let mut bare = ctx.session_for(Box::new(ctx.dhp()));
    let mut sampler = ctx.sampler();
    let mut bare_digest: u64 = 0;
    for _ in 0..steps.min(4) {
        let report = bare.step(&sampler.sample_batch(ctx.gbs));
        bare_digest = bare_digest.rotate_left(1) ^ report.digest();
    }
    if quiet.digest != bare_digest {
        eprintln!(
            "[bench] ZERO-DRIFT VIOLATION: quiet-injector digest {:#018x} != \
             injector-free digest {:#018x}",
            quiet.digest, bare_digest
        );
        std::process::exit(1);
    }
    println!("[bench] zero-fault path is bit-identical to the fault-free path");

    // Gate 2 — zero-drift on the event kernel: the quiet run replayed
    // through the discrete-event executor must not move a single bit.
    let quiet_ws = run_policy_under_faults_within_step(
        &ctx,
        &dhp,
        FaultConfig::quiet(seed),
        steps.min(4),
    );
    if quiet_ws.digest != bare_digest {
        eprintln!(
            "[bench] EVENT-KERNEL DRIFT: quiet within-step digest {:#018x} != \
             injector-free digest {:#018x}",
            quiet_ws.digest, bare_digest
        );
        std::process::exit(1);
    }
    println!("[bench] quiet event kernel is bit-identical to the reference path");

    // Gate 3 — mid-wave charging: the same scripted failure trace on
    // both paths; the event kernel must charge strictly less lost work.
    let trace = vec![
        Vec::new(),
        vec![TimedFault {
            at_frac: 0.45,
            event: FaultEvent::RankFailure { rank: 2 },
        }],
    ];
    let run_trace = |within: bool| -> (f64, usize) {
        let mut session = ctx
            .session_builder_for(Box::new(ctx.dhp()))
            .fault_injector(FaultInjector::scripted_timed(
                ctx.replicas(),
                trace.clone(),
            ))
            .within_step_faults(within)
            .build();
        let mut sampler = ctx.sampler();
        let mut lost = 0.0;
        let mut interrupted = 0usize;
        for _ in 0..3 {
            let report = session.step(&sampler.sample_batch(ctx.gbs));
            lost += report.lost_work_s;
            interrupted += report.iteration.interrupted_waves;
        }
        (lost, interrupted)
    };
    let (ev_lost, ev_interrupted) = run_trace(true);
    let (bd_lost, _) = run_trace(false);
    if ev_lost >= bd_lost || ev_interrupted == 0 {
        eprintln!(
            "[bench] MID-WAVE CHARGING VIOLATION: event-kernel lost work \
             {ev_lost:.3}s (interrupted {ev_interrupted}) must be strictly \
             below the boundary replay's {bd_lost:.3}s"
        );
        std::process::exit(1);
    }
    println!(
        "[bench] mid-wave failure charges {ev_lost:.3}s vs boundary {bd_lost:.3}s"
    );

    let mtbfs: &[f64] = if quick { &[0.0, 8.0] } else { &[0.0, 50.0, 20.0, 8.0] };
    let rows = compute(&ctx, mtbfs, steps, seed);
    println!(
        "{:<14} {:>12} {:>9} {:>8} {:>8} {:>13} {:>10} {:>18}",
        "policy", "mtbf", "faults", "useful", "failed", "recovery (s)",
        "lost (s)", "goodput (steps/s)"
    );
    for r in &rows {
        println!(
            "{:<14} {:>12} {:>9} {:>8} {:>8} {:>13.1} {:>10.1} {:>18.4}",
            r.policy,
            if r.mtbf_steps <= 0.0 {
                "none".to_string()
            } else {
                format!("{:.0}", r.mtbf_steps)
            },
            if r.within_step { "mid-wave" } else { "boundary" },
            r.useful_steps,
            r.failed_steps,
            r.recovery_s,
            r.lost_work_s,
            r.goodput_steps_per_s
        );
    }

    // Persist the trajectory record at the repo root.
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap_or_else(|| Path::new("."))
        .to_path_buf();
    let out = root.join("BENCH_resilience.json");
    let cells: Vec<Json> = rows
        .iter()
        .map(|r| {
            json::obj(vec![
                ("policy", json::s(&r.policy)),
                ("mtbf_steps", json::num(r.mtbf_steps)),
                ("within_step", Json::Bool(r.within_step)),
                ("useful_steps", json::num(r.useful_steps as f64)),
                ("failed_steps", json::num(r.failed_steps as f64)),
                ("recovery_s", json::num(r.recovery_s)),
                ("straggle_s", json::num(r.straggle_s)),
                ("lost_work_s", json::num(r.lost_work_s)),
                ("goodput_steps_per_s", json::num(r.goodput_steps_per_s)),
            ])
        })
        .collect();
    let doc = json::obj(vec![
        ("bench", json::s("resilience")),
        ("quick", Json::Bool(quick)),
        ("steps", json::num(steps as f64)),
        ("zero_drift_ok", Json::Bool(true)),
        ("within_step_zero_drift_ok", Json::Bool(true)),
        ("mid_wave_charges_less_ok", Json::Bool(true)),
        ("cells", json::arr(cells)),
    ]);
    match std::fs::write(&out, doc.to_string_pretty()) {
        Ok(()) => println!("[bench] wrote {}", out.display()),
        Err(e) => eprintln!("[bench] failed to write {}: {e}", out.display()),
    }
}
