//! Bench: regenerate Table 2 (compute vs schedule vs solver time over NPU
//! count) and micro-time the protocol at each scale.

use dhp::experiments::overhead;
use dhp::util::bench::BenchReport;
use dhp::util::cli::Args;

fn main() {
    let mut args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))
        .expect("args");
    args.options.entry("warmup".into()).or_insert("1".into());
    args.options.entry("measure".into()).or_insert("3".into());
    println!("=== tab2: overhead vs NPU count ===");
    overhead::run_npus(&args).expect("tab2");

    let mut report = BenchReport::new("tab2");
    for npus in [16usize, 32, 64] {
        report.bench(&format!("protocol_npus{npus}_gbs512"), 0, 3, || {
            std::hint::black_box(overhead::compute_row(512, npus, 0, 2, 13));
        });
    }
    report.finish();
}
