//! Cluster-day bench: replay a seeded multi-tenant job trace through
//! every allocator-policy × session-scheduler cell, plus two
//! self-gating invariant checks — any violation exits non-zero so CI
//! catches it:
//!
//! 1. Determinism: every cell replayed twice must be digest- and
//!    byte-identical (the shared virtual clock's `(time, job_id)`
//!    discipline).
//! 2. Departure scenario: on the pinned trace where one job's
//!    departure re-admits a queued job, the queued job's goodput under
//!    best-fit + DHP must measurably beat first-fit + DHP (the whole
//!    node vs cross-node grant).
//!
//! Usage:
//!   cargo bench --bench cluster_day              # full day
//!   cargo bench --bench cluster_day -- --quick   # CI smoke
//!
//! Both modes persist per-cell utilization/SLO rows to
//! `BENCH_cluster_day.json` at the repo root (see
//! scripts/bench_smoke.sh).

use std::path::Path;

use dhp::cluster_service::AllocPolicy;
use dhp::experiments::cluster_day::{
    compute, day_trace, departure_trace, queued_job_goodput, summary_table,
};
use dhp::util::json::{self, Json};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let seed = 0xC1_D4Bu64;

    // Gate 1 — determinism: both traces, every cell, replayed twice.
    let dep_a = compute(&departure_trace()).expect("departure cells");
    let dep_b = compute(&departure_trace()).expect("departure cells");
    let day_a = compute(&day_trace(seed, quick)).expect("day cells");
    let day_b = compute(&day_trace(seed, quick)).expect("day cells");
    for (a, b) in dep_a.iter().zip(&dep_b).chain(day_a.iter().zip(&day_b)) {
        if a.report.digest != b.report.digest
            || a.report.render() != b.report.render()
        {
            eprintln!(
                "[bench] DETERMINISM VIOLATION: {}/{} digests {:#018x} vs \
                 {:#018x}",
                a.alloc.name(),
                a.scheduler.name(),
                a.report.digest,
                b.report.digest
            );
            std::process::exit(1);
        }
    }
    println!("[bench] every cell replays bit-identically");

    // Gate 2 — the departure scenario's allocator effect.
    let ff = queued_job_goodput(&dep_a, AllocPolicy::FirstFit);
    let bf = queued_job_goodput(&dep_a, AllocPolicy::BestFit);
    if !(ff > 0.0 && bf > ff * 1.05) {
        eprintln!(
            "[bench] DEPARTURE-SCENARIO VIOLATION: queued-job goodput \
             best-fit {bf:.4} must beat first-fit {ff:.4} by >5%"
        );
        std::process::exit(1);
    }
    println!(
        "[bench] queued job goodput: first-fit {:.4} vs best-fit {:.4} \
         steps/s ({:+.1}%)",
        ff,
        bf,
        (bf / ff - 1.0) * 100.0
    );

    print!("{}", summary_table("Departure scenario", &dep_a).render());
    print!(
        "{}",
        summary_table(&format!("Cluster day (seed {seed:#x})"), &day_a)
            .render()
    );

    // Persist the trajectory record at the repo root.
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap_or_else(|| Path::new("."))
        .to_path_buf();
    let out = root.join("BENCH_cluster_day.json");
    let cell_rows = |cells: &[dhp::experiments::cluster_day::CellResult]| {
        cells
            .iter()
            .map(|c| {
                json::obj(vec![
                    ("alloc_policy", json::s(c.alloc.name())),
                    ("scheduler", json::s(c.scheduler.name())),
                    (
                        "mean_utilization",
                        json::num(c.report.mean_utilization()),
                    ),
                    (
                        "mean_fragmentation",
                        json::num(c.report.mean_fragmentation()),
                    ),
                    (
                        "mean_queue_wait_steps",
                        json::num(c.report.mean_queue_wait_steps()),
                    ),
                    (
                        "completed_jobs",
                        json::num(c.report.completed_jobs() as f64),
                    ),
                    ("jobs", json::num(c.report.jobs.len() as f64)),
                    (
                        "total_goodput_steps_per_s",
                        json::num(c.report.total_goodput_steps_per_s()),
                    ),
                    ("digest", json::s(&format!("{:016x}", c.report.digest))),
                ])
            })
            .collect::<Vec<Json>>()
    };
    let doc = json::obj(vec![
        ("bench", json::s("cluster_day")),
        ("quick", Json::Bool(quick)),
        ("seed", json::num(seed as f64)),
        ("determinism_ok", Json::Bool(true)),
        ("departure_scenario_ok", Json::Bool(true)),
        ("queued_job_goodput_first_fit", json::num(ff)),
        ("queued_job_goodput_best_fit", json::num(bf)),
        ("departure_cells", json::arr(cell_rows(&dep_a))),
        ("day_cells", json::arr(cell_rows(&day_a))),
    ]);
    match std::fs::write(&out, doc.to_string_pretty()) {
        Ok(()) => println!("[bench] wrote {}", out.display()),
        Err(e) => eprintln!("[bench] failed to write {}: {e}", out.display()),
    }
}
