//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * degree policy: arbitrary-integer vs power-of-two (FlexSP) vs static;
//! * the balance-target outer search vs single-target packing;
//! * group pooling on vs off (creation-cost accounting);
//! * pool capacity: unbounded vs 2×/1×/0.5× of the workload's working
//!   set, with overlap-hidden vs fully-serial reconfiguration charging —
//!   locating where the paper's near-free-reconfiguration claim breaks.

use dhp::baselines::SchedulePolicy;
use dhp::cluster::CommKind;
use dhp::config::presets::by_name;
use dhp::config::TrainStage;
use dhp::data::batch::GlobalBatch;
use dhp::data::datasets::DatasetKind;
use dhp::experiments::harness::{run_policy, ExpContext, PolicySet};
use dhp::parallel::{GroupPool, PoolCapacity};
use dhp::scheduler::DegreePolicy;
use dhp::util::bench::BenchReport;

fn main() {
    let ctx = ExpContext::new(
        by_name("InternVL3-8B").unwrap(),
        DatasetKind::OpenVid,
        32,
        TrainStage::Full,
    )
    .with_gbs(128)
    .with_steps(1, 3);

    // --- Ablation 1: degree policy.
    println!("=== ablation: degree policy (OpenVid, 8 replicas, GBS 128) ===");
    let set = PolicySet::build(&ctx);
    let dhp = run_policy(&ctx, &set.dhp);
    let flex = dhp::experiments::harness::flexsp(&ctx);
    let flex_res = run_policy(&ctx, &flex);
    let mega = run_policy(&ctx, &set.megatron);
    println!(
        "  any-integer {:.3}s | pow2-only {:.3}s | static {:.3}s  \
         (relaxation gain over pow2: {:.2}%)",
        dhp.mean_iter_s,
        flex_res.mean_iter_s,
        mega.mean_iter_s,
        (flex_res.mean_iter_s / dhp.mean_iter_s - 1.0) * 100.0
    );

    // --- Ablation 2: balance-target outer search.
    println!("=== ablation: outer search over group-count targets ===");
    let sch = ctx.dhp();
    let mut sampler = ctx.sampler();
    let batch = GlobalBatch {
        step: 0,
        sequences: sampler.sample_batch(128),
    };
    let mbs = ctx.micro_batch_planner().plan(&batch);
    let sim = ctx.sim();
    let mut t_full = 0.0;
    let mut t_single = 0.0;
    for mb in &mbs {
        let full = sch.schedule(&mb.sequences);
        let single = sch.schedule_with_target(&mb.sequences, ctx.replicas());
        t_full += sim
            .execute_schedule(&mb.sequences, &full, CommKind::RingCp)
            .iter()
            .map(|w| w.makespan_s)
            .sum::<f64>();
        t_single += sim
            .execute_schedule(&mb.sequences, &single, CommKind::RingCp)
            .iter()
            .map(|w| w.makespan_s)
            .sum::<f64>();
    }
    println!(
        "  outer search {:.3}s vs single-target {:.3}s (gain {:.2}%)",
        t_full,
        t_single,
        (t_single / t_full - 1.0) * 100.0
    );

    // --- Ablation 3: group pool reuse. Schedules are PLACED, so the
    // pool keys come straight off the plans (no re-allocation here).
    println!("=== ablation: communication-group pooling ===");
    let mut pool = GroupPool::new();
    let mut created_without_pool = 0u64;
    for mb in &mbs {
        let s = sch.schedule(&mb.sequences);
        for plan in &s.waves {
            for g in &plan.groups {
                let (kind, ranks) = g.pool_key();
                pool.acquire(kind, ranks);
                created_without_pool += 1;
            }
        }
    }
    let stats = pool.stats();
    println!(
        "  groups requested {created_without_pool}, unique created {}, \
         hit-rate {:.1}%, creation time saved {:.1} ms",
        stats.misses,
        stats.hit_rate() * 100.0,
        (created_without_pool - stats.misses) as f64
            * dhp::parallel::group::GROUP_CREATE_COST_S
            * 1e3
    );

    // --- Ablation 4: pool capacity. The paper's "creation overhead
    // becomes negligible" claim holds only while the pool retains the
    // workload's working set; this sweep shows where it breaks down and
    // how much of the residual cost the prewarm overlap still hides.
    println!("=== ablation: pool capacity (reconfiguration economics) ===");
    let cap_ctx = ctx.clone().with_steps(4, 6);
    let unbounded = run_policy(&cap_ctx, &cap_ctx.dhp());
    let working_set = unbounded.pool_groups.max(2);
    println!(
        "  working set: {} groups ({:.0} MB modeled communicator buffers)",
        working_set,
        unbounded.pool_buffer_bytes as f64 / 1e6
    );
    println!(
        "  {:<24} {:>8} {:>8} {:>9} {:>12} {:>12} {:>8}",
        "capacity", "hit-rate", "replay", "evictions", "charged (ms)", "serial (ms)", "iter (s)"
    );
    let mut sweep: Vec<(String, Option<usize>)> = vec![("unbounded".into(), None)];
    for (label, frac) in [("2.0x working set", 2.0), ("1.0x working set", 1.0), ("0.5x working set", 0.5)] {
        let cap = ((working_set as f64 * frac).round() as usize).max(1);
        sweep.push((format!("{label} ({cap})"), Some(cap)));
    }
    for (label, cap) in sweep {
        let r = match cap {
            None => unbounded.clone(),
            Some(c) => {
                let cctx = cap_ctx
                    .clone()
                    .with_pool_capacity(PoolCapacity::MaxGroups(c));
                run_policy(&cctx, &cctx.dhp())
            }
        };
        println!(
            "  {:<24} {:>8.2} {:>8.2} {:>9} {:>12.1} {:>12.1} {:>8.3}",
            label,
            r.pool.hit_rate(),
            r.replay_rate,
            r.pool.evictions,
            r.mean_reconfig_s * 1e3,
            r.mean_reconfig_serial_s * 1e3,
            r.mean_iter_s,
        );
        assert!(
            r.mean_reconfig_s <= r.mean_reconfig_serial_s + 1e-12,
            "overlap charging exceeded the serial cost"
        );
    }

    // --- Timings.
    let mut report = BenchReport::new("ablations");
    report.bench("policy_set_tuning", 0, 3, || {
        std::hint::black_box(PolicySet::build(&ctx));
    });
    report.finish();
}
