//! First-principles cost accounting — the cluster simulator's ground
//! truth, deliberately more detailed than the scheduler's Eq. 8–10
//! parametric estimator:
//!
//! * LM causal attention and vision-encoder FULL attention are costed
//!   separately with their own hidden dims and layer counts (the paper's
//!   reduced form folds the vision term into one (1+η)|s|² expression —
//!   that folding is precisely the modelling error Table 3 measures);
//! * the training stage (full vs frozen vision) changes the backward
//!   multiplier of the vision tower;
//! * ring communication is stepped per hop with per-hop latency.

use crate::config::presets::ModelPreset;
use crate::config::TrainStage;
use crate::cost::HardwareSpec;
use crate::data::sequence::Sequence;

/// FLOPs multiplier for a trained component (fwd + 2×bwd).
const TRAIN_MULT: f64 = 3.0;
/// FLOPs multiplier for a frozen component (fwd only).
const FROZEN_MULT: f64 = 1.0;

/// Exact FLOPs of one training step over one sequence.
pub fn seq_flops(preset: &ModelPreset, stage: TrainStage, s: &Sequence) -> f64 {
    let l = s.len() as f64;
    let lv = s.vision_tokens as f64;
    let vis_mult = match stage {
        TrainStage::Full => TRAIN_MULT,
        TrainStage::FrozenVision => FROZEN_MULT,
    };
    let lm = TRAIN_MULT
        * (preset.attn_flops_per_token_sq() * l * l
            + preset.linear_flops_per_token() * l);
    let vision = vis_mult
        * (preset.vision_attn_flops_per_token_sq() * lv * lv
            + preset.vision_linear_flops_per_token() * lv);
    lm + vision
}

/// Of which: the ring-overlappable LM attention score/value FLOPs.
pub fn seq_attn_flops(preset: &ModelPreset, s: &Sequence) -> f64 {
    let l = s.len() as f64;
    TRAIN_MULT * preset.attn_flops_per_token_sq() * l * l
}

/// Ring-exchanged KV bytes per token (K+V, GQA heads, bf16, all layers).
pub fn kv_bytes_per_token(preset: &ModelPreset) -> f64 {
    let kv_frac = preset.kv_groups as f64 / preset.heads as f64;
    2.0 * kv_frac * preset.hidden as f64 * 2.0 * preset.layers as f64
}

/// Exact per-group execution time at CP degree `d` over bandwidth `v_p`:
/// the ring is stepped hop by hop, overlapping each hop's KV transfer with
/// the previous hop's attention compute (what Ring Attention actually
/// does), then the non-overlappable linear work is added.
pub fn group_time(
    preset: &ModelPreset,
    stage: TrainStage,
    hw: &HardwareSpec,
    seqs: &[Sequence],
    d: usize,
    v_p: f64,
) -> f64 {
    let flops_rate = hw.effective_flops();
    let total_flops: f64 = seqs.iter().map(|s| seq_flops(preset, stage, s)).sum();
    let attn_flops: f64 = seqs.iter().map(|s| seq_attn_flops(preset, s)).sum();
    let other_flops = total_flops - attn_flops;
    let tokens: f64 = seqs.iter().map(|s| s.len() as f64).sum();

    if d <= 1 {
        return total_flops / flops_rate + hw.launch_overhead_s;
    }

    // Per-rank, per-hop quantities: each of the d ranks holds 1/d of the
    // group's PACKED token stream and sweeps d KV chunks (d−1 remote).
    // The ring rotates INSIDE every attention layer, so per-hop fixed
    // costs (kernel relaunch + P2P setup) are paid once per layer per hop.
    //
    // Crucially, attention between DIFFERENT packed sequences is masked
    // out: a hop at chunk distance δ only does useful work for token
    // pairs of the same sequence spanning ≥ δ chunks. Short sequences
    // packed into a big ring therefore ship full-size KV chunks past
    // ranks that have nothing to compute on them — the transfer is
    // EXPOSED. This is the paper's "redundant communication caused by
    // packing massive short sequences" (§4.3), and the mechanism that
    // makes over-sized static meshes lose.
    let layers = preset.layers as f64;
    let chunk = tokens / d as f64;
    let kv_chunk_bytes = kv_bytes_per_token(preset) * chunk;
    let transfer = kv_chunk_bytes / v_p + hw.p2p_latency_s * layers;

    // Useful attention FLOPs at hop distance δ: pairs further apart than
    // δ·chunk, i.e. Σ_k ((s_k − δ·C)⁺)² tails of the per-sequence
    // quadratic mass.
    let tail = |delta: f64| -> f64 {
        seqs.iter()
            .map(|s| {
                let rem = (s.len() as f64 - delta * chunk).max(0.0);
                rem * rem
            })
            .sum::<f64>()
    };
    let quad_total: f64 = tail(0.0);

    let mut t = 0.0;
    for hop in 0..d {
        let delta = hop as f64;
        // Attention mass exclusive to this hop distance, spread over the
        // d ranks (each rank computes its 1/d query share).
        let frac = if quad_total > 0.0 {
            (tail(delta) - tail(delta + 1.0)).max(0.0) / quad_total
        } else {
            0.0
        };
        let attn_hop = attn_flops * frac / d as f64 / flops_rate;
        let xfer = if hop < d - 1 { transfer } else { 0.0 };
        t += attn_hop.max(xfer);
        if hop < d - 1 {
            t += hw.hop_overhead_s * layers;
        }
    }
    t += other_flops / (d as f64 * flops_rate);
    t + hw.launch_overhead_s
}

/// DeepSpeed-Ulysses group time: all-to-all sequence/head redistribution
/// around attention instead of a KV ring. Per layer, four all-to-alls move
/// the full activation (L·h·2 bytes) with each rank exchanging a (d−1)/d
/// share; Ulysses does NOT overlap these with attention compute. Degree
/// must divide the head count (the restriction DHP's Ring-CP lifts) —
/// callers enforce it; the cost itself is defined for any d.
pub fn ulysses_group_time(
    preset: &ModelPreset,
    stage: TrainStage,
    hw: &HardwareSpec,
    seqs: &[Sequence],
    d: usize,
    v_p: f64,
) -> f64 {
    let flops_rate = hw.effective_flops();
    let total_flops: f64 = seqs.iter().map(|s| seq_flops(preset, stage, s)).sum();
    let tokens: f64 = seqs.iter().map(|s| s.len() as f64).sum();
    let compute = total_flops / (d as f64 * flops_rate);
    if d <= 1 {
        return compute + hw.launch_overhead_s;
    }
    // 4 all-to-alls per layer (q/k/v scatter + output gather), fwd + bwd
    // (2×), half-precision activations, (d−1)/d wire share per rank.
    let bytes_per_token =
        4.0 * 2.0 * preset.hidden as f64 * 2.0 * preset.layers as f64;
    let frac = (d as f64 - 1.0) / d as f64;
    let comm = bytes_per_token * tokens * frac / (d as f64 * v_p)
        + 4.0 * hw.p2p_latency_s * preset.layers as f64;
    compute + comm + hw.launch_overhead_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::by_name;

    fn seq(lv: u64, lt: u64) -> Sequence {
        Sequence::new(0, lv, lt)
    }

    #[test]
    fn frozen_vision_is_cheaper() {
        let p = by_name("InternVL3-8B").unwrap();
        let s = seq(4096, 512);
        let full = seq_flops(&p, TrainStage::Full, &s);
        let frozen = seq_flops(&p, TrainStage::FrozenVision, &s);
        assert!(frozen < full);
        // Text-only sequences are unaffected by freezing.
        let t = seq(0, 512);
        assert_eq!(
            seq_flops(&p, TrainStage::Full, &t),
            seq_flops(&p, TrainStage::FrozenVision, &t)
        );
    }

    #[test]
    fn group_time_decreases_then_flattens() {
        let p = by_name("Qwen3VL-8B").unwrap();
        let hw = HardwareSpec::default();
        let seqs = vec![seq(6144, 512)];
        let t1 = group_time(&p, TrainStage::Full, &hw, &seqs, 1, 12.5e9);
        let t4 = group_time(&p, TrainStage::Full, &hw, &seqs, 4, 12.5e9);
        assert!(t4 < t1);
        // At very high degree with little work per rank, comm dominates:
        // the speedup from 32 → 64 collapses well below the ideal 2×.
        let t32 = group_time(&p, TrainStage::Full, &hw, &seqs, 32, 12.5e9);
        let t64 = group_time(&p, TrainStage::Full, &hw, &seqs, 64, 12.5e9);
        assert!(t64 >= t32 * 0.6, "t64 {t64} t32 {t32}");
    }

    #[test]
    fn short_sequence_has_interior_optimum() {
        let p = by_name("InternVL3-8B").unwrap();
        let hw = HardwareSpec::default();
        let seqs = vec![seq(128, 128)];
        let times: Vec<f64> = (1..=64)
            .map(|d| group_time(&p, TrainStage::Full, &hw, &seqs, d, 12.5e9))
            .collect();
        let best = times
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
            + 1;
        assert!(best < 64, "short-seq best degree {best} should be interior");
        assert!(times[63] > times[best - 1] * 1.2, "over-parallelizing must hurt");
    }

    #[test]
    fn higher_bandwidth_never_slower() {
        let p = by_name("InternVL3-2B").unwrap();
        let hw = HardwareSpec::default();
        let seqs = vec![seq(2048, 256), seq(512, 128)];
        for d in [2usize, 3, 5, 8] {
            let slow = group_time(&p, TrainStage::Full, &hw, &seqs, d, 12.5e9);
            let fast = group_time(&p, TrainStage::Full, &hw, &seqs, d, 196e9);
            assert!(fast <= slow + 1e-12, "d={d} fast {fast} slow {slow}");
        }
    }

    #[test]
    fn kv_bytes_reflect_gqa() {
        let full_kv = by_name("InternVL3-2B").unwrap(); // 2 groups / 12 heads
        let gqa = by_name("Qwen3VL-2B").unwrap(); // 8 groups / 16 heads
        let a = kv_bytes_per_token(&full_kv) / (full_kv.layers as f64);
        let b = kv_bytes_per_token(&gqa) / (gqa.layers as f64);
        // Per layer: 2·(2/12·1536)·2 = 1024 vs 2·(8/16·2048)·2 = 4096.
        assert!((a - 1024.0).abs() < 1e-9);
        assert!((b - 4096.0).abs() < 1e-9);
    }

    #[test]
    fn exact_vs_parametric_within_reasonable_error() {
        // The paper's Table 3 reports < 8% estimator error; our parametric
        // form should land in the same ballpark against the exact model
        // on text-dominated workloads (vision folding is the error source).
        use crate::cost::{CostCoeffs, CostModel, MemoryModel, WorkloadAgg};
        let p = by_name("InternVL3-8B").unwrap();
        let hw = HardwareSpec::default();
        let cm = CostModel {
            coeffs: CostCoeffs::analytic(&p, TrainStage::Full, &hw),
            memory: MemoryModel::new(&p, 64e9, 64),
        };
        let seqs = vec![seq(1024, 3072), seq(256, 768)];
        let agg = WorkloadAgg::of(&seqs);
        for d in [1usize, 2, 4, 8] {
            let exact = group_time(&p, TrainStage::Full, &hw, &seqs, d, 12.5e9);
            let est = cm.t_total(&agg, d, 12.5e9);
            let err = ((est - exact) / exact).abs();
            assert!(err < 0.35, "d={d} exact={exact} est={est} err={err}");
        }
    }
}
