//! The DHP cost model (paper §4.2): memory (Eq. 7), computation with the
//! mask-efficiency factor η (Eq. 8), ring communication (Eq. 9), and the
//! compute/communication overlap of ring attention (Eq. 10).
//!
//! Two cost layers exist deliberately:
//!
//! * [`exact`] — a first-principles per-component FLOP/byte accounting,
//!   used by the cluster *simulator* as ground truth;
//! * [`CostModel`] — the paper's reduced α/β parametric form, which the
//!   *scheduler* queries. Its coefficients come either from
//!   [`CostCoeffs::analytic`] (hardware spec + model preset) or from the
//!   [`profiler`], which fits them to measured executions exactly as the
//!   paper's Profiler class does.
//!
//! The gap between the two layers is a real modelling error, quantified by
//! the Table 3 experiment.

pub mod exact;
pub mod profiler;

use crate::config::presets::ModelPreset;
use crate::config::TrainStage;
use crate::data::sequence::Sequence;

/// Accelerator characteristics of one model replica (defaults: Ascend
/// 910B-class — 376 TFLOPS half-precision peak, ~0.35 achievable MFU).
#[derive(Debug, Clone)]
pub struct HardwareSpec {
    /// Peak half-precision FLOP/s of one replica.
    pub peak_flops: f64,
    /// Achievable fraction of peak (model FLOPs utilization).
    pub efficiency: f64,
    /// P2P hop latency inside a ring (seconds).
    pub p2p_latency_s: f64,
    /// Non-overlappable per-ring-hop overhead (attention kernel re-launch
    /// + P2P setup). This is what makes over-parallelizing SHORT sequences
    /// actively harmful — the paper's "redundant communication overhead"
    /// for short sequences (§1 requirement 2).
    pub hop_overhead_s: f64,
    /// Fixed per-micro-batch launch overhead (seconds).
    pub launch_overhead_s: f64,
}

impl Default for HardwareSpec {
    fn default() -> Self {
        HardwareSpec {
            peak_flops: 376e12,
            efficiency: 0.35,
            p2p_latency_s: 15e-6,
            hop_overhead_s: 30e-6,
            launch_overhead_s: 1e-3,
        }
    }
}

impl HardwareSpec {
    /// Sustained FLOP/s: peak × efficiency.
    pub fn effective_flops(&self) -> f64 {
        self.peak_flops * self.efficiency
    }
}

/// The fitted/derived coefficients of Eqs. 8–10.
#[derive(Debug, Clone, PartialEq)]
pub struct CostCoeffs {
    /// Seconds per token² of causal-LM attention work (Eq. 8 α₁).
    pub alpha1: f64,
    /// Seconds per token of linear (projection/MLP) work (Eq. 8 α₂).
    pub alpha2: f64,
    /// Fixed compute launch overhead (Eq. 8 β₁), seconds.
    pub beta1: f64,
    /// Ring-exchanged bytes per token (Eq. 9 α₃; divided by v_p at query
    /// time).
    pub alpha3: f64,
    /// Per-ring-hop fixed overhead (Eq. 9 β₂; charged (d−1)× — each ring
    /// step re-launches the attention kernel and a P2P transfer).
    pub beta2: f64,
    /// Fraction of the quadratic term that is ring-overlappable attention
    /// (used for Eq. 10's min(T_cpa, T_cma) term).
    pub attn_frac: f64,
}

impl CostCoeffs {
    /// Derive coefficients analytically from a model preset + hardware
    /// spec. Backward counts double the forward FLOPs (2 matmuls per
    /// forward one), so full training multiplies by 3; a frozen vision
    /// encoder contributes forward-only (paper Fig. 4's stage).
    pub fn analytic(
        preset: &ModelPreset,
        stage: TrainStage,
        hw: &HardwareSpec,
    ) -> CostCoeffs {
        let flops = hw.effective_flops();
        let train_mult = 3.0;
        // LM quadratic + linear terms (always trained).
        let alpha1 = preset.attn_flops_per_token_sq() * train_mult / flops;
        let alpha2 = preset.linear_flops_per_token() * train_mult / flops;
        // KV bytes exchanged per token per ring pass: K+V, GQA-sharded
        // heads, half precision, all layers.
        let kv_frac = preset.kv_groups as f64 / preset.heads as f64;
        let alpha3 =
            2.0 * (kv_frac * preset.hidden as f64) * 2.0 * preset.layers as f64;
        let _ = stage; // stage affects η's weight via exact::*, see below
        CostCoeffs {
            alpha1,
            alpha2,
            beta1: hw.launch_overhead_s,
            alpha3,
            // Per-hop fixed cost: the ring rotates inside EVERY attention
            // layer, so relaunch/setup gaps are paid per layer per hop.
            beta2: hw.hop_overhead_s * preset.layers as f64,
            attn_frac: 0.95,
        }
    }

    /// Content fingerprint of the coefficient set (FNV over the raw f64
    /// bit patterns). The solver's memoized cost cache
    /// ([`crate::scheduler::scratch::CostCache`]) keys every entry on this
    /// so cached `T(agg, d, bw)` values from one cost model are never
    /// served to another (the scratch pool is shared process-wide).
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        for bits in [
            self.alpha1.to_bits(),
            self.alpha2.to_bits(),
            self.beta1.to_bits(),
            self.alpha3.to_bits(),
            self.beta2.to_bits(),
            self.attn_frac.to_bits(),
        ] {
            h = (h ^ bits).wrapping_mul(0x100000001b3);
        }
        h
    }

    /// Scale coefficients fitted on one (small) model to another preset by
    /// FLOP ratio — how the repo transfers real PJRT-CPU profiles of the
    /// ~4M profile model onto the 2B–8B presets (DESIGN.md §2).
    pub fn scaled_to(
        &self,
        from_quad_flops: f64,
        from_lin_flops: f64,
        to: &ModelPreset,
    ) -> CostCoeffs {
        let quad_ratio = to.attn_flops_per_token_sq() / from_quad_flops;
        let lin_ratio = to.linear_flops_per_token() / from_lin_flops;
        let kv_frac = to.kv_groups as f64 / to.heads as f64;
        CostCoeffs {
            alpha1: self.alpha1 * quad_ratio,
            alpha2: self.alpha2 * lin_ratio,
            beta1: self.beta1,
            alpha3: 2.0 * (kv_frac * to.hidden as f64) * 2.0 * to.layers as f64,
            beta2: self.beta2,
            attn_frac: self.attn_frac,
        }
    }
}

/// Eq. 7's memory model: per-rank budget E, constant model states M_ms
/// (ZeRO-3), activation bytes per token M_token.
#[derive(Debug, Clone)]
pub struct MemoryModel {
    /// Per-rank memory budget E (bytes).
    pub e_bytes: f64,
    /// Model-state bytes per rank (M_ms).
    pub m_states: f64,
    /// Activation bytes per token (M_token).
    pub m_token: f64,
}

impl MemoryModel {
    /// Eq. 7 instantiated for a model preset: per-rank budget `e_bytes`,
    /// ZeRO-3 model states sharded over `zero_shards` ranks.
    pub fn new(preset: &ModelPreset, e_bytes: f64, zero_shards: usize) -> Self {
        MemoryModel {
            e_bytes,
            m_states: preset.model_state_bytes(zero_shards),
            m_token: preset.act_bytes_per_token(),
        }
    }

    /// Usable activation bytes per rank.
    pub fn rank_budget(&self) -> f64 {
        (self.e_bytes - self.m_states).max(0.0)
    }

    /// Minimum CP degree for `tokens` total tokens (Stage 1's
    /// d_min = ceil(M(s)/E) with model states pre-subtracted).
    pub fn min_degree(&self, tokens: u64) -> usize {
        let budget = self.rank_budget();
        if budget <= 0.0 {
            return usize::MAX;
        }
        ((tokens as f64 * self.m_token) / budget).ceil().max(1.0) as usize
    }

    /// Eq. 3: does a group with `tokens` total tokens fit at degree `d`?
    pub fn fits(&self, tokens: u64, d: usize) -> bool {
        tokens as f64 * self.m_token <= self.rank_budget() * d as f64
    }
}

/// Precomputed workload aggregates of a set of sequences, so the DP solver
/// evaluates T(G, d) in O(1).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WorkloadAgg {
    /// Σ (1+η_k)·|s_k|² (token² units).
    pub quad: f64,
    /// Σ |s_k|² — the causal-LM part only (the RING-overlappable share;
    /// the vision-encoder's full-attention surcharge runs outside the
    /// ring and cannot hide communication).
    pub quad_base: f64,
    /// Σ |s_k| (tokens).
    pub tokens: f64,
    /// Number of sequences.
    pub count: usize,
}

impl WorkloadAgg {
    /// Aggregate a sequence set.
    pub fn of(seqs: &[Sequence]) -> WorkloadAgg {
        let mut agg = WorkloadAgg::default();
        for s in seqs {
            agg.add(s);
        }
        agg
    }

    /// Fold one sequence into the aggregates.
    pub fn add(&mut self, s: &Sequence) {
        let l = s.len() as f64;
        self.quad += (1.0 + s.eta()) * l * l;
        self.quad_base += l * l;
        self.tokens += l;
        self.count += 1;
    }

    /// Fold another aggregate in (union of disjoint sequence sets).
    pub fn merge(&mut self, other: &WorkloadAgg) {
        self.quad += other.quad;
        self.quad_base += other.quad_base;
        self.tokens += other.tokens;
        self.count += other.count;
    }
}

/// The paper's parametric execution-time estimator (Eqs. 8–10).
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Eq. 8–10 coefficients (analytic or profiler-fitted).
    pub coeffs: CostCoeffs,
    /// Eq. 7 memory model (drives packing feasibility, not time).
    pub memory: MemoryModel,
}

impl CostModel {
    /// Eq. 8: computation time of a group at CP degree `d` — quadratic and
    /// linear work parallelize across the d ranks.
    pub fn t_compute(&self, agg: &WorkloadAgg, d: usize) -> f64 {
        let c = &self.coeffs;
        (c.alpha1 * agg.quad + c.alpha2 * agg.tokens) / d as f64 + c.beta1
    }

    /// Eq. 9's transfer component: ring KV-exchange bytes over bandwidth
    /// `v_p`. Each rank sends/receives its KV shard d−1 times: total bytes
    /// per rank = α₃·Σ|s|·(d−1)/d → α₃·Σ|s| asymptotically, matching
    /// Eq. 9's form. d = 1 needs no ring.
    pub fn t_transfer(&self, agg: &WorkloadAgg, d: usize, v_p: f64) -> f64 {
        if d <= 1 {
            return 0.0;
        }
        let frac = (d as f64 - 1.0) / d as f64;
        self.coeffs.alpha3 * agg.tokens * frac / v_p
    }

    /// Eq. 9: total communication time = transfer + per-hop overheads
    /// (β₂ charged per ring step — kernel re-launch and P2P setup are not
    /// hidden by the overlap).
    pub fn t_comm(&self, agg: &WorkloadAgg, d: usize, v_p: f64) -> f64 {
        if d <= 1 {
            return 0.0;
        }
        self.t_transfer(agg, d, v_p) + self.coeffs.beta2 * (d as f64 - 1.0)
    }

    /// Eq. 10: total time with ring-attention overlap —
    /// T = T_cp + T_cm − min(T_cpa, T_cma), where the overlappable
    /// communication T_cma is the transfer component (hop overheads are
    /// serial by construction).
    pub fn t_total(&self, agg: &WorkloadAgg, d: usize, v_p: f64) -> f64 {
        let t_cp = self.t_compute(agg, d);
        let t_cm = self.t_comm(agg, d, v_p);
        // Only the causal-LM attention (quad_base) rotates with the ring
        // and can hide KV transfers; the vision tower's full-attention
        // surcharge is computed outside the ring.
        let t_cpa =
            self.coeffs.attn_frac * self.coeffs.alpha1 * agg.quad_base / d as f64;
        let t_cma = self.t_transfer(agg, d, v_p);
        t_cp + t_cm - t_cpa.min(t_cma)
    }

    /// Convenience over raw sequences.
    pub fn group_time(&self, seqs: &[Sequence], d: usize, v_p: f64) -> f64 {
        self.t_total(&WorkloadAgg::of(seqs), d, v_p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::by_name;

    fn model() -> CostModel {
        let preset = by_name("InternVL3-8B").unwrap();
        let hw = HardwareSpec::default();
        CostModel {
            coeffs: CostCoeffs::analytic(&preset, TrainStage::Full, &hw),
            memory: MemoryModel::new(&preset, 64e9, 64),
        }
    }

    fn seqs(lens: &[u64]) -> Vec<Sequence> {
        lens.iter()
            .enumerate()
            .map(|(i, &l)| Sequence::new(i as u64, l / 2, l - l / 2))
            .collect()
    }

    #[test]
    fn compute_scales_down_with_degree() {
        let m = model();
        let agg = WorkloadAgg::of(&seqs(&[8192]));
        let t1 = m.t_compute(&agg, 1);
        let t4 = m.t_compute(&agg, 4);
        assert!(t4 < t1);
        // Near-linear modulo the fixed β₁.
        assert!(((t1 - m.coeffs.beta1) / (t4 - m.coeffs.beta1) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn comm_zero_at_degree_one_and_grows_with_degree() {
        let m = model();
        let agg = WorkloadAgg::of(&seqs(&[4096]));
        assert_eq!(m.t_comm(&agg, 1, 12.5e9), 0.0);
        let t2 = m.t_comm(&agg, 2, 12.5e9);
        let t8 = m.t_comm(&agg, 8, 12.5e9);
        let t64 = m.t_comm(&agg, 64, 12.5e9);
        assert!(t2 < t8 && t8 < t64);
        // Transfer saturates at α₃Σs/v; growth past that is per-hop β₂.
        let transfer_cap = m.coeffs.alpha3 * agg.tokens / 12.5e9;
        assert!(m.t_transfer(&agg, 64, 12.5e9) < transfer_cap);
        assert!(t64 > transfer_cap, "hop overheads dominate at high d");
    }

    #[test]
    fn total_has_sweet_spot_degree() {
        // For a SHORT sequence the total time must be non-monotone in d:
        // dropping at first (compute parallelism) then rising again
        // (per-hop ring overheads) — the fundamental tradeoff behind the
        // paper's requirement 2 ("prevent short sequences from incurring
        // redundant communication overhead").
        let m = model();
        let agg = WorkloadAgg::of(&seqs(&[512]));
        let bw = 12.5e9;
        let times: Vec<f64> = (1..=64).map(|d| m.t_total(&agg, d, bw)).collect();
        let best = times
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
            + 1;
        assert!(best < 64, "best degree {best} should be interior");
        assert!(times[best - 1] < times[0]);
        assert!(times[63] > times[best - 1]);
    }

    #[test]
    fn long_sequences_reward_higher_degrees_than_short() {
        // The relaxation DHP exploits: the optimal CP degree grows with
        // sequence length, so a heterogeneous batch wants MIXED degrees.
        let m = model();
        let bw = 12.5e9;
        let best_for = |l: u64| -> usize {
            let agg = WorkloadAgg::of(&seqs(&[l]));
            (1..=64)
                .min_by(|&a, &b| {
                    m.t_total(&agg, a, bw)
                        .partial_cmp(&m.t_total(&agg, b, bw))
                        .unwrap()
                })
                .unwrap()
        };
        let short = best_for(256);
        let long = best_for(8192);
        assert!(
            long > short,
            "long-seq best degree {long} <= short-seq best degree {short}"
        );
    }

    #[test]
    fn overlap_never_negative_total() {
        let m = model();
        for lens in [&[64u64][..], &[100, 7000], &[16384]] {
            let agg = WorkloadAgg::of(&seqs(lens));
            for d in [1usize, 2, 3, 5, 8, 17, 64] {
                let t = m.t_total(&agg, d, 12.5e9);
                assert!(t > 0.0, "t={t} lens={lens:?} d={d}");
                // Overlap cannot push below pure max(compute, comm) bound.
                let lower = m.t_compute(&agg, d).max(m.t_comm(&agg, d, 12.5e9));
                assert!(t + 1e-12 >= lower * 0.99);
            }
        }
    }

    #[test]
    fn full_attention_eta_raises_cost() {
        let m = model();
        let vision_heavy = Sequence::new(0, 1900, 100);
        let text_heavy = Sequence::new(1, 100, 1900);
        let tv = m.group_time(&[vision_heavy], 4, 12.5e9);
        let tt = m.group_time(&[text_heavy], 4, 12.5e9);
        assert!(tv > tt, "vision-heavy {tv} vs text-heavy {tt}");
    }

    #[test]
    fn memory_min_degree() {
        let preset = by_name("InternVL3-8B").unwrap();
        let mm = MemoryModel::new(&preset, 64e9, 64);
        // Short sequence fits on one rank.
        assert_eq!(mm.min_degree(512), 1);
        // Long sequences need more ranks, monotonically.
        let d8k = mm.min_degree(8192);
        let d64k = mm.min_degree(65536);
        assert!(d64k > d8k);
        assert!(mm.fits(8192, d8k));
        assert!(!mm.fits(8192, d8k - 1) || d8k == 1);
    }

    #[test]
    fn agg_matches_manual() {
        let s = seqs(&[100, 200]);
        let agg = WorkloadAgg::of(&s);
        let manual: f64 = s
            .iter()
            .map(|q| (1.0 + q.eta()) * (q.len() as f64).powi(2))
            .sum();
        assert!((agg.quad - manual).abs() < 1e-9);
        assert_eq!(agg.tokens, 300.0);
        assert_eq!(agg.count, 2);
    }

    #[test]
    fn scaled_coeffs_track_flops() {
        let small = by_name("InternVL3-2B").unwrap();
        let big = by_name("InternVL3-8B").unwrap();
        let hw = HardwareSpec::default();
        let c_small = CostCoeffs::analytic(&small, TrainStage::Full, &hw);
        let c_big_direct = CostCoeffs::analytic(&big, TrainStage::Full, &hw);
        let c_big_scaled = c_small.scaled_to(
            small.attn_flops_per_token_sq(),
            small.linear_flops_per_token(),
            &big,
        );
        assert!((c_big_scaled.alpha1 - c_big_direct.alpha1).abs() / c_big_direct.alpha1 < 1e-9);
        assert!((c_big_scaled.alpha2 - c_big_direct.alpha2).abs() / c_big_direct.alpha2 < 1e-9);
        assert!((c_big_scaled.alpha3 - c_big_direct.alpha3).abs() / c_big_direct.alpha3 < 1e-9);
    }
}
