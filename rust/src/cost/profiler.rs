//! The Profiler (paper §5, implementation detail 3): before training, run
//! forward/backward passes at swept sequence lengths and CP degrees,
//! measure execution times, and fit the functional relationship between
//! runtime and (sequence length, degree) — i.e. the α/β coefficients of
//! Eqs. 8–9. The scheduler then queries predictions at planning time with
//! no further measurement.
//!
//! Measurement sources are abstracted behind a closure so the same fitting
//! pipeline serves (a) REAL PJRT-CPU executions of the AOT-lowered model
//! (see `runtime::profile`) and (b) the cluster simulator's exact model
//! (for cluster-scale coefficient sets).

use anyhow::{bail, Result};

use crate::util::stats;

use super::CostCoeffs;

/// One profiling observation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sample {
    /// Total sequence length (tokens).
    pub seq_len: u64,
    /// (1+η)-weighted squared length — the Eq. 8 quadratic feature. For a
    /// single sequence this is (1+η)·L².
    pub quad: f64,
    /// CP degree the measurement ran at.
    pub degree: usize,
    /// Measured wall-clock seconds.
    pub time_s: f64,
}

impl Sample {
    /// Degree-1 observation of one sequence with mask-efficiency `eta`.
    pub fn simple(seq_len: u64, eta: f64, time_s: f64) -> Sample {
        let l = seq_len as f64;
        Sample {
            seq_len,
            quad: (1.0 + eta) * l * l,
            degree: 1,
            time_s,
        }
    }
}

/// Fits Eq. 8's compute coefficients from degree-1 measurements:
/// t = α₁·quad + α₂·L + β₁ (non-negative least squares — negative
/// coefficients are physically meaningless and would mislead the DP).
pub fn fit_compute(samples: &[Sample]) -> Result<CostCoeffs> {
    fit_compute_with(samples, CostCoeffs {
        alpha1: 0.0,
        alpha2: 0.0,
        beta1: 0.0,
        alpha3: 0.0,
        beta2: 0.0,
        attn_frac: 0.95,
    })
}

/// Same, but preserving the communication coefficients of `base`.
pub fn fit_compute_with(samples: &[Sample], base: CostCoeffs) -> Result<CostCoeffs> {
    let d1: Vec<&Sample> = samples.iter().filter(|s| s.degree == 1).collect();
    if d1.len() < 3 {
        bail!(
            "need >= 3 degree-1 samples to fit (quad, linear, const), got {}",
            d1.len()
        );
    }
    let mut design = Vec::with_capacity(d1.len() * 3);
    let mut y = Vec::with_capacity(d1.len());
    for s in &d1 {
        design.extend_from_slice(&[s.quad, s.seq_len as f64, 1.0]);
        y.push(s.time_s);
    }
    let beta = stats::nnls(&design, d1.len(), 3, &y, 2000);
    Ok(CostCoeffs {
        alpha1: beta[0],
        alpha2: beta[1],
        beta1: beta[2],
        ..base
    })
}

/// Fit quality diagnostics for a coefficient set against samples.
pub fn fit_error(coeffs: &CostCoeffs, samples: &[Sample]) -> FitReport {
    let mut obs = Vec::new();
    let mut pred = Vec::new();
    for s in samples.iter().filter(|s| s.degree == 1) {
        obs.push(s.time_s);
        pred.push(coeffs.alpha1 * s.quad + coeffs.alpha2 * s.seq_len as f64 + coeffs.beta1);
    }
    FitReport {
        mape: stats::mape(&obs, &pred),
        r_squared: stats::r_squared(&obs, &pred),
        n: obs.len(),
    }
}

/// Goodness-of-fit summary.
#[derive(Debug, Clone, Copy)]
pub struct FitReport {
    /// Mean absolute percentage error (%) — Table 3's metric.
    pub mape: f64,
    /// Coefficient of determination of the fit.
    pub r_squared: f64,
    /// Number of degree-1 samples the report covers.
    pub n: usize,
}

/// Run a measurement sweep: `measure(seq_len)` must return wall-clock
/// seconds for a degree-1 execution at that length, `reps` times each;
/// the median per length enters the fit (robust to scheduler noise).
pub fn sweep<F>(lengths: &[u64], eta: f64, reps: usize, mut measure: F) -> Vec<Sample>
where
    F: FnMut(u64) -> f64,
{
    let mut samples = Vec::with_capacity(lengths.len());
    for &l in lengths {
        let times: Vec<f64> = (0..reps.max(1)).map(|_| measure(l)).collect();
        samples.push(Sample::simple(l, eta, stats::median(&times)));
    }
    samples
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn synth_samples(a1: f64, a2: f64, b1: f64, noise: f64, seed: u64) -> Vec<Sample> {
        let mut rng = Rng::new(seed);
        [128u64, 256, 384, 512, 768, 1024, 1536, 2048]
            .iter()
            .map(|&l| {
                let lf = l as f64;
                let t = a1 * lf * lf + a2 * lf + b1;
                Sample::simple(l, 0.0, t * (1.0 + noise * rng.normal()))
            })
            .collect()
    }

    #[test]
    fn exact_fit_recovers_coefficients() {
        let samples = synth_samples(3e-9, 2e-6, 5e-4, 0.0, 1);
        let c = fit_compute(&samples).unwrap();
        assert!((c.alpha1 - 3e-9).abs() / 3e-9 < 1e-6, "{c:?}");
        assert!((c.alpha2 - 2e-6).abs() / 2e-6 < 1e-4, "{c:?}");
        assert!((c.beta1 - 5e-4).abs() / 5e-4 < 1e-2, "{c:?}");
    }

    #[test]
    fn noisy_fit_stays_close_and_reports_error() {
        let samples = synth_samples(3e-9, 2e-6, 5e-4, 0.03, 2);
        let c = fit_compute(&samples).unwrap();
        assert!((c.alpha1 - 3e-9).abs() / 3e-9 < 0.15, "{c:?}");
        let report = fit_error(&c, &samples);
        assert!(report.mape < 8.0, "paper-level error bound: {report:?}");
        assert!(report.r_squared > 0.99);
    }

    #[test]
    fn too_few_samples_is_error() {
        let samples = synth_samples(1e-9, 1e-6, 1e-4, 0.0, 3);
        assert!(fit_compute(&samples[..2]).is_err());
    }

    #[test]
    fn coefficients_never_negative() {
        // Pathological data sloping downward: NNLS must clamp.
        let samples = vec![
            Sample::simple(128, 0.0, 1.0),
            Sample::simple(256, 0.0, 0.8),
            Sample::simple(512, 0.0, 0.6),
            Sample::simple(1024, 0.0, 0.5),
        ];
        let c = fit_compute(&samples).unwrap();
        assert!(c.alpha1 >= 0.0 && c.alpha2 >= 0.0 && c.beta1 >= 0.0);
    }

    #[test]
    fn sweep_takes_medians() {
        let mut call = 0usize;
        let samples = sweep(&[100, 200], 0.0, 3, |l| {
            call += 1;
            // One outlier per length; median suppresses it.
            if call % 3 == 0 {
                1000.0
            } else {
                l as f64
            }
        });
        assert_eq!(samples.len(), 2);
        assert_eq!(samples[0].time_s, 100.0);
        assert_eq!(samples[1].time_s, 200.0);
    }

    #[test]
    fn eta_enters_quad_feature() {
        let s = Sample::simple(100, 1.0, 0.5);
        assert!((s.quad - 2.0 * 10_000.0).abs() < 1e-9);
    }
}
