//! # DHP — Dynamic Hybrid Parallelism for MLLM training
//!
//! A from-scratch reproduction of *"DHP: Efficient Scaling of MLLM Training
//! with Dynamic Hybrid Parallelism"* as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **Layer 3 (this crate)** — the coordination contribution: a
//!   micro-batch scheduler that dynamically partitions the cluster's model
//!   replicas into context-parallel (CP) groups of *arbitrary integer*
//!   degree and assigns heterogeneous-length multimodal sequences to groups
//!   to minimize makespan, via memory-aware Best-Fit-Decreasing packing
//!   ([`scheduler::packing`]) followed by 2D dynamic programming
//!   ([`scheduler::dp`], paper Alg. 1). Plus every substrate the paper
//!   depends on: a cost model (Eqs. 7–10, [`cost`]), a profiler that fits
//!   its coefficients from real PJRT executions ([`cost::profiler`]),
//!   communication-group pooling and MPU parallel state ([`parallel`]), a
//!   discrete-event cluster simulator ([`cluster`]), static-parallelism
//!   baselines ([`baselines`]), and an asynchronous scheduling pipeline
//!   ([`scheduler::pipeline`]) — all owned end to end by the
//!   [`session::DhpSession`] façade, which turns Algorithm 1's per-batch
//!   loop into `session.step(batch)` and feeds live mesh-occupancy
//!   events ([`session::MeshEvent`]) into the next solve.
//! * **Layer 2** — a JAX MLLM (vision encoder with full attention →
//!   connector → causal LM) lowered once, ahead of time, to HLO text
//!   (`python/compile/`).
//! * **Layer 1** — a Pallas flash-attention kernel called from the L2 model
//!   (`python/compile/kernels/`).
//!
//! The [`runtime`] module loads the AOT artifacts via the PJRT C API (the
//! `xla` crate) and executes them from Rust; Python never runs on the
//! training path.
//!
//! A map from every paper artifact (equations, algorithm, figures,
//! tables) to the code and bench target that reproduces it lives in
//! `docs/paper_map.md`.

#![warn(missing_docs)]

pub mod baselines;
pub mod cluster;
pub mod cluster_service;
pub mod config;
pub mod cost;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod parallel;
pub mod report;
pub mod runtime;
pub mod scheduler;
pub mod session;
pub mod train;
pub mod util;

/// Crate-wide result type (anyhow-based, like the rest of the stack).
pub type Result<T> = anyhow::Result<T>;
