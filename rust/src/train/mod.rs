//! Training drivers: the real PJRT trainer ([`trainer`]) with Rust-side
//! Adam ([`adam`]), and the `dhp train` CLI command.

pub mod adam;
pub mod checkpoint;
pub mod trainer;

use std::path::PathBuf;

use anyhow::Result;

use crate::util::cli::Args;

pub use adam::{average_grads, Adam, AdamConfig};
pub use checkpoint::{Checkpoint, CheckpointCostModel};
pub use trainer::{run, StepRecord, TrainReport, TrainerConfig};

/// `dhp train` — real end-to-end training on the AOT artifacts.
pub fn train_cmd(args: &Args) -> Result<()> {
    let preset = args.str_or("preset", "e2e");
    let (artifact, params_file) = match preset {
        "tiny" => ("model.hlo.txt", "tiny_params.f32"),
        "e2e" => ("e2e_grad.hlo.txt", "e2e_params.f32"),
        other => anyhow::bail!("--preset must be tiny|e2e, got {other:?}"),
    };
    let cfg = TrainerConfig {
        artifacts_dir: PathBuf::from(args.str_or("artifacts", "artifacts")),
        artifact: artifact.into(),
        params_file: params_file.into(),
        steps: args.usize_or("steps", 200)?,
        adam: AdamConfig {
            lr: args.f64_or("lr", 3e-4)? as f32,
            ..Default::default()
        },
        seed: args.u64_or("seed", 0xE2E)?,
        log_path: args.get("log").map(PathBuf::from),
        sim_npus: args.usize_or("sim-npus", 8)?,
        pool_capacity: match args.usize_or("pool-cap", 0)? {
            0 => crate::parallel::PoolCapacity::Unbounded,
            n => crate::parallel::PoolCapacity::MaxGroups(n),
        },
    };
    log::info!(
        "training {} for {} steps (params from {})",
        cfg.artifact,
        cfg.steps,
        cfg.params_file
    );
    let report = run(&cfg)?;
    println!(
        "trained {} params for {} steps in {:.1}s",
        report.param_count,
        report.records.len(),
        report.total_time_s
    );
    println!(
        "loss: first {:.4} -> last {:.4} (tail-10 mean {:.4})",
        report.first_loss(),
        report.last_loss(),
        report.tail_mean_loss(10)
    );
    let hidden = report
        .records
        .iter()
        .filter(|r| r.schedule_latency_s < r.step_time_s)
        .count();
    println!(
        "scheduling hidden behind compute in {hidden}/{} steps",
        report.records.len()
    );
    Ok(())
}
