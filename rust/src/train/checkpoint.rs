//! Training-state checkpointing: parameters + Adam moments + step
//! counter, in a versioned little-endian binary container with an
//! integrity checksum. The coordinator owns optimizer state (flat
//! vectors), so checkpoints are trivial to stream and resume from.
//!
//! Corrupt restores are a first-class concern: a truncated file, a
//! flipped byte, a foreign format, or an unsupported version must each
//! fail with a descriptive error — never panic, never allocate from an
//! attacker-controlled length, never return garbage moments. The file
//! length is validated against the declared arity BEFORE any payload
//! allocation, so a corrupt header cannot drive an absurd `vec!`.
//!
//! [`CheckpointCostModel`] prices save/restore wall-clock for the
//! resilience simulation ([`crate::session::DhpSession`]'s recovery
//! accounting): rank failures charge one restore plus the lost work
//! since the last checkpoint.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::adam::{Adam, AdamConfig};

/// Container magic (7 bytes) followed by a one-byte format version.
/// Together they reproduce the historical 8-byte `DHPCKPT1` header, so
/// existing checkpoints load unchanged.
const MAGIC: &[u8; 7] = b"DHPCKPT";
const VERSION: u8 = b'1';

/// Fixed header size: magic+version (8) + n (8) + step (8) + checksum (8).
const HEADER_BYTES: u64 = 32;

/// Cost model for checkpoint save/restore wall-clock, used by the
/// session's recovery accounting (the simulated runs never write real
/// multi-gigabyte state; the *time* is what goodput accounting needs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CheckpointCostModel {
    /// Bytes of training state: f32 master params + both Adam moments.
    pub state_bytes: f64,
    /// Aggregate write bandwidth to checkpoint storage (bytes/s).
    pub write_bw: f64,
    /// Aggregate read bandwidth from checkpoint storage (bytes/s).
    pub read_bw: f64,
    /// Fixed orchestration overhead per restore: process respawn,
    /// collective re-init barrier, dataloader seek.
    pub restart_overhead_s: f64,
}

impl CheckpointCostModel {
    /// Model for `params_b` billion parameters against a striped parallel
    /// filesystem (40 GB/s aggregate both ways, 5 s restart overhead —
    /// the magnitudes MegaScale-class recovery papers report).
    pub fn for_params(params_b: f64) -> Self {
        CheckpointCostModel {
            // f32 master copy + Adam m + Adam v = 12 bytes/parameter.
            state_bytes: params_b * 1e9 * 12.0,
            write_bw: 40e9,
            read_bw: 40e9,
            restart_overhead_s: 5.0,
        }
    }

    /// Wall-clock seconds to write one checkpoint.
    pub fn save_time_s(&self) -> f64 {
        self.state_bytes / self.write_bw
    }

    /// Wall-clock seconds to restore from the latest checkpoint (restart
    /// overhead + state read).
    pub fn restore_time_s(&self) -> f64 {
        self.restart_overhead_s + self.state_bytes / self.read_bw
    }
}

/// A complete resumable training state.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Optimizer step the state was captured at.
    pub step: u64,
    /// Flat parameter vector.
    pub params: Vec<f32>,
    /// Adam first moments.
    pub adam_m: Vec<f32>,
    /// Adam second moments.
    pub adam_v: Vec<f32>,
}

impl Checkpoint {
    /// Capture the current state (optimizer moments are cloned out).
    pub fn capture(step: u64, params: &[f32], opt: &Adam) -> Checkpoint {
        let (m, v) = opt.moments();
        Checkpoint {
            step,
            params: params.to_vec(),
            adam_m: m.to_vec(),
            adam_v: v.to_vec(),
        }
    }

    /// Restore into (params, optimizer). The optimizer is rebuilt with
    /// the given config and the saved moments/step.
    pub fn restore(&self, cfg: AdamConfig) -> (Vec<f32>, Adam) {
        let opt = Adam::from_state(
            cfg,
            self.adam_m.clone(),
            self.adam_v.clone(),
            self.step,
        );
        (self.params.clone(), opt)
    }

    /// FNV-1a over all payload bytes (cheap integrity check).
    fn checksum(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        eat(&self.step.to_le_bytes());
        for xs in [&self.params, &self.adam_m, &self.adam_v] {
            for x in xs.iter() {
                eat(&x.to_le_bytes());
            }
        }
        h
    }

    /// Write the versioned binary container (with checksum) to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        let n = self.params.len();
        anyhow::ensure!(
            self.adam_m.len() == n && self.adam_v.len() == n,
            "inconsistent state arity"
        );
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path)
                .with_context(|| format!("creating {path:?}"))?,
        );
        f.write_all(MAGIC)?;
        f.write_all(&[VERSION])?;
        f.write_all(&(n as u64).to_le_bytes())?;
        f.write_all(&self.step.to_le_bytes())?;
        f.write_all(&self.checksum().to_le_bytes())?;
        for xs in [&self.params, &self.adam_m, &self.adam_v] {
            for x in xs.iter() {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        f.flush()?;
        Ok(())
    }

    /// Read and integrity-check a checkpoint from `path`.
    ///
    /// Every corruption class fails with a descriptive error: wrong
    /// magic, unsupported version, a header/payload length mismatch
    /// (truncation or a corrupt arity field — checked against the real
    /// file size before allocating anything), and payload bit flips
    /// (checksum).
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let file = std::fs::File::open(path)
            .with_context(|| format!("opening {path:?}"))?;
        let file_len = file
            .metadata()
            .with_context(|| format!("stat {path:?}"))?
            .len();
        if file_len < HEADER_BYTES {
            bail!(
                "checkpoint truncated: {file_len} bytes, header needs {HEADER_BYTES}"
            );
        }
        let mut f = std::io::BufReader::new(file);
        let mut header = [0u8; 8];
        f.read_exact(&mut header)?;
        if &header[..7] != MAGIC {
            bail!("not a DHP checkpoint (bad magic)");
        }
        if header[7] != VERSION {
            bail!(
                "unsupported checkpoint version {:?} (this build reads {:?})",
                header[7] as char,
                VERSION as char
            );
        }
        let mut u64buf = [0u8; 8];
        f.read_exact(&mut u64buf)?;
        let n = u64::from_le_bytes(u64buf);
        // Validate the declared arity against the actual file size BEFORE
        // any allocation: 3 f32 vectors of n elements follow the header.
        // This catches truncation, trailing garbage, and a corrupt arity
        // field (which could otherwise demand an absurd allocation).
        let expected = HEADER_BYTES as u128 + 12 * n as u128;
        if file_len as u128 != expected {
            bail!(
                "checkpoint truncated or corrupt: {file_len} bytes on disk, \
                 header declares {n} params ({expected} bytes)"
            );
        }
        let n = n as usize;
        f.read_exact(&mut u64buf)?;
        let step = u64::from_le_bytes(u64buf);
        f.read_exact(&mut u64buf)?;
        let want_sum = u64::from_le_bytes(u64buf);

        let mut read_vec = |n: usize| -> Result<Vec<f32>> {
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes).context("checkpoint payload short")?;
            Ok(bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect())
        };
        let params = read_vec(n)?;
        let adam_m = read_vec(n)?;
        let adam_v = read_vec(n)?;
        let ckpt = Checkpoint {
            step,
            params,
            adam_m,
            adam_v,
        };
        if ckpt.checksum() != want_sum {
            bail!("checkpoint corrupt: checksum mismatch");
        }
        Ok(ckpt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("dhp-ckpt-test-{name}-{}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip_preserves_training_trajectory() {
        // Train a toy quadratic, checkpoint mid-way, resume, and verify
        // the resumed trajectory matches the uninterrupted one exactly.
        let cfg = AdamConfig {
            lr: 0.05,
            grad_clip: 0.0,
            ..Default::default()
        };
        let target = [3.0f32, -1.0, 2.0];
        let grad = |x: &[f32]| -> Vec<f32> {
            x.iter().zip(&target).map(|(xi, ti)| 2.0 * (xi - ti)).collect()
        };

        // Uninterrupted run: 40 steps.
        let mut x_ref = vec![0.0f32; 3];
        let mut opt_ref = Adam::new(3, cfg);
        for _ in 0..40 {
            let g = grad(&x_ref);
            opt_ref.step(&mut x_ref, &g);
        }

        // Interrupted run: 20 steps, save, load, 20 more.
        let mut x = vec![0.0f32; 3];
        let mut opt = Adam::new(3, cfg);
        for _ in 0..20 {
            let g = grad(&x);
            opt.step(&mut x, &g);
        }
        let path = tmpfile("roundtrip");
        Checkpoint::capture(20, &x, &opt).save(&path).unwrap();
        let ckpt = Checkpoint::load(&path).unwrap();
        assert_eq!(ckpt.step, 20);
        let (mut x2, mut opt2) = ckpt.restore(cfg);
        for _ in 0..20 {
            let g = grad(&x2);
            opt2.step(&mut x2, &g);
        }
        assert_eq!(x2, x_ref, "resumed trajectory must be bit-identical");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn corruption_detected() {
        let cfg = AdamConfig::default();
        let opt = Adam::new(4, cfg);
        let ckpt = Checkpoint::capture(7, &[1.0, 2.0, 3.0, 4.0], &opt);
        let path = tmpfile("corrupt");
        ckpt.save(&path).unwrap();
        // Flip one payload byte.
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn bad_magic_rejected() {
        let path = tmpfile("magic");
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxxxxxxxxxxxxxxx").unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");
        let _ = std::fs::remove_file(path);
    }

    /// A small valid on-disk checkpoint to corrupt in the tests below.
    fn saved(name: &str) -> (std::path::PathBuf, Vec<u8>) {
        let opt = Adam::new(4, AdamConfig::default());
        let ckpt = Checkpoint::capture(9, &[1.5, -2.0, 0.25, 8.0], &opt);
        let path = tmpfile(name);
        ckpt.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        (path, bytes)
    }

    #[test]
    fn truncation_anywhere_is_a_descriptive_error() {
        let (path, bytes) = saved("trunc");
        // Cut inside the header, right after it, and mid-payload.
        for cut in [3usize, 17, 31, 32, bytes.len() - 5] {
            std::fs::write(&path, &bytes[..cut]).unwrap();
            let err = Checkpoint::load(&path).unwrap_err().to_string();
            assert!(err.contains("truncated"), "cut at {cut}: {err}");
        }
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn flipped_checksum_byte_is_detected() {
        let (path, mut bytes) = saved("sumflip");
        // Bytes 24..32 hold the stored checksum; flip one bit there. The
        // payload is intact, so only the checksum comparison can catch it.
        bytes[25] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn wrong_version_is_a_descriptive_error() {
        let (path, mut bytes) = saved("version");
        bytes[7] = b'9'; // magic intact, version bumped
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("unsupported checkpoint version"), "{err}");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn absurd_arity_header_does_not_allocate() {
        let (path, mut bytes) = saved("arity");
        // Claim u64::MAX params: must fail on the length check, not OOM.
        bytes[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("truncated or corrupt"), "{err}");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn trailing_garbage_rejected() {
        let (path, mut bytes) = saved("trailing");
        bytes.extend_from_slice(&[0xAB; 7]);
        std::fs::write(&path, &bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn cost_model_scales_with_params() {
        let small = CheckpointCostModel::for_params(2.0);
        let big = CheckpointCostModel::for_params(8.0);
        assert!(big.save_time_s() > small.save_time_s());
        assert!(big.restore_time_s() > small.restore_time_s());
        // Restore always pays the restart overhead on top of the read.
        assert!(big.restore_time_s() > big.save_time_s());
        // Sanity magnitude: 8B params = 96 GB at 40 GB/s ≈ 2.4 s write.
        assert!((big.save_time_s() - 2.4).abs() < 0.1);
    }
}
