//! Training-state checkpointing: parameters + Adam moments + step
//! counter, in a versioned little-endian binary container with an
//! integrity checksum. The coordinator owns optimizer state (flat
//! vectors), so checkpoints are trivial to stream and resume from.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::adam::{Adam, AdamConfig};

const MAGIC: &[u8; 8] = b"DHPCKPT1";

/// A complete resumable training state.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Optimizer step the state was captured at.
    pub step: u64,
    /// Flat parameter vector.
    pub params: Vec<f32>,
    /// Adam first moments.
    pub adam_m: Vec<f32>,
    /// Adam second moments.
    pub adam_v: Vec<f32>,
}

impl Checkpoint {
    /// Capture the current state (optimizer moments are cloned out).
    pub fn capture(step: u64, params: &[f32], opt: &Adam) -> Checkpoint {
        let (m, v) = opt.moments();
        Checkpoint {
            step,
            params: params.to_vec(),
            adam_m: m.to_vec(),
            adam_v: v.to_vec(),
        }
    }

    /// Restore into (params, optimizer). The optimizer is rebuilt with
    /// the given config and the saved moments/step.
    pub fn restore(&self, cfg: AdamConfig) -> (Vec<f32>, Adam) {
        let opt = Adam::from_state(
            cfg,
            self.adam_m.clone(),
            self.adam_v.clone(),
            self.step,
        );
        (self.params.clone(), opt)
    }

    /// FNV-1a over all payload bytes (cheap integrity check).
    fn checksum(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        eat(&self.step.to_le_bytes());
        for xs in [&self.params, &self.adam_m, &self.adam_v] {
            for x in xs.iter() {
                eat(&x.to_le_bytes());
            }
        }
        h
    }

    /// Write the versioned binary container (with checksum) to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        let n = self.params.len();
        anyhow::ensure!(
            self.adam_m.len() == n && self.adam_v.len() == n,
            "inconsistent state arity"
        );
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path)
                .with_context(|| format!("creating {path:?}"))?,
        );
        f.write_all(MAGIC)?;
        f.write_all(&(n as u64).to_le_bytes())?;
        f.write_all(&self.step.to_le_bytes())?;
        f.write_all(&self.checksum().to_le_bytes())?;
        for xs in [&self.params, &self.adam_m, &self.adam_v] {
            for x in xs.iter() {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        f.flush()?;
        Ok(())
    }

    /// Read and integrity-check a checkpoint from `path`.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path)
                .with_context(|| format!("opening {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a DHP checkpoint (bad magic)");
        }
        let mut u64buf = [0u8; 8];
        f.read_exact(&mut u64buf)?;
        let n = u64::from_le_bytes(u64buf) as usize;
        f.read_exact(&mut u64buf)?;
        let step = u64::from_le_bytes(u64buf);
        f.read_exact(&mut u64buf)?;
        let want_sum = u64::from_le_bytes(u64buf);

        let mut read_vec = |n: usize| -> Result<Vec<f32>> {
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)?;
            Ok(bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect())
        };
        let params = read_vec(n)?;
        let adam_m = read_vec(n)?;
        let adam_v = read_vec(n)?;
        let ckpt = Checkpoint {
            step,
            params,
            adam_m,
            adam_v,
        };
        if ckpt.checksum() != want_sum {
            bail!("checkpoint corrupt: checksum mismatch");
        }
        Ok(ckpt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("dhp-ckpt-test-{name}-{}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip_preserves_training_trajectory() {
        // Train a toy quadratic, checkpoint mid-way, resume, and verify
        // the resumed trajectory matches the uninterrupted one exactly.
        let cfg = AdamConfig {
            lr: 0.05,
            grad_clip: 0.0,
            ..Default::default()
        };
        let target = [3.0f32, -1.0, 2.0];
        let grad = |x: &[f32]| -> Vec<f32> {
            x.iter().zip(&target).map(|(xi, ti)| 2.0 * (xi - ti)).collect()
        };

        // Uninterrupted run: 40 steps.
        let mut x_ref = vec![0.0f32; 3];
        let mut opt_ref = Adam::new(3, cfg);
        for _ in 0..40 {
            let g = grad(&x_ref);
            opt_ref.step(&mut x_ref, &g);
        }

        // Interrupted run: 20 steps, save, load, 20 more.
        let mut x = vec![0.0f32; 3];
        let mut opt = Adam::new(3, cfg);
        for _ in 0..20 {
            let g = grad(&x);
            opt.step(&mut x, &g);
        }
        let path = tmpfile("roundtrip");
        Checkpoint::capture(20, &x, &opt).save(&path).unwrap();
        let ckpt = Checkpoint::load(&path).unwrap();
        assert_eq!(ckpt.step, 20);
        let (mut x2, mut opt2) = ckpt.restore(cfg);
        for _ in 0..20 {
            let g = grad(&x2);
            opt2.step(&mut x2, &g);
        }
        assert_eq!(x2, x_ref, "resumed trajectory must be bit-identical");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn corruption_detected() {
        let cfg = AdamConfig::default();
        let opt = Adam::new(4, cfg);
        let ckpt = Checkpoint::capture(7, &[1.0, 2.0, 3.0, 4.0], &opt);
        let path = tmpfile("corrupt");
        ckpt.save(&path).unwrap();
        // Flip one payload byte.
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn bad_magic_rejected() {
        let path = tmpfile("magic");
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxxxxxxx").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        let _ = std::fs::remove_file(path);
    }
}
