//! Adam optimizer over the flat f32 parameter vector.
//!
//! Layer 3 owns optimizer state (the AOT artifact returns raw gradients) —
//! this keeps the PJRT artifact signature trivial and puts the optimizer
//! where the coordinator can shard/offload it.

/// Adam hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct AdamConfig {
    /// Learning rate.
    pub lr: f32,
    /// First-moment decay.
    pub beta1: f32,
    /// Second-moment decay.
    pub beta2: f32,
    /// Denominator epsilon.
    pub eps: f32,
    /// Decoupled weight decay (AdamW-style; 0 = off).
    pub weight_decay: f32,
    /// Global-norm gradient clipping (0 = off).
    pub grad_clip: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig {
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            grad_clip: 1.0,
        }
    }
}

/// Optimizer state (first/second moments + step count).
#[derive(Debug, Clone)]
pub struct Adam {
    /// Hyper-parameters the optimizer was built with.
    pub cfg: AdamConfig,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    /// Fresh optimizer state over `param_count` parameters.
    pub fn new(param_count: usize, cfg: AdamConfig) -> Self {
        Adam {
            cfg,
            m: vec![0.0; param_count],
            v: vec![0.0; param_count],
            t: 0,
        }
    }

    /// Optimizer steps applied so far.
    pub fn steps_taken(&self) -> u64 {
        self.t
    }

    /// Borrow the first/second moment vectors (checkpointing).
    pub fn moments(&self) -> (&[f32], &[f32]) {
        (&self.m, &self.v)
    }

    /// Rebuild an optimizer from checkpointed state.
    pub fn from_state(cfg: AdamConfig, m: Vec<f32>, v: Vec<f32>, t: u64) -> Adam {
        assert_eq!(m.len(), v.len());
        Adam { cfg, m, v, t }
    }

    /// Global L2 norm of a gradient vector.
    pub fn grad_norm(grads: &[f32]) -> f32 {
        grads.iter().map(|g| (*g as f64) * (*g as f64)).sum::<f64>().sqrt() as f32
    }

    /// One optimizer step, in place. Returns the (pre-clip) grad norm.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) -> f32 {
        assert_eq!(params.len(), self.m.len(), "param arity");
        assert_eq!(grads.len(), self.m.len(), "grad arity");
        self.t += 1;
        let c = self.cfg;
        let norm = Self::grad_norm(grads);
        let scale = if c.grad_clip > 0.0 && norm > c.grad_clip {
            c.grad_clip / norm
        } else {
            1.0
        };
        // Bias corrections hoisted out of the loop.
        let bc1 = 1.0 - c.beta1.powi(self.t as i32);
        let bc2 = 1.0 - c.beta2.powi(self.t as i32);
        let lr_t = c.lr * bc2.sqrt() / bc1;
        // Zip-based loop: no bounds checks, auto-vectorizes (the §Perf
        // pass measured ~4× over the naive indexed loop at 100M params).
        let (b1, b2, wd, eps) = (c.beta1, c.beta2, c.weight_decay, c.eps);
        for ((p, &gr), (m, v)) in params
            .iter_mut()
            .zip(grads.iter())
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            let g = gr * scale + wd * *p;
            let m_new = b1 * *m + (1.0 - b1) * g;
            let v_new = b2 * *v + (1.0 - b2) * g * g;
            *m = m_new;
            *v = v_new;
            *p -= lr_t * m_new / (v_new.sqrt() + eps);
        }
        norm
    }
}

/// Average several gradient vectors in place into the first one — the
/// coordinator-side DP gradient reduction for multi-group steps.
pub fn average_grads(acc: &mut [f32], others: &[Vec<f32>]) {
    let n = (others.len() + 1) as f32;
    for other in others {
        assert_eq!(other.len(), acc.len());
    }
    for i in 0..acc.len() {
        let mut s = acc[i];
        for other in others {
            s += other[i];
        }
        acc[i] = s / n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // f(x) = Σ (x_i − target_i)², gradient 2(x − target).
        let target = [3.0f32, -2.0, 0.5, 10.0];
        let mut x = vec![0.0f32; 4];
        let mut opt = Adam::new(
            4,
            AdamConfig {
                lr: 0.05,
                grad_clip: 0.0,
                ..Default::default()
            },
        );
        for _ in 0..2000 {
            let grads: Vec<f32> =
                x.iter().zip(&target).map(|(xi, ti)| 2.0 * (xi - ti)).collect();
            opt.step(&mut x, &grads);
        }
        for (xi, ti) in x.iter().zip(&target) {
            assert!((xi - ti).abs() < 0.05, "{xi} vs {ti}");
        }
    }

    #[test]
    fn grad_clip_bounds_update() {
        let mut x = vec![0.0f32; 2];
        let mut opt = Adam::new(2, AdamConfig::default()); // clip = 1.0
        let norm = opt.step(&mut x, &[1e6, 1e6]);
        assert!(norm > 1e5);
        // First-step Adam update magnitude is ≤ lr regardless of raw grad.
        assert!(x.iter().all(|&v| v.abs() <= opt.cfg.lr * 1.01), "{x:?}");
    }

    #[test]
    fn step_count_and_determinism() {
        let mut a = Adam::new(3, AdamConfig::default());
        let mut b = Adam::new(3, AdamConfig::default());
        let mut xa = vec![1.0f32, 2.0, 3.0];
        let mut xb = xa.clone();
        for _ in 0..5 {
            a.step(&mut xa, &[0.1, -0.2, 0.3]);
            b.step(&mut xb, &[0.1, -0.2, 0.3]);
        }
        assert_eq!(xa, xb);
        assert_eq!(a.steps_taken(), 5);
    }

    #[test]
    fn average_grads_means() {
        let mut a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 4.0];
        let c = vec![5.0f32, 6.0];
        average_grads(&mut a, &[b, c]);
        assert_eq!(a, vec![3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        let mut opt = Adam::new(2, AdamConfig::default());
        let mut x = vec![0.0f32; 3];
        opt.step(&mut x, &[0.0, 0.0, 0.0]);
    }
}
