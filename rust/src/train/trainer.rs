//! The REAL training loop: Rust drives the AOT-compiled JAX MLLM through
//! PJRT, owns Adam, and runs the DHP scheduler asynchronously alongside —
//! every layer of the stack composes here (L1 Pallas kernel inside the L2
//! HLO, executed by the L3 coordinator).
//!
//! Semantics: each optimizer step draws a micro-batch from the synthetic
//! corpus, the [`DhpSession`] schedules it onto the (simulated) cluster
//! while the *previous* step's gradients are being computed for real on
//! the PJRT CPU device (the paper's producer–consumer overlap, via
//! [`DhpSession::prefetch`] + [`DhpSession::step_prefetched`]), gradients
//! are reduced and Adam applied. The loss curve goes to EXPERIMENTS.md
//! §E2E.

use std::io::Write as _;
use std::path::PathBuf;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::cluster::ClusterSim;
use crate::config::presets::by_name;
use crate::config::{ClusterConfig, TrainStage};
use crate::cost::{CostCoeffs, CostModel, HardwareSpec, MemoryModel};
use crate::data::corpus::CorpusGenerator;
use crate::data::sequence::Sequence;
use crate::parallel::mesh::DeviceMesh;
use crate::runtime::{load_params, Runtime};
use crate::scheduler::Scheduler;
use crate::session::DhpSession;

use super::adam::{Adam, AdamConfig};

/// Configuration of a real training run.
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    /// Directory holding the AOT artifacts + manifest.
    pub artifacts_dir: PathBuf,
    /// grad_step artifact file name (e.g. "e2e_grad.hlo.txt").
    pub artifact: String,
    /// params blob file name (e.g. "e2e_params.f32").
    pub params_file: String,
    /// Optimizer steps to run.
    pub steps: usize,
    /// Adam hyperparameters.
    pub adam: AdamConfig,
    /// Synthetic-corpus sampling seed.
    pub seed: u64,
    /// Optional per-step CSV log (see the header row written in
    /// [`run`] for the column list).
    pub log_path: Option<PathBuf>,
    /// Simulated cluster size the async scheduler plans for.
    pub sim_npus: usize,
    /// Budget for the session's communication-group pool (unbounded by
    /// default — cap it to model a device that cannot keep every
    /// communicator established; evictions then show up in the per-step
    /// CSV).
    pub pool_capacity: crate::parallel::PoolCapacity,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            artifact: "e2e_grad.hlo.txt".into(),
            params_file: "e2e_params.f32".into(),
            steps: 200,
            adam: AdamConfig {
                lr: 3e-4,
                ..Default::default()
            },
            seed: 0xE2E,
            log_path: None,
            sim_npus: 8,
            pool_capacity: crate::parallel::PoolCapacity::Unbounded,
        }
    }
}

/// Per-step record.
#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    /// Optimizer step index.
    pub step: usize,
    /// Training loss of the step.
    pub loss: f32,
    /// Global gradient L2 norm.
    pub grad_norm: f32,
    /// Real wall-clock of the PJRT execution + optimizer.
    pub step_time_s: f64,
    /// Simulated cluster makespan for the DHP plan of this batch.
    pub sim_makespan_s: f64,
    /// Background scheduling latency (hidden behind compute).
    pub schedule_latency_s: f64,
    /// Pure solver wall time (packing + DP + placement), measured on the
    /// scheduling thread — the paper's "millisecond-level scheduling
    /// overhead" number, excluding queueing and group prewarm.
    pub solver_time_s: f64,
    /// FULLY-SERIAL simulated group-creation time the session paid
    /// prewarming this step's communication groups.
    pub reconfig_serial_s: f64,
    /// Overlap-aware charge: the creation time NOT hidden behind the
    /// previous step's real COMPUTE span (PJRT execution + optimizer,
    /// excluding time spent waiting on the scheduler),
    /// `max(0, serial − prev_compute)`. ~0 once the pool is warm or
    /// compute is long enough to hide misses.
    pub reconfig_charged_s: f64,
    /// Fraction of this step's groups that replayed the previous step's
    /// rank blocks (hint-quality telemetry).
    pub replay_rate: f64,
    /// Groups evicted from the (capacity-capped) session pool during
    /// this step — 0 on the default unbounded pool.
    pub pool_evictions: u64,
    /// Cumulative communication-group pool hit-rate after this step.
    pub pool_hit_rate: f64,
    /// Micro-batches served from the exact-hit schedule cache
    /// ([`dhp::scheduler::schedule_cache`]). The CSV's `solve_cache`
    /// column renders this with the other reuse counters as
    /// `hits:warms:fasts`.
    pub solve_cache_hits: usize,
    /// Micro-batches whose outer search ran warm-started (incumbent
    /// seeded by the re-costed previous plan).
    pub solve_warm_starts: usize,
    /// Micro-batches that took the opt-in ε fast path (always 0 under
    /// the trainer's default exact configuration).
    pub solve_fast_paths: usize,
    /// Mean pruned-candidate fraction over the micro-batches whose
    /// search actually ran — the CSV's `solve_pruned_frac` column.
    pub solve_pruned_frac: f64,
}

/// Full run report.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Per-step records in step order.
    pub records: Vec<StepRecord>,
    /// Trainable parameter count of the loaded model.
    pub param_count: usize,
    /// Wall-clock of the whole run.
    pub total_time_s: f64,
}

impl TrainReport {
    /// Loss of the first step (NaN for an empty run).
    pub fn first_loss(&self) -> f32 {
        self.records.first().map(|r| r.loss).unwrap_or(f32::NAN)
    }

    /// Loss of the last step (NaN for an empty run).
    pub fn last_loss(&self) -> f32 {
        self.records.last().map(|r| r.loss).unwrap_or(f32::NAN)
    }

    /// Mean loss over the final `n` steps (noise-robust convergence check).
    pub fn tail_mean_loss(&self, n: usize) -> f32 {
        let tail: Vec<f32> = self
            .records
            .iter()
            .rev()
            .take(n)
            .map(|r| r.loss)
            .collect();
        if tail.is_empty() {
            return f32::NAN;
        }
        tail.iter().sum::<f32>() / tail.len() as f32
    }
}

/// Run real training per `cfg`. See module docs for semantics.
pub fn run(cfg: &TrainerConfig) -> Result<TrainReport> {
    anyhow::ensure!(cfg.steps >= 1, "--steps must be >= 1");
    let t_start = Instant::now();
    let rt = Runtime::cpu()?;
    let model = rt.load(&cfg.artifacts_dir, &cfg.artifact)?;
    let meta = model.meta.clone();
    let mut params = load_params(&cfg.artifacts_dir.join(&cfg.params_file))
        .context("loading initial params")?;
    anyhow::ensure!(
        params.len() == meta.param_count,
        "params blob {} != artifact {}",
        params.len(),
        meta.param_count
    );
    let mut opt = Adam::new(params.len(), cfg.adam);
    let mut corpus = CorpusGenerator::new(meta.vocab, meta.patch_dim, cfg.seed);

    // Async DHP scheduling over a simulated cluster, one step ahead —
    // the whole lifecycle (pipeline + pool + simulator) behind one
    // session. `warm_start(false)`: a real launch surfaces step 0's
    // group-creation cost instead of hiding it pre-stream.
    let preset = by_name("InternVL3-2B").unwrap();
    let cluster = ClusterConfig::default().with_npus(cfg.sim_npus);
    let hw = HardwareSpec::default();
    let cost = CostModel {
        coeffs: CostCoeffs::analytic(&preset, TrainStage::Full, &hw),
        memory: MemoryModel {
            e_bytes: 8192.0 * preset.act_bytes_per_token() + 2e9,
            m_states: 2e9,
            m_token: preset.act_bytes_per_token(),
        },
    };
    let scheduler = Scheduler::new(cost, DeviceMesh::new(&cluster));
    let sim = ClusterSim::new(preset, TrainStage::Full, cluster.clone());
    let mut session = DhpSession::builder(Box::new(scheduler), sim)
        .pool_capacity(cfg.pool_capacity)
        .group_buffer_bytes(cluster.group_buffer_bytes)
        .pipeline_depth(2)
        .warm_start(false)
        .build();

    // Scheduling view of a batch: B sequences of (Lv vision + Lt text).
    let batch_seqs = |step: usize| -> Vec<Sequence> {
        (0..meta.batch)
            .map(|i| {
                Sequence::new(
                    (step * meta.batch + i) as u64,
                    meta.seq_vision as u64,
                    meta.seq_text as u64,
                )
            })
            .collect()
    };

    let mut log_file = match &cfg.log_path {
        Some(p) => {
            let mut f = std::fs::File::create(p)
                .with_context(|| format!("creating log {p:?}"))?;
            writeln!(
                f,
                "step,loss,grad_norm,step_s,sim_makespan_s,sched_latency_s,\
                 solver_time_s,reconfig_serial_s,reconfig_charged_s,\
                 replay_rate,pool_evictions,pool_hit_rate,solve_cache,\
                 solve_pruned_frac"
            )?;
            Some(f)
        }
        None => None,
    };

    // Prime the session with step 0's plan.
    session.prefetch(&batch_seqs(0));

    let mut records = Vec::with_capacity(cfg.steps);
    // Overlap budget for step t's group prewarm: the prepare ran while
    // step t−1 COMPUTED (PJRT execution + optimizer). Only that compute
    // span hides creation — the blocking schedule wait inside
    // `step_prefetched` is time spent waiting on the scheduler itself,
    // so counting it as slack would report reconfiguration as hidden
    // precisely when the run is scheduling-bound. Step 0's prepare
    // overlapped nothing.
    let mut prev_compute_s = 0.0f64;
    for step in 0..cfg.steps {
        let t0 = Instant::now();
        // Pipeline ahead: prefetch step+1 before computing step.
        if step + 1 < cfg.steps {
            session.prefetch(&batch_seqs(step + 1));
        }
        let (vis, tok, tgt) = corpus.sample_flat_batch(
            meta.batch,
            meta.seq_vision,
            meta.seq_text,
        );
        // REAL compute: PJRT execution of the AOT HLO (L1+L2 inside).
        let out = model.grad_step(&params, &vis, &tok, &tgt)?;
        let grad_norm = opt.step(&mut params, &out.grads);
        // Compute-only span: the prewarm-overlap budget for the NEXT
        // step (measured before step_prefetched starts waiting).
        let compute_s = t0.elapsed().as_secs_f64();
        // Collect this step's (already computed) schedule, prewarm its
        // groups through the session pool, and execute it on the
        // simulated cluster — charged max(0, serial − prev compute).
        let report = session
            .step_prefetched(prev_compute_s)
            .context("scheduler pipeline closed")?;
        let step_time_s = t0.elapsed().as_secs_f64();
        let rec = StepRecord {
            step,
            loss: out.loss,
            grad_norm,
            step_time_s,
            sim_makespan_s: report.iteration.exec_time_s,
            schedule_latency_s: report.schedule_latency_s,
            solver_time_s: report.solver_time_s,
            reconfig_serial_s: report.iteration.reconfig_serial_s,
            reconfig_charged_s: report.iteration.reconfig_time_s,
            replay_rate: report.replay_rate,
            pool_evictions: report.evictions,
            pool_hit_rate: report.pool.hit_rate(),
            solve_cache_hits: report.solve_cache_hits,
            solve_warm_starts: report.solve_warm_starts,
            solve_fast_paths: report.solve_fast_paths,
            solve_pruned_frac: report.solve_pruned_frac,
        };
        prev_compute_s = compute_s;
        if let Some(f) = log_file.as_mut() {
            writeln!(
                f,
                "{},{:.6},{:.4},{:.4},{:.6},{:.6},{:.6},{:.6},{:.6},{:.4},{},{:.4},{}:{}:{},{:.4}",
                rec.step,
                rec.loss,
                rec.grad_norm,
                rec.step_time_s,
                rec.sim_makespan_s,
                rec.schedule_latency_s,
                rec.solver_time_s,
                rec.reconfig_serial_s,
                rec.reconfig_charged_s,
                rec.replay_rate,
                rec.pool_evictions,
                rec.pool_hit_rate,
                rec.solve_cache_hits,
                rec.solve_warm_starts,
                rec.solve_fast_paths,
                rec.solve_pruned_frac
            )?;
        }
        if step % 10 == 0 || step + 1 == cfg.steps {
            log::info!(
                "step {step:4}  loss {:.4}  |g| {:.3}  {:.2}s/step",
                rec.loss,
                rec.grad_norm,
                rec.step_time_s
            );
        }
        records.push(rec);
    }
    session.shutdown();
    Ok(TrainReport {
        records,
        param_count: params.len(),
        total_time_s: t_start.elapsed().as_secs_f64(),
    })
}
