//! Report rendering (paper-style tables) and the `dhp` CLI dispatcher.

use anyhow::{bail, Result};

use crate::util::cli::Args;

/// Fixed-width table printer for paper-style console reports.
pub struct Table {
    /// Title printed above the table.
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Row cells (each row's arity must match the headers).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with the given title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (panics if the arity differs from the headers).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    /// Render to an aligned fixed-width string.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render and print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

const USAGE: &str = "\
dhp — Dynamic Hybrid Parallelism for MLLM training (paper reproduction)

USAGE:
    dhp <COMMAND> [OPTIONS]

COMMANDS:
    reproduce <exp>   Regenerate a paper artifact: fig1 fig2 fig4 fig5 fig6
                      tab1 tab2 tab3 tab4 resilience cluster_day, or `all`
    models            Print the Table 5 model presets
    schedule          Run the scheduler once on a sampled batch and print
                      the plan (options: --dataset --npus --gbs --seed)
    train             Real e2e training via PJRT artifacts
                      (options: --steps --artifacts <dir> --log <file>
                       --pool-cap <groups, 0 = unbounded>)
    help              Show this help

OPTIONS (common):
    --dataset <msrvtt|internvid|openvid>
    --model <Table-5 name, e.g. InternVL3-8B>
    --npus <n>            total NPUs (default 64)
    --gbs <n>             global batch size (default 512)
    --seed <n>
    --out <file>          also write a JSON report
";

/// CLI entry point used by `main.rs`.
pub fn run_cli(args: Args) -> Result<()> {
    match args.command.as_deref() {
        None | Some("help") => {
            print!("{USAGE}");
            Ok(())
        }
        Some("models") => {
            let mut t = Table::new(
                "Table 5: models for evaluation",
                &["Model", "#Layers", "#Heads", "#Groups", "Hidden", "VisionHidden"],
            );
            for p in crate::config::presets::PRESETS.iter() {
                t.row(vec![
                    p.name.to_string(),
                    p.layers.to_string(),
                    p.heads.to_string(),
                    p.kv_groups.to_string(),
                    p.hidden.to_string(),
                    p.vision_hidden.to_string(),
                ]);
            }
            t.print();
            Ok(())
        }
        Some("schedule") => crate::experiments::schedule_cmd(&args),
        Some("reproduce") => crate::experiments::reproduce(&args),
        Some("train") => crate::train::train_cmd(&args),
        Some(other) => bail!("unknown command {other:?} — try `dhp help`"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "bbbb"]);
        t.row(vec!["xx".into(), "y".into()]);
        t.row(vec!["1".into(), "22222".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("a   bbbb"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 6);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
