//! Persistent work-stealing worker pool for the outer search (ISSUE-7).
//!
//! The seed's `plan_search` spawned a fresh `std::thread::scope` per
//! solve — a ~50–100 µs tax paid on every micro-batch, which dominates
//! the solver's budget once the DP itself is near-linear. This module
//! replaces it with a pool of long-lived workers that candidate solves
//! are *submitted* to:
//!
//! * Workers block on one shared job queue. A submission sends the job's
//!   `Arc` once per requested helper, then the **submitting thread joins
//!   the search itself** — it is always the (helpers + 1)-th participant,
//!   so a solve makes progress even if every pooled worker is busy with
//!   another scheduler's job (or the pool has zero workers).
//! * Work-stealing is candidate-index stealing: participants claim
//!   indices off the job's shared `fetch_add` counter, exactly the
//!   seed's queue discipline, so the incumbent-pruned, `(est, index)`-
//!   selected result is bit-identical to the scoped-thread search and to
//!   the sequential first-wins reference (see the module docs in
//!   [`super`]).
//! * Completion is tracked per *candidate*, not per participant: each
//!   participant decrements the job's pending count by the indices it
//!   claimed, so stray job handles still queued when the search drains
//!   are harmless — a late worker claims nothing, decrements nothing,
//!   and moves on.
//!
//! [`crate::scheduler::pipeline::SchedulePipeline`] owns one pool per
//! scheduling thread and attaches it to its policy
//! ([`crate::baselines::SchedulePolicy::attach_search_pool`]), so a
//! session's steady-state `step()` never spawns a thread. Bare
//! `Scheduler::schedule` callers (benches, tests) fall back to a
//! process-global pool — lazily created once, then reused — so the
//! per-solve spawn tax is gone on every path.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

use crate::data::sequence::Sequence;

use super::scratch::solver_threads;
use super::{Candidate, Draft, FabricModel, Scheduler, SolverScratch};

/// One submitted outer search: everything a participant needs to claim
/// and solve candidates, owned (cloned/moved in) so worker threads need
/// no borrowed lifetimes. The `Scheduler` clone is cheap — it shares the
/// placement-hint `Arc` — and `plan_search` never touches the hint, so
/// solving through the clone is bit-identical to solving through the
/// original.
struct SearchJob {
    sch: Scheduler,
    seqs: Vec<Sequence>,
    fabric: FabricModel,
    model_fp: u64,
    candidates: Vec<Candidate>,
    /// Shared claim counter — the work-stealing queue head.
    next: AtomicUsize,
    /// Incumbent best estimate as f64 bits (non-negative IEEE-754 floats
    /// order identically to their bit patterns).
    incumbent: AtomicU64,
    state: Mutex<JobState>,
    done: Condvar,
}

struct JobState {
    /// Candidates not yet claimed-and-processed. 0 ⇒ search complete.
    pending: usize,
    results: Vec<(usize, Draft)>,
}

impl SearchJob {
    /// Claim-and-solve until the index counter drains, then fold this
    /// participant's results and claim count into the job state. Run by
    /// pooled workers and by the submitting thread alike.
    fn run(&self) {
        let fabric_fp = self.fabric.fingerprint();
        let total = self.candidates.len();
        let mut scratch = SolverScratch::acquire();
        let mut local: Vec<(usize, Draft)> = Vec::new();
        let mut claimed = 0usize;
        loop {
            let ci = self.next.fetch_add(1, Ordering::Relaxed);
            if ci >= total {
                break;
            }
            claimed += 1;
            let bound = f64::from_bits(self.incumbent.load(Ordering::Relaxed));
            if let Some(draft) = self.sch.solve_candidate(
                &self.seqs,
                &self.candidates,
                ci,
                &self.fabric,
                self.model_fp,
                fabric_fp,
                bound,
                &mut scratch,
            ) {
                self.incumbent
                    .fetch_min(draft.est_time_s.to_bits(), Ordering::Relaxed);
                local.push((ci, draft));
            }
        }
        scratch.release();
        if claimed > 0 {
            let mut state = self.state.lock().unwrap_or_else(|e| e.into_inner());
            state.results.append(&mut local);
            state.pending -= claimed;
            if state.pending == 0 {
                self.done.notify_all();
            }
        }
    }
}

/// A pool of persistent search workers (see module docs). Dropping the
/// pool closes the queue and joins every worker.
#[derive(Debug)]
pub struct SearchPool {
    tx: Mutex<Option<Sender<Arc<SearchJob>>>>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    workers: usize,
    /// Threads spawned over this pool's lifetime — exactly `workers`,
    /// all at construction. The zero-spawn acceptance test snapshots
    /// this across steps.
    spawned: AtomicUsize,
}

impl SearchPool {
    /// Spawn a pool of `workers` persistent search threads (0 is valid:
    /// submissions then run entirely on the submitting thread).
    pub fn new(workers: usize) -> Self {
        let (tx, rx) = channel::<Arc<SearchJob>>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let rx = Arc::clone(&rx);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("dhp-search-{w}"))
                    .spawn(move || loop {
                        // Hold the lock through the blocking recv: the
                        // standard shared-receiver handoff — the waiting
                        // worker takes the job, releases, and the next
                        // worker moves up to wait.
                        let job = {
                            let guard =
                                rx.lock().unwrap_or_else(|e| e.into_inner());
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job.run(),
                            Err(_) => break, // queue closed: pool dropped
                        }
                    })
                    .expect("failed to spawn dhp-search worker"),
            );
        }
        SearchPool {
            tx: Mutex::new(Some(tx)),
            handles: Mutex::new(handles),
            workers,
            spawned: AtomicUsize::new(workers),
        }
    }

    /// Pool sized for `plan_search`'s historical parallelism: the
    /// submitter plus `solver_threads() − 1` helpers.
    pub fn with_default_size() -> Self {
        SearchPool::new(solver_threads().saturating_sub(1))
    }

    /// The process-global fallback pool, created on first use. Bare
    /// `Scheduler::schedule` calls without an attached pool (benches,
    /// tests, one-off CLI solves) share it, so even they stop paying the
    /// per-solve spawn tax after the very first solve.
    pub fn global() -> &'static Arc<SearchPool> {
        static GLOBAL: OnceLock<Arc<SearchPool>> = OnceLock::new();
        GLOBAL.get_or_init(|| Arc::new(SearchPool::with_default_size()))
    }

    /// Number of persistent workers.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Total threads ever spawned by this pool (== `workers()`; the pool
    /// never re-spawns). A steady-state session asserts this constant
    /// across steps.
    pub fn threads_spawned(&self) -> usize {
        self.spawned.load(Ordering::Relaxed)
    }

    /// Run one outer search through the pool: enqueue the job for up to
    /// `helpers` workers, participate from the calling thread, and block
    /// until every candidate is claimed and processed. Returns the
    /// per-candidate drafts exactly as the scoped-thread search did.
    ///
    /// `seed_bits` initializes the incumbent — `f64::INFINITY.to_bits()`
    /// for a cold search, or a warm-start upper bound's bits (the
    /// re-costed previous plan, see
    /// [`crate::scheduler::schedule_cache`]). Because the seed is a
    /// feasible solution's cost, the strict-`>` pruning stays sound;
    /// `plan_search`'s acceptance guard keeps the final selection
    /// bit-identical to the cold search.
    #[allow(clippy::too_many_arguments)]
    pub(in crate::scheduler) fn search(
        &self,
        sch: &Scheduler,
        seqs: &[Sequence],
        fabric: &FabricModel,
        model_fp: u64,
        candidates: Vec<Candidate>,
        helpers: usize,
        seed_bits: u64,
    ) -> Vec<(usize, Draft)> {
        let total = candidates.len();
        if total == 0 {
            return Vec::new();
        }
        let job = Arc::new(SearchJob {
            sch: sch.clone(),
            seqs: seqs.to_vec(),
            fabric: fabric.clone(),
            model_fp,
            candidates,
            next: AtomicUsize::new(0),
            incumbent: AtomicU64::new(seed_bits),
            state: Mutex::new(JobState {
                pending: total,
                results: Vec::with_capacity(total),
            }),
            done: Condvar::new(),
        });
        let helpers = helpers.min(self.workers).min(total.saturating_sub(1));
        if helpers > 0 {
            let tx = self.tx.lock().unwrap_or_else(|e| e.into_inner());
            if let Some(tx) = tx.as_ref() {
                for _ in 0..helpers {
                    if tx.send(Arc::clone(&job)).is_err() {
                        break;
                    }
                }
            }
        }
        job.run();
        let mut state = job.state.lock().unwrap_or_else(|e| e.into_inner());
        while state.pending > 0 {
            state = job.done.wait(state).unwrap_or_else(|e| e.into_inner());
        }
        std::mem::take(&mut state.results)
    }
}

impl Drop for SearchPool {
    fn drop(&mut self) {
        // Closing the sender unblocks every worker's recv with an error.
        self.tx.lock().unwrap_or_else(|e| e.into_inner()).take();
        let handles =
            std::mem::take(&mut *self.handles.lock().unwrap_or_else(|e| e.into_inner()));
        for h in handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_worker_pool_runs_on_the_submitter() {
        let pool = SearchPool::new(0);
        assert_eq!(pool.workers(), 0);
        assert_eq!(pool.threads_spawned(), 0);
        // No job to submit here — `search` needs a Scheduler; the
        // scheduler tests cover submission. This guards the degenerate
        // construction and the clean drop path.
    }

    #[test]
    fn pool_spawns_exactly_once_and_joins_on_drop() {
        let pool = SearchPool::new(3);
        assert_eq!(pool.workers(), 3);
        assert_eq!(pool.threads_spawned(), 3);
        drop(pool); // must not hang: sender closes, workers exit
    }
}
