//! The solver's bandwidth oracle (the fabric-aware objective).
//!
//! Every cost query the solver makes — the DP's per-transition
//! `T(G, d, bw)` evaluations, the outer search's incumbent pruning
//! bounds, the uniform-grid anchors — needs a bandwidth for each
//! candidate degree. The seed answered with a *uniform-fabric heuristic*
//! ("a degree that fits within one node is intra-node"), which is exact
//! on an empty mesh but optimistic on a fragmented one: when concurrent
//! jobs (or earlier waves) hold slots, a degree that nominally fits a
//! node may have no node with that many free slots left, and the placed
//! group rides the slow inter-node fabric the search never priced in.
//! The search can then crown a candidate that loses after placement —
//! exactly the failure mode FlexSP warns about (degree choice is only as
//! good as the bandwidth it is costed against) and that MegaScale-style
//! fragmented production meshes make common.
//!
//! [`FabricModel`] closes that gap. A [`crate::scheduler::Scheduler`]
//! acquires ONE snapshot per `schedule()` call (a consistent view of
//! mesh occupancy and the replayable placement hint) and routes every
//! bandwidth question through it:
//!
//! * [`FabricModel::bw_for_degree`] — the bandwidth the search costs a
//!   degree-`d` group at. The mesh-backed oracle answers from the free-
//!   slot census (intra-node iff some node still has `d` free slots, or
//!   a hint-replayable intra-node block of that degree is still free);
//!   the uniform oracle reproduces the seed heuristic bit-for-bit.
//! * [`FabricModel::max_bw_for_degree`] — the *optimistic* bandwidth used
//!   by the incumbent pruning bound. Under a non-uniform fabric the
//!   objective's bandwidth is placement-dependent, so admissibility
//!   requires bounding with the best bandwidth any placement could see.
//! * [`FabricModel::capacity`] — the rank budget N the packing, wave
//!   split, and DP may spend: the *free* replicas, not the mesh total.
//! * [`FabricModel::fingerprint`] — a semantic identity of the oracle
//!   (it hashes exactly the state that determines bandwidth answers),
//!   folded into every [`super::scratch::CostCache`] key so memoized
//!   `T(agg, d, bw)` entries are never served across fabric states
//!   whose answers differ — while states that merely wiggle (hint
//!   churn, occupancy that flips no locality) keep the cache warm.
//!
//! The uniform oracle is retained as the reference path
//! ([`FabricKind::Uniform`], used unconditionally by
//! [`crate::scheduler::Scheduler::schedule_reference`]): on an empty
//! mesh the two oracles answer identically, which is what keeps the
//! seed's reference-equality tests bit-exact while the production
//! default switches to the mesh-backed objective.

use std::collections::BTreeMap;

use super::scratch::mix;
use crate::parallel::mesh::{DeviceMesh, PlacementHint};

/// Which bandwidth oracle a [`crate::scheduler::Scheduler`] costs its
/// candidates against.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FabricKind {
    /// Free-slot-aware oracle snapshotted from the mesh each
    /// `schedule()` call (the production default): degrees are costed at
    /// the bandwidth the *current* fragmentation lets them achieve.
    #[default]
    MeshBacked,
    /// The seed's uniform-fabric heuristic (degree fits one node ⇒
    /// intra-node bandwidth, regardless of occupancy). Kept as the
    /// reference oracle for the reference-equality tests and ablations.
    Uniform,
}

/// An immutable, consistent snapshot of fabric state: the single
/// bandwidth oracle one `schedule()` call costs, prunes, and places
/// against. See the [module docs](self) for why snapshot consistency
/// matters (the pipeline's one-step-ahead prewarm and the trainer must
/// see estimates derived from one coherent mesh view, not a view that
/// drifted mid-search).
#[derive(Debug, Clone)]
pub struct FabricModel {
    kind: FabricKind,
    /// Replica slots one physical node hosts.
    replicas_per_node: usize,
    /// Free replica ranks at snapshot time — the rank budget N the
    /// search may spend (Cond. 6 against the *available* mesh).
    capacity: usize,
    /// Intra-node fabric bandwidth (bytes/s).
    intra_bw: f64,
    /// Inter-node fabric bandwidth (bytes/s).
    inter_bw: f64,
    /// Mesh-backed: the largest free-slot count on any single node — a
    /// degree above this cannot be hosted intra-node right now.
    max_node_free: usize,
    /// Mesh-backed: degree → number of hint-recorded intra-node blocks of
    /// that degree that are still fully free (replaying one keeps the
    /// group on the fast fabric AND on a pooled communicator). Today a
    /// free intra block always implies its node has that many free slots,
    /// so this is subsumed by `max_node_free`; it is kept explicit so the
    /// oracle stays correct if the census ever coarsens, and as
    /// telemetry ([`FabricModel::replayable_intra_blocks`]).
    replayable_intra: BTreeMap<usize, usize>,
    /// Semantic identity of this oracle (see [`FabricModel::fingerprint`]).
    fingerprint: u64,
}

impl FabricModel {
    /// The seed's uniform-fabric heuristic over `mesh`. Occupancy still
    /// bounds the rank budget (placement must be feasible), but
    /// bandwidth answers ignore fragmentation entirely.
    pub fn uniform(mesh: &DeviceMesh) -> Self {
        let mut f = FabricModel {
            kind: FabricKind::Uniform,
            replicas_per_node: mesh.replicas_per_node,
            capacity: mesh.free_replicas(),
            intra_bw: mesh.intra_bw,
            inter_bw: mesh.inter_bw,
            max_node_free: mesh.replicas_per_node,
            replayable_intra: BTreeMap::new(),
            fingerprint: 0,
        };
        f.fingerprint = f.derive_fingerprint();
        f
    }

    /// Snapshot the free-slot-aware oracle from the mesh's current
    /// occupancy plus the scheduler's cross-step placement `hint` (the
    /// rank blocks the previous step used — still-free intra-node blocks
    /// among them are replayable at full intra bandwidth).
    pub fn mesh_backed(mesh: &DeviceMesh, hint: Option<&PlacementHint>) -> Self {
        let free_per_node = mesh.free_per_node();
        let max_node_free = free_per_node.iter().copied().max().unwrap_or(0);
        let mut replayable_intra: BTreeMap<usize, usize> = BTreeMap::new();
        if let Some(h) = hint {
            for wave in &h.waves {
                for (d, count) in wave.free_intra_degrees(mesh) {
                    // Subsumption invariant the fingerprint relies on: a
                    // fully-free intra block of degree d lives inside a
                    // node with at least d free slots.
                    debug_assert!(d <= max_node_free);
                    *replayable_intra.entry(d).or_insert(0) += count;
                }
            }
        }
        let mut f = FabricModel {
            kind: FabricKind::MeshBacked,
            replicas_per_node: mesh.replicas_per_node,
            capacity: mesh.free_replicas(),
            intra_bw: mesh.intra_bw,
            inter_bw: mesh.inter_bw,
            max_node_free,
            replayable_intra,
            fingerprint: 0,
        };
        f.fingerprint = f.derive_fingerprint();
        f
    }

    /// Which oracle this snapshot implements.
    pub fn kind(&self) -> FabricKind {
        self.kind
    }

    /// The rank budget N the search may spend: free replicas at snapshot
    /// time (equals the mesh total on an unfragmented mesh).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Can a degree-`d` group be hosted on the fast intra-node fabric
    /// under this snapshot?
    fn intra_capable(&self, d: usize) -> bool {
        match self.kind {
            FabricKind::Uniform => d <= self.replicas_per_node,
            FabricKind::MeshBacked => {
                d <= self.max_node_free
                    || self.replayable_intra.get(&d).copied().unwrap_or(0) > 0
            }
        }
    }

    /// The ring bandwidth the search costs a degree-`d` group at — the
    /// solver stack's single bandwidth oracle (DP transitions, grid
    /// anchors, draft estimates).
    pub fn bw_for_degree(&self, d: usize) -> f64 {
        if self.intra_capable(d) {
            self.intra_bw
        } else {
            self.inter_bw
        }
    }

    /// The *optimistic* bandwidth a degree-`d` group could possibly see —
    /// what the incumbent pruning bound must use to stay admissible
    /// under a non-uniform fabric (a candidate may only be pruned on a
    /// bound that is ≤ its achievable objective; bigger bandwidth ⇒
    /// smaller `T`, so the best-case bandwidth gives a sound lower
    /// bound). On the uniform oracle this IS `bw_for_degree`, preserving
    /// the seed's pruning behavior bit-for-bit.
    pub fn max_bw_for_degree(&self, d: usize) -> f64 {
        match self.kind {
            FabricKind::Uniform => self.bw_for_degree(d),
            FabricKind::MeshBacked => {
                if self.intra_capable(d) {
                    self.intra_bw.max(self.inter_bw)
                } else {
                    // A group no node can host spans nodes under every
                    // placement: its ring's slowest link is inter-node.
                    self.inter_bw
                }
            }
        }
    }

    /// Hint telemetry: how many previously-used intra-node blocks of
    /// degree `d` are still fully free (replaying one yields a pool hit
    /// at full intra bandwidth). Always 0 on the uniform oracle.
    pub fn replayable_intra_blocks(&self, d: usize) -> usize {
        self.replayable_intra.get(&d).copied().unwrap_or(0)
    }

    /// Semantic identity of this oracle: two snapshots share a
    /// fingerprint **iff** they answer every `bw_for_degree` /
    /// `max_bw_for_degree` question identically. Folded into every
    /// [`super::scratch::CostCache`] key so memoized cost evaluations
    /// from one fabric state are never served under a state whose
    /// answers differ (the scratch pool is shared process-wide, across
    /// schedulers and mesh states).
    ///
    /// Deliberately NOT hashed: the capacity and the replayable-hint
    /// census. Neither can change a bandwidth answer — capacity is not
    /// part of the bw mapping at all, and a free intra hint block of
    /// degree `d` implies its node has `d` free slots, so the census is
    /// subsumed by the intra threshold (see
    /// [`FabricModel::bw_for_degree`]). Hashing them would re-key — and
    /// therefore cold-start — the shared cost cache on every placement-
    /// hint or occupancy wiggle that leaves the oracle unchanged,
    /// defeating the cross-step memoization the scratch pool exists for.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    fn derive_fingerprint(&self) -> u64 {
        let tag: u64 = match self.kind {
            FabricKind::MeshBacked => 0x4D45_5348,
            FabricKind::Uniform => 0x554E_4946,
        };
        // The intra/inter threshold is the oracle's entire degree
        // dependence: degrees at or below it are intra-capable, the rest
        // ride the inter fabric.
        let threshold = match self.kind {
            FabricKind::Uniform => self.replicas_per_node,
            FabricKind::MeshBacked => self.max_node_free,
        };
        let mut h = mix(tag ^ (threshold as u64).rotate_left(24));
        h = mix(h ^ self.intra_bw.to_bits());
        mix(h ^ self.inter_bw.to_bits())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::parallel::mesh::WaveHint;

    fn mesh() -> DeviceMesh {
        // 8 nodes × 8 NPUs, TP=PP=1 → 64 replicas, 8 per node.
        DeviceMesh::new(&ClusterConfig::default())
    }

    #[test]
    fn oracles_agree_on_an_empty_mesh() {
        let m = mesh();
        let uni = FabricModel::uniform(&m);
        let backed = FabricModel::mesh_backed(&m, None);
        assert_eq!(uni.capacity(), 64);
        assert_eq!(backed.capacity(), 64);
        for d in 1..=64usize {
            assert_eq!(
                uni.bw_for_degree(d).to_bits(),
                backed.bw_for_degree(d).to_bits(),
                "degree {d}"
            );
            assert_eq!(
                uni.max_bw_for_degree(d).to_bits(),
                backed.max_bw_for_degree(d).to_bits(),
                "degree {d}"
            );
        }
        // Distinct oracles carry distinct identities even when they
        // currently agree — cache entries must not alias across kinds.
        assert_ne!(uni.fingerprint(), backed.fingerprint());
    }

    #[test]
    fn fragmentation_downgrades_mesh_backed_bandwidth_only() {
        let mut m = mesh();
        // Occupy 6 of 8 slots on every node: max_node_free = 2.
        let occ: Vec<usize> =
            (0..64).filter(|r| r % 8 < 6).collect();
        m.occupy(&occ);
        let uni = FabricModel::uniform(&m);
        let backed = FabricModel::mesh_backed(&m, None);
        assert_eq!(backed.capacity(), 16);
        assert_eq!(uni.capacity(), 16, "budget honors occupancy on both");
        // Degree 3..8 nominally fits a node — the uniform heuristic
        // still prices it intra; the mesh-backed oracle knows better.
        assert_eq!(uni.bw_for_degree(4), m.intra_bw);
        assert_eq!(backed.bw_for_degree(4), m.inter_bw);
        assert_eq!(backed.bw_for_degree(2), m.intra_bw);
        // The optimistic bound tracks achievability.
        assert_eq!(backed.max_bw_for_degree(4), m.inter_bw);
        assert_eq!(backed.max_bw_for_degree(2), m.intra_bw.max(m.inter_bw));
    }

    #[test]
    fn fingerprint_tracks_oracle_semantics_not_raw_state() {
        let mut m = mesh();
        let before = FabricModel::mesh_backed(&m, None);
        // Occupancy that changes no bandwidth answer (node 1 still has 8
        // free slots, so every degree's locality is unchanged) must NOT
        // re-key the cache — that would cold-start the memoization on
        // every harmless wiggle.
        m.occupy(&[0, 1, 2, 3]);
        let benign = FabricModel::mesh_backed(&m, None);
        assert_eq!(before.fingerprint(), benign.fingerprint());
        assert_ne!(before.capacity(), benign.capacity());
        // Occupancy that DOES flip answers (6 of 8 slots taken on every
        // node: degrees 3..8 fall off the intra fabric) must re-key.
        let rest: Vec<usize> = (0..64)
            .filter(|r| r % 8 < 6 && !(0..4).contains(r))
            .collect();
        m.occupy(&rest);
        let after = FabricModel::mesh_backed(&m, None);
        assert_ne!(
            before.fingerprint(),
            after.fingerprint(),
            "an oracle-visible occupancy change must re-key the cost cache"
        );
        assert_ne!(before.bw_for_degree(4), after.bw_for_degree(4));
        m.release(&rest);
        m.release(&[0, 1, 2, 3]);
        let restored = FabricModel::mesh_backed(&m, None);
        assert_eq!(before.fingerprint(), restored.fingerprint());
    }

    #[test]
    fn hint_blocks_are_replayable_while_free() {
        let m = mesh();
        let mut hint = PlacementHint::default();
        let mut wh = WaveHint::default();
        wh.remember(&[0, 1, 2]); // intra-node, free
        wh.remember(&[6, 7, 8]); // spans nodes — not an intra block
        hint.waves.push(wh);
        let backed = FabricModel::mesh_backed(&m, Some(&hint));
        assert_eq!(backed.replayable_intra_blocks(3), 1);
        // Occupying a member kills replayability — but since the census
        // still hosts degree 3 intra (other nodes untouched), no
        // bandwidth answer changed and the cache key must stay stable.
        let mut m2 = mesh();
        m2.occupy(&[1]);
        let backed2 = FabricModel::mesh_backed(&m2, Some(&hint));
        assert_eq!(backed2.replayable_intra_blocks(3), 0);
        assert_eq!(backed2.bw_for_degree(3), m2.intra_bw);
        assert_eq!(backed.fingerprint(), backed2.fingerprint());
    }

    #[test]
    fn max_bw_never_below_costing_bw() {
        let mut m = mesh();
        m.occupy(&(0..29).collect::<Vec<_>>());
        let mut hint = PlacementHint::default();
        let mut wh = WaveHint::default();
        wh.remember(&[32, 33, 34, 35]);
        hint.waves.push(wh);
        for fab in [
            FabricModel::uniform(&m),
            FabricModel::mesh_backed(&m, Some(&hint)),
        ] {
            for d in 1..=fab.capacity() {
                assert!(
                    fab.max_bw_for_degree(d) >= fab.bw_for_degree(d),
                    "degree {d}: pruning bound bandwidth below objective"
                );
            }
        }
    }
}
