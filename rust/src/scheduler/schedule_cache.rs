//! Cross-step solver reuse (ISSUE-9): the schedule cache, warm-start
//! incumbent seeding, and the opt-in ε-bounded fast path.
//!
//! Consecutive micro-batches in a training stream are strongly
//! correlated — the data loader draws from one distribution, the mesh
//! rarely changes between steps, and the cost model never does. The
//! solver nevertheless used to treat every `schedule()` call as its
//! first. This module makes [`Scheduler::schedule`] temporally
//! incremental in three layers, ordered by strength of guarantee:
//!
//! 1. **Exact-hit schedule cache** (exact): a bounded LRU keyed on the
//!    canonical batch content plus every input the solve depends on
//!    (fabric fingerprint + capacity, mesh occupancy, cost-model
//!    fingerprint, degree policy, fabric kind). A hit returns the
//!    cached pre-placement [`Draft`] — remapped to the current batch's
//!    indices and re-placed against the *current* mesh and hint — so
//!    the result is bit-identical to re-solving while skipping the
//!    entire outer search. The cache stores drafts, not placed
//!    schedules, precisely so placement (which depends on the mutable
//!    cross-step [`crate::parallel::mesh::PlacementHint`]) always runs
//!    fresh.
//! 2. **Warm-start incumbent seeding** (exact): on a miss, the previous
//!    step's winning plan is re-costed under the current fabric
//!    snapshot (memoized [`super::scratch::CostCache`] evaluations, no
//!    placement) and, if still feasible, its cost `U` seeds the
//!    search's atomic incumbent before any candidate runs. A feasible
//!    solution's cost is an admissible upper bound, so the sound
//!    strict-`>` pruning fires from candidate 0. A post-search guard
//!    keeps this exact: the seeded result is accepted only when its
//!    best estimate is ≤ `U` — in that regime the incumbent was always
//!    ≥ the cold optimum, so the cold winner was never pruned and the
//!    deterministic `(est, index)` selection is unchanged; otherwise
//!    (the previous plan beat every candidate, so `U` under-cut the
//!    cold optimum) the search re-runs unseeded.
//! 3. **ε-bounded fast path** (bounded suboptimality, opt-in via
//!    [`Scheduler::with_reuse_epsilon`], off by default): when the
//!    re-costed previous plan lands within `(1+ε)` of a sound
//!    batch-global lower bound, the search is skipped entirely and the
//!    mapped plan is reused. Every use is counted in telemetry
//!    ([`SolveStats::fast_path`]); fast-path results are never
//!    inserted into the exact cache.
//!
//! # Canonicalization
//!
//! The solver consumes sequences only through their `(vision_tokens,
//! text_tokens)` content — `Sequence::id` and `duration_s` never enter
//! packing, the DP, or the cost model — and every content-order-
//! sensitive step (BFD packing, LPT grid assignment) sorts by length
//! descending with ties broken by ascending batch index. The canonical
//! form of a batch is therefore its content list *in that sort order*:
//! two batches with equal canonical lists are solved through identical
//! arithmetic, differing only in the original-index labels, so a cached
//! draft transfers by mapping canonical rank → current index. Batches
//! whose equal multisets interleave distinct `(vision, text)` splits at
//! a shared total length sort differently and deliberately get distinct
//! keys — the index tie-break makes those solves order-dependent, and
//! the cache must never serve a result re-solving wouldn't reproduce.

use crate::cost::WorkloadAgg;
use crate::data::sequence::Sequence;
use crate::parallel::mesh::DeviceMesh;

use super::scratch::mix;
use super::{
    DegreePolicy, Draft, FabricKind, FabricModel, Plan, PlannedGroup,
    Scheduler, SolverScratch,
};

/// How many distinct solves the per-scheduler cache retains. Training
/// streams revisit a handful of recurring micro-batch shapes (and the
/// trainer's fixed-geometry stream exactly one), so a small bound keeps
/// the exact-compare probe cheap while covering the steady state.
const CACHE_CAPACITY: usize = 32;

/// Provenance and search telemetry of one `schedule()` call — carried
/// on every [`super::Schedule`] and aggregated into
/// [`crate::session::StepReport`] / the trainer CSV. Telemetry only:
/// never folded into semantic digests.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SolveStats {
    /// Served from the exact-hit schedule cache (bit-identical to
    /// re-solving; the outer search never ran).
    pub cache_hit: bool,
    /// The outer search ran with its incumbent seeded by the re-costed
    /// previous plan AND the seeded result passed the exactness guard.
    pub warm_started: bool,
    /// The ε-bounded fast path reused the previous plan without
    /// searching (only possible when an ε is configured).
    pub fast_path: bool,
    /// Outer-search candidates considered (0 on the hit/fast paths).
    pub candidates: usize,
    /// Candidates skipped by incumbent pruning or inadmissibility.
    pub pruned: usize,
}

impl SolveStats {
    /// Fraction of candidates the incumbent pruning (plus
    /// inadmissibility) eliminated before DP work; 0 when no search ran.
    pub fn pruned_frac(&self) -> f64 {
        if self.candidates == 0 {
            0.0
        } else {
            self.pruned as f64 / self.candidates as f64
        }
    }

    /// Compact provenance label for tables and the trainer CSV.
    pub fn label(&self) -> &'static str {
        if self.cache_hit {
            "hit"
        } else if self.fast_path {
            "fast"
        } else if self.warm_started {
            "warm"
        } else {
            "cold"
        }
    }
}

/// The canonical batch order: length descending, ties by ascending
/// index — exactly the comparator BFD packing and the LPT grid anchors
/// sort by, so position `k` of this permutation is "the k-th sequence
/// as the solver consumes them".
pub(super) fn canonical_order(seqs: &[Sequence]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..seqs.len()).collect();
    order.sort_by(|&a, &b| seqs[b].len().cmp(&seqs[a].len()).then(a.cmp(&b)));
    order
}

fn canonical_lens(seqs: &[Sequence], order: &[usize]) -> Vec<(u64, u64)> {
    order
        .iter()
        .map(|&i| (seqs[i].vision_tokens, seqs[i].text_tokens))
        .collect()
}

/// Occupancy identity of the mesh a solve places onto. The fabric
/// fingerprint is deliberately *semantic* (it ignores occupancy that
/// flips no bandwidth answer — see [`FabricModel::fingerprint`]), but a
/// cached draft's placement context is the concrete free-rank set, so
/// the cache key must include it: [`super::Scheduler::sync_mesh`]
/// clears the cache on every ordered mesh re-snapshot, and this
/// fingerprint is defense-in-depth for bare schedulers whose mesh is
/// mutated directly between calls.
fn mesh_occupancy_fp(mesh: &DeviceMesh) -> u64 {
    let mut h = mix(0x0CC5_0CC5 ^ (mesh.replicas as u64).rotate_left(32));
    for r in 0..mesh.replicas {
        if !mesh.is_rank_free(r) {
            h = mix(h ^ (r as u64 + 1));
        }
    }
    h
}

/// Everything a solve's draft depends on. Two calls with equal keys run
/// identical search arithmetic (see the module docs), so serving one's
/// draft for the other is exact. Compared field-by-field on probe — the
/// 64-bit pre-filter hash only narrows the scan; a collision is never
/// served.
#[derive(Debug, Clone, PartialEq)]
pub(super) struct CacheKey {
    /// Canonical `(vision, text)` content list (packing order).
    lens: Vec<(u64, u64)>,
    /// Semantic fabric identity (bandwidth answers).
    fabric_fp: u64,
    /// Rank budget N of the snapshot (not part of `fabric_fp`).
    capacity: usize,
    /// Concrete mesh occupancy (placement context).
    mesh_fp: u64,
    /// Cost-model coefficient identity.
    model_fp: u64,
    /// Degree admissibility policy.
    policy: DegreePolicy,
    /// Which bandwidth oracle produced the snapshot.
    fabric_kind: FabricKind,
}

impl CacheKey {
    fn new(
        sch: &Scheduler,
        seqs: &[Sequence],
        order: &[usize],
        fabric: &FabricModel,
    ) -> Self {
        CacheKey {
            lens: canonical_lens(seqs, order),
            fabric_fp: fabric.fingerprint(),
            capacity: fabric.capacity(),
            mesh_fp: mesh_occupancy_fp(&sch.mesh),
            model_fp: sch.cost.coeffs.fingerprint(),
            policy: sch.policy,
            fabric_kind: sch.fabric,
        }
    }

    fn hash(&self) -> u64 {
        let mut h = mix(
            self.fabric_fp
                ^ self.model_fp.rotate_left(17)
                ^ self.mesh_fp.rotate_left(41)
                ^ (self.capacity as u64).rotate_left(7),
        );
        h = mix(
            h ^ match self.policy {
                DegreePolicy::AnyInteger => 0xA11,
                DegreePolicy::PowerOfTwo => 0xF02,
            } ^ match self.fabric_kind {
                FabricKind::MeshBacked => 0x4D00,
                FabricKind::Uniform => 0x5500,
            },
        );
        for &(v, t) in &self.lens {
            h = mix(h ^ v ^ t.rotate_left(21));
        }
        h
    }
}

/// Bounded LRU over `(key → canonical draft)`. Entries are stored
/// most-recently-used last; probes scan the (≤ [`CACHE_CAPACITY`])
/// entries with a hash pre-filter and an exact key compare.
#[derive(Debug, Default)]
pub(super) struct ScheduleCache {
    entries: Vec<(u64, CacheKey, Draft)>,
    hits: u64,
    misses: u64,
}

impl ScheduleCache {
    fn get(&mut self, hash: u64, key: &CacheKey) -> Option<Draft> {
        match self
            .entries
            .iter()
            .position(|(h, k, _)| *h == hash && k == key)
        {
            Some(pos) => {
                self.hits += 1;
                // Move to MRU position; the clone is cheap relative to
                // the search it replaces.
                let entry = self.entries.remove(pos);
                let draft = entry.2.clone();
                self.entries.push(entry);
                Some(draft)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    fn insert(&mut self, hash: u64, key: CacheKey, draft: Draft) {
        if let Some(pos) = self
            .entries
            .iter()
            .position(|(h, k, _)| *h == hash && *k == key)
        {
            self.entries.remove(pos);
        }
        self.entries.push((hash, key, draft));
        if self.entries.len() > CACHE_CAPACITY {
            self.entries.remove(0); // evict LRU
        }
    }

    fn clear(&mut self) {
        self.entries.clear();
    }

    #[cfg(test)]
    fn len(&self) -> usize {
        self.entries.len()
    }
}

/// The previous step's winning plan, kept in canonical-rank index space
/// so it can be re-mapped onto any same-size batch.
#[derive(Debug, Clone)]
struct PrevSolve {
    /// Canonical content list of the batch it was solved for (retained
    /// for debugging; the mapping itself only needs the count).
    #[allow(dead_code)]
    lens: Vec<(u64, u64)>,
    draft: Draft,
}

/// Per-scheduler (shared across clones, like the placement hint)
/// cross-step reuse state: the exact-hit cache plus the warm-start
/// seed. The mutex is held only for probes and inserts — never across
/// a search.
#[derive(Debug, Default)]
pub(super) struct ReuseState {
    cache: ScheduleCache,
    prev: Option<PrevSolve>,
}

/// Map a canonical-rank draft onto concrete batch indices through the
/// canonical order (`rank → order[rank]`).
fn remap_draft(mut draft: Draft, order: &[usize]) -> Draft {
    for plan in &mut draft.waves {
        for g in &mut plan.groups {
            for idx in &mut g.seq_idxs {
                *idx = order[*idx];
            }
        }
    }
    draft
}

/// Inverse of [`remap_draft`]: rewrite concrete indices as canonical
/// ranks (`index → rank_of[index]`) for storage.
fn canonicalize_draft(mut draft: Draft, order: &[usize]) -> Draft {
    let mut rank_of = vec![0usize; order.len()];
    for (rank, &i) in order.iter().enumerate() {
        rank_of[i] = rank;
    }
    for plan in &mut draft.waves {
        for g in &mut plan.groups {
            for idx in &mut g.seq_idxs {
                *idx = rank_of[*idx];
            }
        }
    }
    draft
}

impl Scheduler {
    /// Enable or disable cross-step solver reuse (the exact-hit cache,
    /// warm-start seeding, and the ε fast path) wholesale. On by
    /// default; disabling forces every `schedule()` call down the cold
    /// search — the reference discipline for the bit-identity property
    /// tests and for benchmarks that re-solve one batch repeatedly and
    /// must keep measuring the search, not the cache.
    pub fn with_solver_reuse(mut self, enabled: bool) -> Self {
        self.reuse_enabled = enabled;
        self
    }

    /// Opt into the ε-bounded fast path: when the re-costed previous
    /// plan lands within `(1 + epsilon)` of a sound batch-global lower
    /// bound for the *current* batch, the outer search is skipped and
    /// the plan reused — the returned schedule's search objective is
    /// then guaranteed within `(1 + epsilon)` of the optimum. Off by
    /// default (`None`); every use is counted in
    /// [`SolveStats::fast_path`]. Requires `epsilon ≥ 0`.
    pub fn with_reuse_epsilon(mut self, epsilon: f64) -> Self {
        assert!(
            epsilon >= 0.0 && epsilon.is_finite(),
            "reuse epsilon must be finite and non-negative"
        );
        self.epsilon = Some(epsilon);
        self
    }

    /// Drop every cached solve (the exact-hit cache). Called from
    /// [`crate::baselines::SchedulePolicy::sync_mesh`] so the pipeline's
    /// ordered `SyncMesh` control message invalidates the scheduling
    /// thread's cache in the same breath that re-snapshots the mesh —
    /// a stale cached placement onto a now-occupied rank would be a
    /// correctness bug. The warm-start seed survives: it is re-costed
    /// and feasibility-checked against the fresh fabric snapshot on
    /// every use, which is exactly what lets elastic-recovery re-solves
    /// start from the pre-fault plan.
    pub fn invalidate_schedule_cache(&self) {
        self.reuse
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .cache
            .clear();
    }

    /// Cumulative (hits, misses) of the exact-hit schedule cache.
    pub fn schedule_cache_stats(&self) -> (u64, u64) {
        let st = self.reuse.lock().unwrap_or_else(|e| e.into_inner());
        (st.cache.hits, st.cache.misses)
    }

    /// The reuse-aware front of the solve: exact-hit cache probe, then
    /// the ε fast path, then the warm-start-seeded (guarded, exact)
    /// search. Returns the chosen pre-placement draft plus provenance.
    pub(super) fn plan_with_reuse(
        &self,
        seqs: &[Sequence],
        fabric: &FabricModel,
    ) -> (Draft, SolveStats) {
        if !self.reuse_enabled || seqs.is_empty() {
            return self.plan_search(seqs, fabric, None);
        }
        let order = canonical_order(seqs);
        let key = CacheKey::new(self, seqs, &order, fabric);
        let hash = key.hash();
        // Probe and snapshot under one short critical section; the lock
        // is NOT held across the search (search workers clone `self`,
        // and a bare scheduler's submitting thread re-enters this type).
        let prev = {
            let mut st = self.reuse.lock().unwrap_or_else(|e| e.into_inner());
            if let Some(draft) = st.cache.get(hash, &key) {
                let stats = SolveStats {
                    cache_hit: true,
                    ..SolveStats::default()
                };
                return (remap_draft(draft, &order), stats);
            }
            st.prev.clone()
        };
        let recosted = prev.and_then(|p| self.recost_prev(&p, seqs, &order, fabric));
        if let (Some(eps), Some((u, mapped))) = (self.epsilon, &recosted) {
            let lb = self.batch_lower_bound(seqs, fabric);
            if *u <= lb * (1.0 + eps) {
                // Bounded-suboptimality reuse: optimum ≥ lb ≥ U/(1+ε).
                // Never inserted into the exact cache.
                let stats = SolveStats {
                    fast_path: true,
                    ..SolveStats::default()
                };
                return (mapped.clone(), stats);
            }
        }
        let seed = recosted.map(|(u, _)| u);
        let (draft, stats) = self.plan_search(seqs, fabric, seed);
        {
            let mut st = self.reuse.lock().unwrap_or_else(|e| e.into_inner());
            let canonical = canonicalize_draft(draft.clone(), &order);
            st.prev = Some(PrevSolve {
                lens: key.lens.clone(),
                draft: canonical.clone(),
            });
            st.cache.insert(hash, key, canonical);
        }
        (draft, stats)
    }

    /// Re-cost the previous winning plan under the current batch and
    /// fabric snapshot: map canonical rank `k` to the current batch's
    /// k-th canonical sequence, rebuild each group's aggregate, and
    /// verify the plan is still feasible (degrees admissible and within
    /// the rank budget, per-wave rank sums within capacity, group
    /// memory fits). Returns the achievable cost `U` — an admissible
    /// upper bound on the current optimum — and the mapped draft
    /// (costed at the snapshot's `bw_for_degree`, the search-objective
    /// lineage). `None` when the batch size changed or any feasibility
    /// check fails.
    fn recost_prev(
        &self,
        prev: &PrevSolve,
        seqs: &[Sequence],
        order: &[usize],
        fabric: &FabricModel,
    ) -> Option<(f64, Draft)> {
        if prev.draft.waves.is_empty()
            || prev.draft.waves.iter().map(|w| w.groups.iter().map(|g| g.seq_idxs.len()).sum::<usize>()).sum::<usize>()
                != seqs.len()
        {
            return None;
        }
        let mut scratch = SolverScratch::acquire();
        let out = self.recost_prev_in(prev, seqs, order, fabric, &mut scratch);
        scratch.release();
        out
    }

    fn recost_prev_in(
        &self,
        prev: &PrevSolve,
        seqs: &[Sequence],
        order: &[usize],
        fabric: &FabricModel,
        scratch: &mut SolverScratch,
    ) -> Option<(f64, Draft)> {
        let n = fabric.capacity();
        let model_fp = self.cost.coeffs.fingerprint();
        let fabric_fp = fabric.fingerprint();
        let mut draft = Draft::default();
        for plan in &prev.draft.waves {
            let mut mapped = Plan::default();
            let mut wave_ranks = 0usize;
            for g in &plan.groups {
                let d = g.degree;
                if d == 0 || d > n || !self.policy.admits(d) {
                    return None;
                }
                wave_ranks += d;
                let mut agg = WorkloadAgg::default();
                let mut tokens = 0u64;
                let mut idxs = Vec::with_capacity(g.seq_idxs.len());
                for &rank in &g.seq_idxs {
                    let i = *order.get(rank)?;
                    let s = &seqs[i];
                    agg.add(s);
                    tokens += s.len();
                    idxs.push(i);
                }
                if !self.cost.memory.fits(tokens, d) {
                    return None;
                }
                let t = scratch.cache.t_total(
                    model_fp,
                    fabric_fp,
                    &self.cost,
                    &agg,
                    d,
                    fabric.bw_for_degree(d),
                );
                mapped.est_makespan_s = mapped.est_makespan_s.max(t);
                mapped.groups.push(PlannedGroup {
                    degree: d,
                    seq_idxs: idxs,
                    agg,
                    est_time_s: t,
                });
            }
            if wave_ranks > n {
                return None;
            }
            draft.est_time_s += mapped.est_makespan_s;
            draft.waves.push(mapped);
        }
        Some((draft.est_time_s, draft))
    }

    /// A sound lower bound on ANY schedule's search objective for this
    /// batch, computable before packing (the ε fast path's yardstick):
    /// the larger of
    ///
    /// * the aggregate-work bound — `t_compute` is linear in the
    ///   aggregate, so summing the per-wave work bounds of any wave
    ///   partition gives `t_compute(Σ_batch agg, N)` regardless of how
    ///   the batch splits into waves (1e-9 shave as in
    ///   [`Scheduler::lower_bound`]);
    /// * the single-sequence communication floor — a sequence whose
    ///   memory-forced minimum degree (policy-rounded) is ≥ 2 sits in a
    ///   group with at least its own aggregate and at least that
    ///   degree, and `T ≥ T_cm` with `t_comm` monotone in both, so its
    ///   floor at the fabric's best bandwidth bounds that group's time
    ///   — and any single group's time bounds the total.
    fn batch_lower_bound(&self, seqs: &[Sequence], fabric: &FabricModel) -> f64 {
        let n = fabric.capacity();
        let mut v_star = 0.0f64;
        for d in 2..=n {
            let v = fabric.max_bw_for_degree(d);
            if v > v_star {
                v_star = v;
            }
        }
        let mut agg = WorkloadAgg::default();
        let mut comm_floor = 0.0f64;
        for s in seqs {
            agg.add(s);
            let dm = self
                .policy
                .min_admissible(self.cost.memory.min_degree(s.len()))
                .min(n)
                .max(1);
            if dm >= 2 && v_star > 0.0 {
                let single = WorkloadAgg::of(std::slice::from_ref(s));
                let f = self.cost.t_comm(&single, dm, v_star) * (1.0 - 1e-9);
                if f > comm_floor {
                    comm_floor = f;
                }
            }
        }
        (self.cost.t_compute(&agg, n) * (1.0 - 1e-9)).max(comm_floor)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::by_name;
    use crate::config::{ClusterConfig, TrainStage};
    use crate::cost::{CostCoeffs, CostModel, HardwareSpec, MemoryModel};
    use crate::data::datasets::{DatasetKind, DatasetSampler, TokenizerSpec};
    use crate::parallel::mesh::DeviceMesh;
    use crate::util::quickcheck::forall;
    use crate::util::rng::Rng;

    fn sampler(kind: DatasetKind, seed: u64) -> DatasetSampler {
        DatasetSampler::new(kind, seed).with_spec(TokenizerSpec {
            fps: 2.0,
            tokens_per_frame: 256.0,
            text_min: 32,
            text_max: 512,
        })
    }

    fn scheduler(replicas: usize) -> Scheduler {
        let mut cluster = ClusterConfig::default().with_npus(replicas * 4);
        cluster.tp = 2;
        cluster.pp = 2;
        let preset = by_name("InternVL3-8B").unwrap();
        let hw = HardwareSpec {
            peak_flops: 376e12 * 4.0,
            ..HardwareSpec::default()
        };
        let cost = CostModel {
            coeffs: CostCoeffs::analytic(&preset, TrainStage::Full, &hw),
            memory: MemoryModel {
                e_bytes: 8192.0 * preset.act_bytes_per_token() + 2e9,
                m_states: 2e9,
                m_token: preset.act_bytes_per_token(),
            },
        };
        Scheduler::new(cost, DeviceMesh::new(&cluster))
    }

    fn assert_bit_identical(a: &super::super::Schedule, b: &super::super::Schedule, ctx: &str) {
        assert_eq!(a.waves, b.waves, "{ctx}: waves diverged");
        assert_eq!(
            a.est_time_s.to_bits(),
            b.est_time_s.to_bits(),
            "{ctx}: est drifted"
        );
        assert_eq!(
            a.search_est_time_s.to_bits(),
            b.search_est_time_s.to_bits(),
            "{ctx}: search est drifted"
        );
    }

    #[test]
    fn lru_is_bounded_and_promotes_hits() {
        let sch = scheduler(8);
        let mesh = &sch.mesh;
        let fabric = FabricModel::mesh_backed(mesh, None);
        let mut cache = ScheduleCache::default();
        let mk_key = |tokens: u64| {
            let seqs = vec![Sequence::new(0, tokens, 64)];
            let order = canonical_order(&seqs);
            CacheKey::new(&sch, &seqs, &order, &fabric)
        };
        for t in 0..(CACHE_CAPACITY as u64 + 8) {
            let key = mk_key(1000 + t);
            let hash = key.hash();
            cache.insert(hash, key, Draft::default());
        }
        assert_eq!(cache.len(), CACHE_CAPACITY);
        // The oldest 8 were evicted; a survivor probes positively and is
        // promoted to MRU (it then survives one more insert).
        let survivor = mk_key(1000 + 8);
        assert!(cache.get(survivor.hash(), &survivor).is_some());
        let evicted = mk_key(1000);
        assert!(cache.get(evicted.hash(), &evicted).is_none());
        let fresh = mk_key(9999);
        cache.insert(fresh.hash(), fresh, Draft::default());
        assert!(cache.get(survivor.hash(), &survivor).is_some());
        cache.clear();
        assert_eq!(cache.len(), 0);
    }

    #[test]
    fn repeat_batch_is_a_cache_hit_and_bit_identical() {
        // Tentpole layer 1 on the nose: the second identical call must
        // be served from the cache AND be bit-identical to what a
        // reuse-disabled twin (same call history) re-solves.
        let sch = scheduler(16);
        let cold = scheduler(16).with_solver_reuse(false);
        let mut s = sampler(DatasetKind::OpenVid, 77);
        let seqs = s.sample_batch(48);
        let first = sch.schedule(&seqs);
        assert!(!first.stats.cache_hit, "first solve cannot hit");
        let _ = cold.schedule(&seqs);
        let again = sch.schedule(&seqs);
        let again_cold = cold.schedule(&seqs);
        assert!(again.stats.cache_hit, "identical re-solve must hit");
        assert_eq!(again.stats.candidates, 0, "hit must skip the search");
        assert_bit_identical(&again, &again_cold, "hit vs re-solve");
        let (hits, misses) = sch.schedule_cache_stats();
        assert_eq!((hits, misses), (1, 1));
    }

    #[test]
    fn property_cache_hit_is_bit_identical_to_resolving() {
        // Satellite (a): across random batches, fabrics (occupancy),
        // and input permutations, a reuse-enabled scheduler must return
        // exactly what a reuse-disabled twin with the same call history
        // returns — hits, warm starts, and permuted replays included.
        forall(12, 0x9E05E, |rng| {
            let npus = *rng.choose(&[8usize, 16, 32]);
            let mut reuse = scheduler(npus);
            let mut cold = scheduler(npus).with_solver_reuse(false);
            if rng.range_usize(0, 3) == 0 {
                // A fragmented mesh: occupy one rank of every other node.
                let occ: Vec<usize> = (0..npus).step_by(4).collect();
                reuse.mesh.occupy(&occ);
                cold.mesh.occupy(&occ);
            }
            let kind = *rng.choose(&DatasetKind::all());
            let mut s = sampler(kind, rng.next_u64());
            let base = s.sample_batch(rng.range_usize(2, 48));
            // A replay schedule mixing: fresh solve, exact replay,
            // permuted replay — both schedulers see the same stream.
            let mut perm = base.clone();
            rng.shuffle(&mut perm);
            for (round, batch) in
                [&base, &base, &perm, &base].iter().enumerate()
            {
                let a = reuse.schedule(batch);
                let b = cold.schedule(batch);
                if a.waves != b.waves
                    || a.est_time_s.to_bits() != b.est_time_s.to_bits()
                    || a.search_est_time_s.to_bits()
                        != b.search_est_time_s.to_bits()
                {
                    return Err(format!(
                        "round {round} diverged (npus={npus}, kind={kind:?}, \
                         label={})",
                        a.stats.label()
                    ));
                }
                a.validate(batch, npus).map_err(|e| e.to_string())?;
            }
            Ok(())
        });
    }

    #[test]
    fn property_warm_start_matches_cold_search() {
        // Satellite (b): jittered same-count streams — the regime where
        // warm-start seeding (not the exact cache) carries the reuse —
        // must leave est bits, degrees, and placement unchanged vs the
        // cold search.
        let mut warm_seen = false;
        forall(10, 0x3A97, |rng| {
            let npus = *rng.choose(&[8usize, 16]);
            let reuse = scheduler(npus);
            let cold = scheduler(npus).with_solver_reuse(false);
            let kind = *rng.choose(&DatasetKind::all());
            let count = rng.range_usize(4, 40);
            for step in 0..4 {
                // Same count each step, fresh contents: cache misses,
                // warm-start eligible.
                let mut s = sampler(kind, rng.next_u64());
                let batch = s.sample_batch(count);
                let a = reuse.schedule(&batch);
                let b = cold.schedule(&batch);
                warm_seen |= a.stats.warm_started;
                if step > 0 && a.stats.cache_hit {
                    return Err("fresh contents must not exact-hit".into());
                }
                if a.waves != b.waves
                    || a.est_time_s.to_bits() != b.est_time_s.to_bits()
                    || a.search_est_time_s.to_bits()
                        != b.search_est_time_s.to_bits()
                {
                    return Err(format!(
                        "step {step} diverged under {} (npus={npus}, \
                         kind={kind:?}, count={count})",
                        a.stats.label()
                    ));
                }
            }
            Ok(())
        });
        // Not every draw warm-starts (re-mapped feasibility can fail),
        // but a whole run where seeding never engaged tests nothing.
        assert!(warm_seen, "no case ever warm-started");
    }

    #[test]
    fn cache_isolates_fabric_and_model_states() {
        // Satellite (c), mirroring scratch::cache_isolates_fabric_states:
        // a key must never cross-serve across occupancy or cost-model
        // changes, even when the batch is identical.
        let mut sch = scheduler(16);
        let mut s = sampler(DatasetKind::OpenVid, 5150);
        let seqs = s.sample_batch(24);
        let first = sch.schedule(&seqs);
        assert!(!first.stats.cache_hit);
        // Occupancy change (bandwidth answers flip: 3 of 4 slots taken
        // on every node). The cached entry must not be served.
        let occ: Vec<usize> = (0..16).filter(|r| r % 4 != 3).collect();
        sch.mesh.occupy(&occ);
        let fragged = sch.schedule(&seqs);
        assert!(
            !fragged.stats.cache_hit,
            "occupancy change must miss the cache"
        );
        let mut cold = scheduler(16).with_solver_reuse(false);
        cold.mesh.occupy(&occ);
        assert_bit_identical(&fragged, &cold.schedule(&seqs), "post-occupy");
        for wave in &fragged.waves {
            for g in &wave.groups {
                for &r in &g.ranks {
                    assert!(r % 4 == 3, "occupied rank {r} placed from stale state");
                }
            }
        }
        sch.mesh.release(&occ);
        // Cost-model change: perturb a coefficient — the fingerprint
        // moves, so the original entry must not be served either.
        let before_fp = sch.cost.coeffs.fingerprint();
        sch.cost.coeffs.alpha1 *= 2.0;
        assert_ne!(
            before_fp,
            sch.cost.coeffs.fingerprint(),
            "test needs a model change the fingerprint can see"
        );
        let remodeled = sch.schedule(&seqs);
        assert!(
            !remodeled.stats.cache_hit,
            "cost-model change must miss the cache"
        );
    }

    #[test]
    fn epsilon_fast_path_is_opt_in_bounded_and_counted() {
        // Off by default: a default-config stream never takes it.
        let sch = scheduler(16);
        let mut s = sampler(DatasetKind::OpenVid, 404);
        for _ in 0..4 {
            let batch = s.sample_batch(24);
            assert!(!sch.schedule(&batch).stats.fast_path);
        }
        // With an enormous ε, a same-count follow-up step takes the
        // fast path as soon as the re-mapped previous plan is feasible
        // — and must still produce a valid, coverage-complete schedule
        // whose objective respects the ε bound.
        let eager = scheduler(16).with_reuse_epsilon(1e9);
        let mut s = sampler(DatasetKind::OpenVid, 405);
        let first = eager.schedule(&s.sample_batch(24));
        assert!(!first.stats.fast_path, "no previous plan to reuse yet");
        let mut fast: Option<(Vec<Sequence>, super::super::Schedule)> = None;
        for _ in 0..6 {
            let batch = s.sample_batch(24);
            let out = eager.schedule(&batch);
            if out.stats.fast_path {
                fast = Some((batch, out));
                break;
            }
        }
        let (batch, second) =
            fast.expect("ε=1e9 never accepted a feasible re-mapped plan");
        assert_eq!(second.stats.candidates, 0, "fast path skips the search");
        second.validate(&batch, 16).unwrap();
        let fabric = eager.snapshot_fabric();
        let lb = eager.batch_lower_bound(&batch, &fabric);
        assert!(
            second.search_est_time_s <= lb * (1.0 + 1e9),
            "fast-path objective {} exceeds (1+ε)·lb {}",
            second.search_est_time_s,
            lb * (1.0 + 1e9)
        );
        // The fast-path result must NOT have been inserted into the
        // exact cache: re-solving its batch must miss the cache.
        let third = eager.schedule(&batch);
        assert!(
            !third.stats.cache_hit,
            "ε-approximate result leaked into the exact cache"
        );
    }

    #[test]
    fn batch_lower_bound_never_exceeds_solved_estimate() {
        // The fast path's yardstick must be admissible: never above the
        // search's own optimum, across random batches and occupancy.
        forall(20, 0xFA57, |rng| {
            let npus = *rng.choose(&[8usize, 16, 32]);
            let sch = scheduler(npus).with_solver_reuse(false);
            let kind = *rng.choose(&DatasetKind::all());
            let mut s = sampler(kind, rng.next_u64());
            let batch = s.sample_batch(rng.range_usize(1, 48));
            let fabric = sch.snapshot_fabric();
            let lb = sch.batch_lower_bound(&batch, &fabric);
            let solved = sch.schedule(&batch);
            if lb > solved.search_est_time_s {
                return Err(format!(
                    "unsound batch bound {lb} > solved {} (npus={npus}, \
                     kind={kind:?})",
                    solved.search_est_time_s
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn sync_mesh_clears_the_cache_but_keeps_the_warm_seed() {
        use crate::baselines::SchedulePolicy;
        let mut sch = scheduler(16);
        let mut s = sampler(DatasetKind::OpenVid, 808);
        let seqs = s.sample_batch(24);
        let _ = sch.schedule(&seqs);
        let mesh = sch.mesh.clone();
        SchedulePolicy::sync_mesh(&mut sch, &mesh);
        let after = sch.schedule(&seqs);
        assert!(
            !after.stats.cache_hit,
            "sync_mesh must invalidate the exact cache"
        );
        assert!(
            after.stats.warm_started || after.stats.candidates > 0,
            "the search must actually re-run after invalidation"
        );
    }
}
