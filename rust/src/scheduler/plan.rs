//! Plan types: the output of the DHP scheduler for one micro-batch.

use crate::cost::WorkloadAgg;
use crate::data::sequence::Sequence;

/// One planned CP group: a degree and the sequences assigned to it.
#[derive(Debug, Clone, PartialEq)]
pub struct PlannedGroup {
    /// CP degree d_p (any positive integer — the paper's relaxation).
    pub degree: usize,
    /// Indices into the micro-batch's sequence list.
    pub seq_idxs: Vec<usize>,
    /// Cached workload aggregates of the assigned sequences.
    pub agg: WorkloadAgg,
    /// Estimated execution time under the cost model (filled by the
    /// solver; the simulator computes its own ground truth).
    pub est_time_s: f64,
}

/// A complete parallelism plan for one micro-batch (paper Eq. 2's (A, C)).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Plan {
    pub groups: Vec<PlannedGroup>,
    /// Estimated makespan = max over groups of est_time_s.
    pub est_makespan_s: f64,
    /// Wall-clock the solver spent producing this plan (Tables 1–2's
    /// "Solver Time").
    pub solve_time_s: f64,
}

impl Plan {
    /// Total ranks consumed (must satisfy Eq. 6: ≤ N).
    pub fn total_degree(&self) -> usize {
        self.groups.iter().map(|g| g.degree).sum()
    }

    /// Degrees in descending order (Table 4 presentation).
    pub fn degree_multiset(&self) -> Vec<usize> {
        let mut d: Vec<usize> = self.groups.iter().map(|g| g.degree).collect();
        d.sort_unstable_by(|a, b| b.cmp(a));
        d
    }

    /// Validate the paper's constraints (4)–(6) against a micro-batch.
    pub fn validate(&self, seqs: &[Sequence], replicas: usize) -> anyhow::Result<()> {
        use anyhow::bail;
        if self.total_degree() > replicas {
            bail!(
                "Cond. (6) violated: total degree {} > N = {replicas}",
                self.total_degree()
            );
        }
        let mut seen = vec![0usize; seqs.len()];
        for g in &self.groups {
            if g.degree == 0 {
                bail!("zero-degree group");
            }
            for &i in &g.seq_idxs {
                if i >= seqs.len() {
                    bail!("sequence index {i} out of range");
                }
                seen[i] += 1;
            }
        }
        for (i, &count) in seen.iter().enumerate() {
            if count != 1 {
                bail!(
                    "Cond. (5) violated: sequence {i} assigned {count} times"
                );
            }
        }
        Ok(())
    }
}

/// Table-4-style compact rendering: "⟨8⟩×1 ⟨6⟩×2 ⟨4⟩×1 ⟨2⟩×2 ⟨1⟩×4".
pub fn format_degree_multiset(degrees: &[usize]) -> String {
    let mut out = String::new();
    let mut i = 0;
    while i < degrees.len() {
        let d = degrees[i];
        let mut count = 1;
        while i + count < degrees.len() && degrees[i + count] == d {
            count += 1;
        }
        if !out.is_empty() {
            out.push(' ');
        }
        out.push_str(&format!("<{d}>x{count}"));
        i += count;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(degrees_and_seqs: &[(usize, &[usize])]) -> Plan {
        Plan {
            groups: degrees_and_seqs
                .iter()
                .map(|&(d, idxs)| PlannedGroup {
                    degree: d,
                    seq_idxs: idxs.to_vec(),
                    agg: WorkloadAgg::default(),
                    est_time_s: 0.0,
                })
                .collect(),
            est_makespan_s: 0.0,
            solve_time_s: 0.0,
        }
    }

    fn seqs(n: usize) -> Vec<Sequence> {
        (0..n).map(|i| Sequence::new(i as u64, 10, 10)).collect()
    }

    #[test]
    fn valid_plan_passes() {
        let p = plan(&[(4, &[0, 2]), (2, &[1]), (1, &[3])]);
        p.validate(&seqs(4), 8).unwrap();
        assert_eq!(p.total_degree(), 7);
        assert_eq!(p.degree_multiset(), vec![4, 2, 1]);
    }

    #[test]
    fn over_budget_rejected() {
        let p = plan(&[(6, &[0]), (4, &[1])]);
        assert!(p.validate(&seqs(2), 8).is_err());
    }

    #[test]
    fn duplicate_assignment_rejected() {
        let p = plan(&[(2, &[0, 1]), (2, &[1])]);
        assert!(p.validate(&seqs(2), 8).is_err());
    }

    #[test]
    fn missing_assignment_rejected() {
        let p = plan(&[(2, &[0])]);
        assert!(p.validate(&seqs(2), 8).is_err());
    }

    #[test]
    fn degree_formatting_matches_table4_style() {
        assert_eq!(
            format_degree_multiset(&[8, 6, 6, 4, 2, 2, 1, 1, 1, 1]),
            "<8>x1 <6>x2 <4>x1 <2>x2 <1>x4"
        );
        assert_eq!(format_degree_multiset(&[]), "");
    }
}
