//! Plan types: the output of the DHP scheduler for one micro-batch.
//!
//! Two layers, deliberately:
//!
//! * [`Plan`]/[`PlannedGroup`] — the *logical* draft the solver's DP
//!   emits: degrees and sequence assignments, costed against the
//!   scheduler's fabric oracle ([`crate::scheduler::FabricModel`] —
//!   free-slot-aware by default, the seed's uniform heuristic on the
//!   reference path). This is what the outer search compares candidates
//!   on.
//! * [`PlacedPlan`]/[`PlacedGroup`] — the *physical* realization: every
//!   group carries its concrete rank set, the ring bandwidth of that
//!   exact set, and the `(GroupKind, ranks)` key the communication-group
//!   pool is addressed by. Estimates are re-derived against the actual
//!   placement, so the estimator-vs-simulator comparison and all
//!   downstream consumers (simulator, MPU, pipeline prewarm) see one
//!   consistent physical story — the executor never re-derives placement.

use crate::cost::{CostModel, WorkloadAgg};
use crate::data::sequence::Sequence;
use crate::parallel::group::GroupKind;
use crate::parallel::mesh::{DeviceMesh, WaveHint};
use crate::parallel::RankId;

/// One planned CP group: a degree and the sequences assigned to it.
#[derive(Debug, Clone, PartialEq)]
pub struct PlannedGroup {
    /// CP degree d_p (any positive integer — the paper's relaxation).
    pub degree: usize,
    /// Indices into the micro-batch's sequence list.
    pub seq_idxs: Vec<usize>,
    /// Cached workload aggregates of the assigned sequences.
    pub agg: WorkloadAgg,
    /// Estimated execution time under the cost model (filled by the
    /// solver; the simulator computes its own ground truth).
    pub est_time_s: f64,
}

/// A complete logical parallelism plan for one micro-batch (paper Eq. 2's
/// (A, C)) — degrees only, not yet bound to ranks.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Plan {
    /// The planned CP groups (degrees + sequence assignments).
    pub groups: Vec<PlannedGroup>,
    /// Estimated makespan = max over groups of est_time_s.
    pub est_makespan_s: f64,
    /// Wall-clock the solver spent producing this plan (Tables 1–2's
    /// "Solver Time").
    pub solve_time_s: f64,
}

impl Plan {
    /// Total ranks consumed (must satisfy Eq. 6: ≤ N).
    pub fn total_degree(&self) -> usize {
        self.groups.iter().map(|g| g.degree).sum()
    }

    /// Degrees in descending order (Table 4 presentation).
    pub fn degree_multiset(&self) -> Vec<usize> {
        let mut d: Vec<usize> = self.groups.iter().map(|g| g.degree).collect();
        d.sort_unstable_by(|a, b| b.cmp(a));
        d
    }

    /// Validate the paper's constraints (4)–(6) against a micro-batch.
    pub fn validate(&self, seqs: &[Sequence], replicas: usize) -> anyhow::Result<()> {
        use anyhow::bail;
        if self.total_degree() > replicas {
            bail!(
                "Cond. (6) violated: total degree {} > N = {replicas}",
                self.total_degree()
            );
        }
        let mut seen = vec![0usize; seqs.len()];
        for g in &self.groups {
            if g.degree == 0 {
                bail!("zero-degree group");
            }
            for &i in &g.seq_idxs {
                if i >= seqs.len() {
                    bail!("sequence index {i} out of range");
                }
                seen[i] += 1;
            }
        }
        for (i, &count) in seen.iter().enumerate() {
            if count != 1 {
                bail!(
                    "Cond. (5) violated: sequence {i} assigned {count} times"
                );
            }
        }
        Ok(())
    }
}

/// One physically realized CP group: the planned group plus the rank set
/// the mesh assigned it and the placement-aware cost estimate.
#[derive(Debug, Clone, PartialEq)]
pub struct PlacedGroup {
    /// CP degree d_p (equals `ranks.len()`).
    pub degree: usize,
    /// Indices into the micro-batch's sequence list.
    pub seq_idxs: Vec<usize>,
    /// Cached workload aggregates of the assigned sequences.
    pub agg: WorkloadAgg,
    /// Placement-aware estimate: `T(agg, degree, ring_bw)` of the ACTUAL
    /// rank set (empty groups — a static mesh's idle slots — cost 0).
    pub est_time_s: f64,
    /// Member replica ranks, sorted ascending (the group's identity).
    pub ranks: Vec<RankId>,
    /// Ring bandwidth of the slowest link among `ranks`.
    pub ring_bw: f64,
}

impl PlacedGroup {
    /// The communication-group pool key this group resolves to.
    pub fn pool_key(&self) -> (GroupKind, Vec<RankId>) {
        (GroupKind::ContextParallel, self.ranks.clone())
    }
}

/// A physically realized wave: what the executor (simulator, MPU,
/// pipeline prewarm) consumes directly — no re-allocation downstream.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct PlacedPlan {
    /// The wave's placed groups, in plan order.
    pub groups: Vec<PlacedGroup>,
    /// Placement-aware makespan = max over groups of est_time_s.
    pub est_makespan_s: f64,
    /// The DP's pre-placement objective for this wave, costed against
    /// the solve's fabric snapshot — retained so candidate-search
    /// behavior stays comparable against the (uniform-oracle) reference
    /// solver.
    pub search_makespan_s: f64,
    /// Hint-quality telemetry: how many of this wave's groups were placed
    /// by replaying the previous step's rank block (see
    /// [`crate::parallel::mesh::Placement`]). Replayed groups key into
    /// already-pooled communication groups, so a low replay rate flags
    /// placement churn as distinct from workload drift.
    pub replayed_groups: usize,
}

impl PlacedPlan {
    /// Total ranks consumed by the wave (must satisfy Eq. 6: ≤ N).
    pub fn total_degree(&self) -> usize {
        self.groups.iter().map(|g| g.degree).sum()
    }

    /// Degrees in descending order (Table 4 presentation).
    pub fn degree_multiset(&self) -> Vec<usize> {
        let mut d: Vec<usize> = self.groups.iter().map(|g| g.degree).collect();
        d.sort_unstable_by(|a, b| b.cmp(a));
        d
    }

    /// Placement invariants: per-group arity (|ranks| = degree), ranks in
    /// range, and pairwise disjointness within the wave (Cond. 6 on the
    /// physical representation).
    pub fn validate_placement(&self, replicas: usize) -> anyhow::Result<()> {
        use anyhow::bail;
        if self.total_degree() > replicas {
            bail!(
                "placed wave over rank budget: {} > {replicas}",
                self.total_degree()
            );
        }
        let mut seen = vec![false; replicas];
        for (gi, g) in self.groups.iter().enumerate() {
            if g.degree == 0 {
                bail!("zero-degree group {gi}");
            }
            if g.ranks.len() != g.degree {
                bail!(
                    "group {gi}: {} ranks != degree {}",
                    g.ranks.len(),
                    g.degree
                );
            }
            for &r in &g.ranks {
                if r >= replicas {
                    bail!("group {gi}: rank {r} out of range (N = {replicas})");
                }
                if seen[r] {
                    bail!("group {gi}: rank {r} placed twice in one wave");
                }
                seen[r] = true;
            }
        }
        Ok(())
    }
}

/// Bind a logical plan to ranks: place every group on the mesh (steered
/// by `hint` — the blocks this wave slot used last step) and re-derive
/// each group's estimate against the ring bandwidth of its ACTUAL rank
/// set. This is the single point where plans become physical; everything
/// downstream (simulator, pool, MPU) consumes the result as-is.
pub fn place_plan(
    plan: &Plan,
    mesh: &DeviceMesh,
    hint: Option<&WaveHint>,
    cost: &CostModel,
) -> PlacedPlan {
    let degrees: Vec<usize> = plan.groups.iter().map(|g| g.degree).collect();
    let placement = mesh.place_tracked(&degrees, hint);
    let mut groups = Vec::with_capacity(plan.groups.len());
    let mut makespan = 0.0f64;
    for (g, ranks) in plan.groups.iter().zip(placement.blocks) {
        let ring_bw = mesh.ring_bandwidth(&ranks);
        let est = if g.seq_idxs.is_empty() {
            0.0
        } else {
            cost.t_total(&g.agg, g.degree, ring_bw)
        };
        makespan = makespan.max(est);
        groups.push(PlacedGroup {
            degree: g.degree,
            seq_idxs: g.seq_idxs.clone(),
            agg: g.agg,
            est_time_s: est,
            ranks,
            ring_bw,
        });
    }
    PlacedPlan {
        groups,
        est_makespan_s: makespan,
        search_makespan_s: plan.est_makespan_s,
        replayed_groups: placement.replayed,
    }
}

/// Table-4-style compact rendering: "⟨8⟩×1 ⟨6⟩×2 ⟨4⟩×1 ⟨2⟩×2 ⟨1⟩×4".
pub fn format_degree_multiset(degrees: &[usize]) -> String {
    let mut out = String::new();
    let mut i = 0;
    while i < degrees.len() {
        let d = degrees[i];
        let mut count = 1;
        while i + count < degrees.len() && degrees[i + count] == d {
            count += 1;
        }
        if !out.is_empty() {
            out.push(' ');
        }
        out.push_str(&format!("<{d}>x{count}"));
        i += count;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::by_name;
    use crate::config::{ClusterConfig, TrainStage};
    use crate::cost::{CostCoeffs, HardwareSpec, MemoryModel};

    fn plan(degrees_and_seqs: &[(usize, &[usize])]) -> Plan {
        Plan {
            groups: degrees_and_seqs
                .iter()
                .map(|&(d, idxs)| PlannedGroup {
                    degree: d,
                    seq_idxs: idxs.to_vec(),
                    agg: WorkloadAgg::default(),
                    est_time_s: 0.0,
                })
                .collect(),
            est_makespan_s: 0.0,
            solve_time_s: 0.0,
        }
    }

    fn seqs(n: usize) -> Vec<Sequence> {
        (0..n).map(|i| Sequence::new(i as u64, 10, 10)).collect()
    }

    fn cost_model() -> CostModel {
        let preset = by_name("InternVL3-8B").unwrap();
        CostModel {
            coeffs: CostCoeffs::analytic(
                &preset,
                TrainStage::Full,
                &HardwareSpec::default(),
            ),
            memory: MemoryModel::new(&preset, 64e9, 8),
        }
    }

    #[test]
    fn valid_plan_passes() {
        let p = plan(&[(4, &[0, 2]), (2, &[1]), (1, &[3])]);
        p.validate(&seqs(4), 8).unwrap();
        assert_eq!(p.total_degree(), 7);
        assert_eq!(p.degree_multiset(), vec![4, 2, 1]);
    }

    #[test]
    fn over_budget_rejected() {
        let p = plan(&[(6, &[0]), (4, &[1])]);
        assert!(p.validate(&seqs(2), 8).is_err());
    }

    #[test]
    fn duplicate_assignment_rejected() {
        let p = plan(&[(2, &[0, 1]), (2, &[1])]);
        assert!(p.validate(&seqs(2), 8).is_err());
    }

    #[test]
    fn missing_assignment_rejected() {
        let p = plan(&[(2, &[0])]);
        assert!(p.validate(&seqs(2), 8).is_err());
    }

    #[test]
    fn degree_formatting_matches_table4_style() {
        assert_eq!(
            format_degree_multiset(&[8, 6, 6, 4, 2, 2, 1, 1, 1, 1]),
            "<8>x1 <6>x2 <4>x1 <2>x2 <1>x4"
        );
        assert_eq!(format_degree_multiset(&[]), "");
    }

    #[test]
    fn place_plan_binds_ranks_and_rescoring_uses_actual_bandwidth() {
        // 8 nodes × 8 NPUs, TP=PP=1 → 8 replicas/node, 64 replicas.
        let mesh = DeviceMesh::new(&ClusterConfig::default());
        let cost = cost_model();
        let s = seqs(3);
        let mut p = plan(&[(10, &[0]), (4, &[1]), (1, &[2])]);
        for g in &mut p.groups {
            g.agg = WorkloadAgg::of(&[s[g.seq_idxs[0]].clone()]);
        }
        let placed = place_plan(&p, &mesh, None, &cost);
        placed.validate_placement(64).unwrap();
        assert_eq!(placed.groups.len(), 3);
        // Degree 10 spans nodes → inter bandwidth; degree 4 fits → intra.
        assert_eq!(placed.groups[0].ring_bw, mesh.inter_bw);
        assert_eq!(placed.groups[1].ring_bw, mesh.intra_bw);
        for g in &placed.groups {
            assert_eq!(g.ranks.len(), g.degree);
            let expected = cost.t_total(&g.agg, g.degree, g.ring_bw);
            assert_eq!(g.est_time_s.to_bits(), expected.to_bits());
        }
        assert!(placed.est_makespan_s >= placed.groups[0].est_time_s);
    }

    #[test]
    fn placement_validation_rejects_overlap_and_bad_arity() {
        let g = |degree: usize, ranks: Vec<RankId>| PlacedGroup {
            degree,
            seq_idxs: vec![],
            agg: WorkloadAgg::default(),
            est_time_s: 0.0,
            ranks,
            ring_bw: 1.0,
        };
        let overlap = PlacedPlan {
            groups: vec![g(2, vec![0, 1]), g(2, vec![1, 2])],
            est_makespan_s: 0.0,
            search_makespan_s: 0.0,
            replayed_groups: 0,
        };
        assert!(overlap.validate_placement(8).is_err());
        let arity = PlacedPlan {
            groups: vec![g(3, vec![0, 1])],
            est_makespan_s: 0.0,
            search_makespan_s: 0.0,
            replayed_groups: 0,
        };
        assert!(arity.validate_placement(8).is_err());
        let range = PlacedPlan {
            groups: vec![g(1, vec![9])],
            est_makespan_s: 0.0,
            search_makespan_s: 0.0,
            replayed_groups: 0,
        };
        assert!(range.validate_placement(8).is_err());
        let ok = PlacedPlan {
            groups: vec![g(2, vec![0, 1]), g(1, vec![7])],
            est_makespan_s: 0.0,
            search_makespan_s: 0.0,
            replayed_groups: 0,
        };
        ok.validate_placement(8).unwrap();
    }

    #[test]
    fn empty_groups_cost_nothing_when_placed() {
        let mesh = DeviceMesh::uniform(8, 12.5e9);
        let cost = cost_model();
        let p = plan(&[(4, &[]), (4, &[])]);
        let placed = place_plan(&p, &mesh, None, &cost);
        for g in &placed.groups {
            assert_eq!(g.est_time_s, 0.0);
        }
        assert_eq!(placed.est_makespan_s, 0.0);
    }
}
