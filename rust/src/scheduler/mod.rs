//! The DHP scheduler (paper §4–§5): the Layer-3 coordination contribution.
//!
//! Pipeline per micro-batch (Fig. 3): memory-aware BFD packing
//! ([`packing`]) → feasibility waves → 2D-DP degree allocation ([`dp`]) →
//! plan assembly and executor preparation (group acquisition through the
//! pool + per-rank data dispatch). The [`pipeline`] module runs all of
//! this asynchronously on a CPU thread while the accelerator executes the
//! previous batch.

pub mod dp;
pub mod packing;
pub mod pipeline;
pub mod plan;

use std::time::Instant;

use crate::cost::CostModel;
use crate::data::sequence::Sequence;
use crate::parallel::mesh::DeviceMesh;

pub use dp::{any_degree, pow2_degree, DpSolution};
pub use plan::{format_degree_multiset, Plan, PlannedGroup};

/// Degree admissibility policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DegreePolicy {
    /// Any positive integer (DHP's Ring-CP relaxation).
    AnyInteger,
    /// Powers of two only (Ulysses head-divisibility restriction;
    /// used by the FlexSP-style baseline).
    PowerOfTwo,
}

impl DegreePolicy {
    pub fn admits(&self, d: usize) -> bool {
        match self {
            DegreePolicy::AnyInteger => true,
            DegreePolicy::PowerOfTwo => d.is_power_of_two(),
        }
    }

    /// Smallest admissible degree ≥ `d` — what a policy-restricted system
    /// must ROUND UP to (the rank waste DHP's relaxation removes).
    pub fn min_admissible(&self, d: usize) -> usize {
        match self {
            DegreePolicy::AnyInteger => d,
            DegreePolicy::PowerOfTwo => d.next_power_of_two(),
        }
    }
}

/// A full schedule for one micro-batch: one or more waves, each a [`Plan`]
/// whose rank demand fits the cluster.
#[derive(Debug, Clone, Default)]
pub struct Schedule {
    pub waves: Vec<Plan>,
    /// Pure solver wall-clock (packing + DP) — Tables 1–2 "Solver Time".
    pub solve_time_s: f64,
    /// Estimated execution makespan summed over waves.
    pub est_time_s: f64,
}

impl Schedule {
    /// Degrees across all waves, descending (Table 4 presentation).
    pub fn degree_multiset(&self) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .waves
            .iter()
            .flat_map(|p| p.groups.iter().map(|g| g.degree))
            .collect();
        out.sort_unstable_by(|a, b| b.cmp(a));
        out
    }

    pub fn validate(&self, seqs: &[Sequence], replicas: usize) -> anyhow::Result<()> {
        // Union of waves must cover each sequence exactly once.
        let mut seen = vec![0usize; seqs.len()];
        for p in &self.waves {
            if p.total_degree() > replicas {
                anyhow::bail!("wave over rank budget");
            }
            for g in &p.groups {
                for &i in &g.seq_idxs {
                    seen[i] += 1;
                }
            }
        }
        if let Some(i) = seen.iter().position(|&c| c != 1) {
            anyhow::bail!("sequence {i} covered {} times", seen[i]);
        }
        Ok(())
    }
}

/// The DHP scheduler: owns the cost model and placement heuristics.
#[derive(Debug, Clone)]
pub struct Scheduler {
    pub cost: CostModel,
    pub mesh: DeviceMesh,
    pub policy: DegreePolicy,
}

impl Scheduler {
    pub fn new(cost: CostModel, mesh: DeviceMesh) -> Self {
        Scheduler {
            cost,
            mesh,
            policy: DegreePolicy::AnyInteger,
        }
    }

    pub fn with_policy(mut self, policy: DegreePolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Plan-time ring-bandwidth heuristic: a group of degree d placed by
    /// the mesh lands intra-node iff d fits within one node.
    fn bw_for_degree(&self, d: usize) -> f64 {
        if d <= self.mesh.replicas_per_node {
            self.mesh.intra_bw
        } else {
            self.mesh.inter_bw
        }
    }

    /// Run the full two-stage algorithm on one micro-batch.
    ///
    /// The balance-target outer search: packing is memory-driven, but the
    /// *granularity* of atomic groups trades ring-communication overhead
    /// (few fat groups → long rings) against load-balance freedom (many
    /// thin groups → DP can spread). We run Stage 1 + Stage 2 for a small
    /// set of group-count targets (each solve O(K'·N²), all together
    /// still millisecond-scale) and keep the best estimated schedule.
    pub fn schedule(&self, seqs: &[Sequence]) -> Schedule {
        let t0 = Instant::now();
        let n = self.mesh.replicas;
        // Candidate targets: every integer up to 16 (cheap, and covers
        // every static-grid shape at small N), powers of two beyond, and
        // N itself.
        let mut targets: Vec<usize> = (1..=n.min(16)).collect();
        let mut p = 32usize;
        while p <= n {
            targets.push(p);
            p *= 2;
        }
        if !targets.contains(&n) {
            targets.push(n);
        }
        let mut best: Option<Schedule> = None;
        let consider = |candidate: Schedule, best: &mut Option<Schedule>| {
            match best {
                Some(b) if b.est_time_s <= candidate.est_time_s => {}
                _ => *best = Some(candidate),
            }
        };
        for target in targets {
            consider(self.schedule_with_target(seqs, target), &mut best);
        }
        // Uniform static-grid candidates (degree d for every group, LPT
        // composition): a dynamic scheduler must never lose to a static
        // grid it can emulate — these anchor the search at the baselines'
        // best configurations, which the DP then refines.
        let mut d = 1usize;
        while d <= n {
            if n % d == 0 {
                if let Some(candidate) = self.uniform_grid_schedule(seqs, d) {
                    consider(candidate, &mut best);
                }
            }
            d *= 2;
        }
        let mut out = best.unwrap_or_default();
        out.solve_time_s = t0.elapsed().as_secs_f64();
        out
    }

    /// Build a uniform-grid candidate: N/d groups of degree d per wave,
    /// sequences LPT-assigned by quadratic work subject to Eq. 3's memory
    /// cap. Returns None if the longest sequence cannot fit degree d.
    fn uniform_grid_schedule(&self, seqs: &[Sequence], d: usize) -> Option<Schedule> {
        let n = self.mesh.replicas;
        if !self.policy.admits(d) {
            return None;
        }
        let cap_tokens = {
            let budget = self.cost.memory.rank_budget() * d as f64;
            (budget / self.cost.memory.m_token).floor() as u64
        };
        if seqs.iter().any(|s| s.len() > cap_tokens) {
            return None;
        }
        let n_groups = (n / d).max(1);
        let mut order: Vec<usize> = (0..seqs.len()).collect();
        order.sort_by(|&a, &b| seqs[b].len().cmp(&seqs[a].len()).then(a.cmp(&b)));

        struct Bin {
            idxs: Vec<usize>,
            tokens: u64,
            agg: crate::cost::WorkloadAgg,
        }
        let mut waves: Vec<Vec<Bin>> = vec![(0..n_groups)
            .map(|_| Bin {
                idxs: vec![],
                tokens: 0,
                agg: Default::default(),
            })
            .collect()];
        for &i in &order {
            let s = &seqs[i];
            loop {
                let wave = waves.last_mut().unwrap();
                let mut best: Option<usize> = None;
                for (bi, b) in wave.iter().enumerate() {
                    if b.tokens + s.len() <= cap_tokens || b.idxs.is_empty() {
                        match best {
                            Some(p) if wave[p].agg.quad <= b.agg.quad => {}
                            _ => best = Some(bi),
                        }
                    }
                }
                if let Some(bi) = best {
                    let b = &mut wave[bi];
                    b.idxs.push(i);
                    b.tokens += s.len();
                    b.agg.add(s);
                    break;
                }
                waves.push(
                    (0..n_groups)
                        .map(|_| Bin {
                            idxs: vec![],
                            tokens: 0,
                            agg: Default::default(),
                        })
                        .collect(),
                );
            }
        }

        let bw = self.bw_for_degree(d);
        let mut out = Schedule::default();
        for wave in waves {
            let mut plan = Plan::default();
            for b in wave {
                if b.idxs.is_empty() {
                    continue;
                }
                let est = self.cost.t_total(&b.agg, d, bw);
                plan.groups.push(PlannedGroup {
                    degree: d,
                    seq_idxs: b.idxs,
                    agg: b.agg,
                    est_time_s: est,
                });
            }
            plan.est_makespan_s = plan
                .groups
                .iter()
                .map(|g| g.est_time_s)
                .fold(0.0f64, f64::max);
            out.est_time_s += plan.est_makespan_s;
            out.waves.push(plan);
        }
        Some(out)
    }

    /// One pack→DP pass at a fixed group-count target (public for
    /// ablation benches and diagnostics).
    pub fn schedule_with_target(&self, seqs: &[Sequence], group_target: usize) -> Schedule {
        let n = self.mesh.replicas;
        let mut groups =
            packing::pack_with_target(seqs, &self.cost.memory, n, group_target);
        // Policy-restricted systems must round minimum degrees up to the
        // admissible set (e.g. pow2) BEFORE wave feasibility is decided.
        for g in &mut groups {
            g.d_min = self.policy.min_admissible(g.d_min).min(n);
        }
        let waves = packing::waves(groups, n);

        let mut out = Schedule::default();
        for wave in waves {
            let policy = self.policy;
            let sol = dp::allocate_degrees(
                &wave,
                n,
                |i, d| self.cost.t_total(&wave[i].agg, d, self.bw_for_degree(d)),
                |d| policy.admits(d),
            );
            let mut plan = Plan::default();
            for (g, &d) in wave.iter().zip(&sol.degrees) {
                plan.groups.push(PlannedGroup {
                    degree: d,
                    seq_idxs: g.seq_idxs.clone(),
                    agg: g.agg,
                    est_time_s: self.cost.t_total(&g.agg, d, self.bw_for_degree(d)),
                });
            }
            plan.est_makespan_s = sol.makespan_s;
            out.est_time_s += sol.makespan_s;
            out.waves.push(plan);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::by_name;
    use crate::config::{ClusterConfig, TrainStage};
    use crate::cost::{CostCoeffs, HardwareSpec, MemoryModel};
    use crate::data::datasets::{DatasetKind, DatasetSampler, TokenizerSpec};
    use crate::util::quickcheck::forall;
    use crate::util::rng::Rng;

    /// High-res video tokenization (2 fps × 256 tokens/frame): the
    /// long-context regime where sequences span 1k-180k tokens and mixed
    /// CP degrees pay off.
    fn sampler(kind: DatasetKind, seed: u64) -> DatasetSampler {
        DatasetSampler::new(kind, seed).with_spec(TokenizerSpec {
            fps: 2.0,
            tokens_per_frame: 256.0,
            text_min: 32,
            text_max: 512,
        })
    }

    fn scheduler(replicas: usize) -> Scheduler {
        // Paper regime: one replica = TP×PP = 4 NPUs, 2 replicas/node —
        // CP degrees ≥ 3 cross nodes and ride the slow interconnect.
        let mut cluster = ClusterConfig::default().with_npus(replicas * 4);
        cluster.tp = 2;
        cluster.pp = 2;
        let preset = by_name("InternVL3-8B").unwrap();
        // Per-replica FLOPs aggregate the TP*PP member NPUs.
        let hw = HardwareSpec {
            peak_flops: 376e12 * 4.0,
            ..HardwareSpec::default()
        };
        let cost = CostModel {
            coeffs: CostCoeffs::analytic(&preset, TrainStage::Full, &hw),
            memory: MemoryModel {
                e_bytes: 8192.0 * preset.act_bytes_per_token() + 2e9,
                m_states: 2e9,
                m_token: preset.act_bytes_per_token(),
            },
        };
        Scheduler::new(cost, DeviceMesh::new(&cluster))
    }

    #[test]
    fn schedule_covers_all_sequences() {
        let sch = scheduler(16);
        let mut sampler = sampler(DatasetKind::OpenVid, 31);
        let seqs = sampler.sample_batch(64);
        let schedule = sch.schedule(&seqs);
        schedule.validate(&seqs, 16).unwrap();
        assert!(!schedule.waves.is_empty());
        assert!(schedule.solve_time_s < 1.0);
    }

    #[test]
    fn skewed_data_produces_mixed_degrees() {
        // The Table 4 phenomenon: OpenVid's skew should yield a rich
        // multiset of degrees, not a uniform one. Uses the realistic
        // cluster context (calibrated cost model, paper memory budget).
        use crate::experiments::harness::ExpContext;
        let ctx = ExpContext::new(
            by_name("InternVL3-8B").unwrap(),
            DatasetKind::OpenVid,
            32,
            TrainStage::Full,
        );
        let sch = ctx.dhp();
        // Heterogeneity is workload-dependent; over a few draws at least
        // one schedule must use mixed degrees (a static mesh never can).
        let mut saw_mixed = false;
        let mut all_degrees = Vec::new();
        for seed in [0xD4Bu64, 0x7AB4, 37] {
            let mut ctx2 = ctx.clone();
            ctx2.seed = seed;
            // Schedule at micro-batch granularity (the planner's output):
            // memory-full micro-batches are where heterogeneity pays off.
            let mut sampler = ctx2.sampler();
            let batch = crate::data::batch::GlobalBatch {
                step: 0,
                sequences: sampler.sample_batch(128),
            };
            for mb in ctx2.micro_batch_planner().plan(&batch) {
                let schedule = sch.schedule(&mb.sequences);
                let degrees = schedule.degree_multiset();
                let distinct: std::collections::HashSet<usize> =
                    degrees.iter().copied().collect();
                saw_mixed |= distinct.len() >= 2;
                all_degrees.push(degrees);
            }
        }
        assert!(
            saw_mixed,
            "expected heterogeneous degrees in at least one draw: {all_degrees:?}"
        );
    }

    #[test]
    fn pow2_policy_restricts_degrees() {
        let sch = scheduler(8).with_policy(DegreePolicy::PowerOfTwo);
        let mut sampler = sampler(DatasetKind::OpenVid, 41);
        let seqs = sampler.sample_batch(32);
        let schedule = sch.schedule(&seqs);
        for d in schedule.degree_multiset() {
            assert!(d.is_power_of_two(), "degree {d} not a power of two");
        }
    }

    #[test]
    fn any_integer_beats_pow2_on_average() {
        // DHP's generalized degrees must never lose to the pow2-restricted
        // search, must exploit non-pow2 degrees on skewed data, and must
        // win measurably over a workload sample.
        use crate::experiments::harness::ExpContext;
        let ctx = ExpContext::new(
            by_name("InternVL3-8B").unwrap(),
            DatasetKind::OpenVid,
            32,
            TrainStage::Full,
        );
        let dhp = ctx.dhp();
        let pow2 = ctx.dhp().with_policy(DegreePolicy::PowerOfTwo);
        let mut total_dhp = 0.0;
        let mut total_pow2 = 0.0;
        let mut used_non_pow2 = false;
        for seed in 0..10 {
            let mut sampler = ctx.sampler();
            let mut skip = Rng::new(seed);
            let _ = skip.next_u64();
            let seqs = sampler.sample_batch(32 + (seed as usize) * 4);
            let s_dhp = dhp.schedule(&seqs);
            used_non_pow2 |= s_dhp
                .degree_multiset()
                .iter()
                .any(|d| !d.is_power_of_two());
            total_dhp += s_dhp.est_time_s;
            total_pow2 += pow2.schedule(&seqs).est_time_s;
        }
        assert!(
            total_dhp <= total_pow2 * 1.0001,
            "dhp {total_dhp} vs pow2 {total_pow2}"
        );
        assert!(
            total_dhp < total_pow2 * 0.999,
            "expected measurable gain: dhp {total_dhp} vs pow2 {total_pow2}"
        );
        assert!(used_non_pow2, "DHP never used a non-pow2 degree");
    }

    #[test]
    fn property_schedule_always_valid() {
        forall(25, 0x5CED, |rng| {
            let npus = *rng.choose(&[8usize, 16, 32, 64]);
            let sch = scheduler(npus);
            let kind = *rng.choose(&DatasetKind::all());
            let n = rng.range_usize(1, 96);
            let mut sampler = sampler(kind, rng.next_u64());
            let seqs = sampler.sample_batch(n);
            let schedule = sch.schedule(&seqs);
            schedule
                .validate(&seqs, npus)
                .map_err(|e| format!("{e} (npus={npus}, n={n})"))?;
            // Makespan estimates must be positive and finite.
            for p in &schedule.waves {
                if !(p.est_makespan_s.is_finite() && p.est_makespan_s > 0.0) {
                    return Err(format!("bad makespan {}", p.est_makespan_s));
                }
            }
            Ok(())
        });
    }
}
