//! The DHP scheduler (paper §4–§5): the Layer-3 coordination contribution.
//!
//! Pipeline per micro-batch (Fig. 3): memory-aware BFD packing
//! ([`packing`]) → feasibility waves → 2D-DP degree allocation ([`dp`]) →
//! **placement** (rank binding + placement-aware re-estimation,
//! [`plan::place_plan`]) → executor preparation (group prewarm through
//! the pool + per-rank data dispatch). The [`pipeline`] module runs all
//! of this asynchronously on a CPU thread while the accelerator executes
//! the previous batch.
//!
//! The scheduler emits *placed* schedules: every [`PlacedGroup`] carries
//! its concrete rank set, the ring bandwidth of that exact set, and the
//! pool key it resolves to. Placement is reuse-aware — each wave slot
//! prefers the rank blocks it used on the previous step (see
//! [`crate::parallel::mesh::WaveHint`]), so a stationary workload's
//! groups keep hitting the communication-group pool and reconfiguration
//! cost amortizes to nothing, exactly the paper's §5 claim.
//!
//! # The fabric oracle (post ISSUE-4)
//!
//! Every bandwidth the solver costs against comes from ONE
//! [`FabricModel`] snapshot acquired at the top of each `schedule()`
//! call ([`fabric`]): the DP's per-transition cost query, the pruning
//! bounds, and the uniform-grid anchors all ask the same oracle, and the
//! same snapshot's rank budget ([`FabricModel::capacity`] — the *free*
//! replicas) bounds packing, wave splitting, and the DP. By default the
//! oracle is mesh-backed ([`FabricKind::MeshBacked`]): it answers from
//! the mesh's current free-slot census (plus still-free hint-replayable
//! blocks), so on a fragmented mesh the search objective prices the slow
//! fabric a placed group will actually ride — the `est_time_s` and
//! `search_est_time_s` numbers become one lineage instead of an
//! optimistic search estimate corrected after placement. The seed's
//! uniform heuristic survives as [`FabricKind::Uniform`], the reference
//! oracle ([`Scheduler::schedule_reference`] always uses it); on an
//! unfragmented mesh the two oracles answer identically, which keeps the
//! reference-equality tests bit-exact.
//!
//! # Solver architecture (post ISSUE-1 hot-path overhaul)
//!
//! The paper's claim that plans cost "only millisecond-level overhead per
//! training batch" is carried by four mechanisms layered over the
//! two-stage algorithm:
//!
//! 1. **Linear-transition DP** — [`dp::allocate_degrees`] solves an
//!    *at-most-j-ranks* reformulation whose transition matrix is totally
//!    monotone: the optimal slot's crossing point only moves right as the
//!    rank budget grows, so one cursor swept across each row finds every
//!    cell's optimum in O(1) amortized — O(K′·N) per wave instead of the
//!    paper's O(K′·N²). The prefix-min + binary-search transition
//!    (O(K′·N·log N)) survives as [`dp::allocate_degrees_prefixmin`] and
//!    the exact-j formulation as [`dp::allocate_degrees_reference`] —
//!    both bit-equivalence oracles and bench baselines.
//! 2. **Scratch arena** — every worker threads a pooled
//!    [`scratch::SolverScratch`] through packing and DP
//!    ([`Scheduler::schedule_with_target_in`]), so the steady-state
//!    planner reuses DP tables, bin index vectors, and wave containers
//!    instead of reallocating them per candidate (only the returned
//!    `Schedule` still owns fresh vectors).
//! 3. **Memoized cost model** — `T(agg, d, bw)` evaluations go through a
//!    content-keyed [`scratch::CostCache`]; the same atomic groups recur
//!    across the balance-target outer search (and across consecutive
//!    micro-batches), so most DP transitions after the first candidate
//!    hit the cache instead of re-deriving Eqs. 8–10.
//! 4. **Parallel pruned outer search on a persistent pool** — the
//!    candidate targets and uniform-grid anchors are solved by
//!    long-lived workers ([`search_pool::SearchPool`]) stealing
//!    candidate indices off a shared counter, with an incumbent best
//!    (lock-free f64-bits `fetch_min`) and a per-candidate lower bound
//!    (aggregate-work/N, best-single-group-time, and a communication
//!    floor at each group's minimum degree) that skips candidates which
//!    provably cannot win. The pipeline owns a pool per scheduling
//!    thread (bare `schedule()` calls share a lazily-created global
//!    one), so the steady state spawns zero threads per solve — the
//!    seed's per-batch `thread::scope` spawn tax is gone. Selection is
//!    by (estimated time, candidate index), which makes the result
//!    bit-identical to the sequential first-wins search regardless of
//!    worker timing: a pruned candidate's bound strictly exceeded a
//!    then-current incumbent, which is ≥ the final best, so it could
//!    never have been selected.
//! 5. **Incremental packing across the target sweep** — the candidate
//!    targets are packed in ascending order through one
//!    [`packing::TargetSweep`], which proves most repacks redundant (a
//!    packing is reused verbatim while every placement's feasibility
//!    threshold stays under the next work cap) instead of running BFD
//!    from scratch per target.
//! 6. **Cross-step solver reuse** ([`schedule_cache`], ISSUE-9) — the
//!    solver is also *temporally* incremental: an exact-hit schedule
//!    cache serves recurring batch shapes without touching the search
//!    pool (bit-identical to re-solving), cache misses seed the
//!    search's incumbent with the re-costed previous plan so mechanism
//!    4's pruning fires from candidate 0 (a post-search guard keeps the
//!    selection bit-identical to the cold search), and an opt-in
//!    ε-bounded fast path can skip the search entirely when the
//!    previous plan provably lands within `(1+ε)` of a batch-global
//!    lower bound.

pub mod dp;
pub mod fabric;
pub mod packing;
pub mod pipeline;
pub mod plan;
pub mod schedule_cache;
pub mod scratch;
pub mod search_pool;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::cost::{CostModel, WorkloadAgg};
use crate::data::sequence::Sequence;
use crate::parallel::mesh::{DeviceMesh, PlacementHint, WaveHint};

use packing::AtomicGroup;
use scratch::CostCache;

pub use dp::{any_degree, pow2_degree, DpSolution};
pub use fabric::{FabricKind, FabricModel};
pub use plan::{
    format_degree_multiset, place_plan, PlacedGroup, PlacedPlan, Plan,
    PlannedGroup,
};
pub use schedule_cache::SolveStats;
pub use scratch::{solver_threads, SolverScratch};
pub use search_pool::SearchPool;

use schedule_cache::ReuseState;

/// Degree admissibility policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DegreePolicy {
    /// Any positive integer (DHP's Ring-CP relaxation).
    AnyInteger,
    /// Powers of two only (Ulysses head-divisibility restriction;
    /// used by the FlexSP-style baseline).
    PowerOfTwo,
}

impl DegreePolicy {
    /// Is degree `d` admissible under this policy?
    pub fn admits(&self, d: usize) -> bool {
        match self {
            DegreePolicy::AnyInteger => true,
            DegreePolicy::PowerOfTwo => d.is_power_of_two(),
        }
    }

    /// Smallest admissible degree ≥ `d` — what a policy-restricted system
    /// must ROUND UP to (the rank waste DHP's relaxation removes).
    pub fn min_admissible(&self, d: usize) -> usize {
        match self {
            DegreePolicy::AnyInteger => d,
            DegreePolicy::PowerOfTwo => d.next_power_of_two(),
        }
    }
}

/// A full, physically realized schedule for one micro-batch: one or more
/// waves, each a [`PlacedPlan`] whose rank sets are concrete, disjoint,
/// and within budget. This is what every executor consumes — the
/// simulator, the MPU, and the pipeline's group prewarm all read the
/// placement off the schedule instead of re-deriving it.
#[derive(Debug, Clone, Default)]
pub struct Schedule {
    /// The placed waves, executed serially over the full cluster.
    pub waves: Vec<PlacedPlan>,
    /// Pure solver wall-clock (packing + DP + placement) — Tables 1–2
    /// "Solver Time".
    pub solve_time_s: f64,
    /// Placement-aware estimated execution time: Σ placed wave makespans
    /// (each group costed at the ring bandwidth of its actual rank set).
    pub est_time_s: f64,
    /// The outer search's pre-placement objective, costed against the
    /// scheduler's fabric oracle. On the mesh-backed default this is the
    /// same lineage as `est_time_s` — the search already priced the
    /// bandwidth the placement delivers (they coincide exactly whenever
    /// the free-slot census fully determines each group's locality); on
    /// the uniform reference oracle it is the seed's heuristic estimate,
    /// exactly comparable against the retained reference solver.
    pub search_est_time_s: f64,
    /// Cross-step reuse provenance ([`schedule_cache`]): exact cache
    /// hit, warm-started search, ε fast path, or cold — plus candidate
    /// and pruning counters. Telemetry only; deliberately excluded from
    /// [`crate::session::StepReport::digest`].
    pub stats: SolveStats,
}

impl Schedule {
    /// Hint-quality telemetry: the fraction of this schedule's placed
    /// groups whose rank block was replayed from the previous step's
    /// placement ([`crate::parallel::mesh::WaveHint`]). Replayed groups
    /// key into already-pooled communication groups, so a drop in replay
    /// rate attributes pool misses to placement churn rather than genuine
    /// workload drift. 0 for an empty schedule (and for the first step,
    /// which has no previous placement to replay).
    pub fn replay_rate(&self) -> f64 {
        let total: usize = self.waves.iter().map(|w| w.groups.len()).sum();
        if total == 0 {
            return 0.0;
        }
        let replayed: usize =
            self.waves.iter().map(|w| w.replayed_groups).sum();
        replayed as f64 / total as f64
    }

    /// Pool keys of every placed group across all waves — the set a
    /// warm start establishes before the measured stream
    /// ([`crate::parallel::GroupPool::prewarm`]).
    pub fn pool_keys(
        &self,
    ) -> impl Iterator<
        Item = (
            crate::parallel::group::GroupKind,
            Vec<crate::parallel::group::RankId>,
        ),
    > + '_ {
        self.waves
            .iter()
            .flat_map(|p| p.groups.iter().map(|g| g.pool_key()))
    }

    /// Degrees across all waves, descending (Table 4 presentation).
    pub fn degree_multiset(&self) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .waves
            .iter()
            .flat_map(|p| p.groups.iter().map(|g| g.degree))
            .collect();
        out.sort_unstable_by(|a, b| b.cmp(a));
        out
    }

    /// Validate coverage (Conds. 4–5) AND the physical placement: every
    /// wave's rank sets must be disjoint, correctly sized, and within the
    /// rank budget (Cond. 6 on the placed representation).
    pub fn validate(&self, seqs: &[Sequence], replicas: usize) -> anyhow::Result<()> {
        // Union of waves must cover each sequence exactly once.
        let mut seen = vec![0usize; seqs.len()];
        for (wi, p) in self.waves.iter().enumerate() {
            p.validate_placement(replicas)
                .map_err(|e| anyhow::anyhow!("wave {wi}: {e}"))?;
            for g in &p.groups {
                for &i in &g.seq_idxs {
                    if i >= seqs.len() {
                        anyhow::bail!("sequence index {i} out of range");
                    }
                    seen[i] += 1;
                }
            }
        }
        if let Some(i) = seen.iter().position(|&c| c != 1) {
            anyhow::bail!("sequence {i} covered {} times", seen[i]);
        }
        Ok(())
    }
}

/// A logical schedule draft: the outer search's unit of comparison.
/// Waves carry degrees and assignments but no ranks yet; `est_time_s` is
/// the search objective costed against the call's fabric snapshot.
/// [`Scheduler::realize`] turns a draft into a placed [`Schedule`].
#[derive(Debug, Clone, Default)]
struct Draft {
    waves: Vec<Plan>,
    est_time_s: f64,
}

/// One unit of the outer search: a balance-target DP solve over a packing
/// produced (once) during candidate construction, or a uniform static-grid
/// anchor.
#[derive(Debug)]
enum Candidate {
    /// DP solve over a pre-packed candidate. The groups are packed once in
    /// `candidates()` (serially, for exact dedupe) and *handed over* to
    /// whichever worker claims the index — `take()`n exactly once, so the
    /// hot path never packs the same target twice.
    Target {
        #[allow(dead_code)] // retained for debugging/telemetry
        target: usize,
        groups: Mutex<Option<Vec<AtomicGroup>>>,
    },
    /// Uniform grid of N/d groups at degree d (LPT composition).
    Grid(usize),
}

/// The DHP scheduler: owns the cost model, the placement policy, and the
/// cross-step placement memory (reuse-aware placement prefers the rank
/// blocks the previous step used, so consecutive schedules key into the
/// same pooled communication groups).
#[derive(Debug)]
pub struct Scheduler {
    /// The Eq. 8–10 cost model candidate plans are scored against.
    pub cost: CostModel,
    /// Physical replica topology plans are placed on.
    pub mesh: DeviceMesh,
    /// Degree admissibility (any-integer for DHP, pow2 for FlexSP-style).
    pub policy: DegreePolicy,
    /// Which bandwidth oracle the search costs against (mesh-backed by
    /// default; uniform is the reference heuristic — see [`fabric`]).
    pub fabric: FabricKind,
    /// Rank blocks of the previously realized schedule, per wave slot.
    /// Shared across clones so a policy wrapper keeps reuse continuity.
    hint: Arc<Mutex<PlacementHint>>,
    /// The persistent worker pool the outer search submits to. `None`
    /// (a bare scheduler) falls back to [`SearchPool::global`]; the
    /// pipeline attaches its own per-scheduling-thread pool via
    /// [`Scheduler::set_search_pool`].
    search_pool: Option<Arc<SearchPool>>,
    /// Cross-step reuse state ([`schedule_cache`]): the exact-hit
    /// schedule cache plus the previous winning plan (the warm-start
    /// seed). Shared across clones, like `hint`, so a policy wrapper
    /// keeps reuse continuity; locked only for probes/inserts, never
    /// across a search.
    reuse: Arc<Mutex<ReuseState>>,
    /// Master switch for cross-step reuse
    /// ([`Scheduler::with_solver_reuse`]); on by default.
    reuse_enabled: bool,
    /// ε of the opt-in bounded-suboptimality fast path
    /// ([`Scheduler::with_reuse_epsilon`]); `None` (default) keeps
    /// every solve exact.
    epsilon: Option<f64>,
}

impl Clone for Scheduler {
    fn clone(&self) -> Self {
        Scheduler {
            cost: self.cost.clone(),
            mesh: self.mesh.clone(),
            policy: self.policy,
            fabric: self.fabric,
            hint: Arc::clone(&self.hint),
            search_pool: self.search_pool.clone(),
            reuse: Arc::clone(&self.reuse),
            reuse_enabled: self.reuse_enabled,
            epsilon: self.epsilon,
        }
    }
}

impl Scheduler {
    /// DHP scheduler (any-integer degrees) over `mesh`, scoring with
    /// `cost` against the mesh-backed fabric oracle.
    pub fn new(cost: CostModel, mesh: DeviceMesh) -> Self {
        Scheduler {
            cost,
            mesh,
            policy: DegreePolicy::AnyInteger,
            fabric: FabricKind::default(),
            hint: Arc::new(Mutex::new(PlacementHint::default())),
            search_pool: None,
            reuse: Arc::new(Mutex::new(ReuseState::default())),
            reuse_enabled: true,
            epsilon: None,
        }
    }

    /// Attach a persistent search pool; subsequent `schedule()` calls
    /// submit their outer search to it instead of the global fallback
    /// pool. Called by the pipeline (through
    /// [`crate::baselines::SchedulePolicy::attach_search_pool`]) so a
    /// session's steady-state solves spawn zero threads.
    pub fn set_search_pool(&mut self, pool: Arc<SearchPool>) {
        self.search_pool = Some(pool);
    }

    /// Restrict the degree search space (e.g. to powers of two for the
    /// FlexSP-style ablation).
    pub fn with_policy(mut self, policy: DegreePolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Select the bandwidth oracle the search costs against (e.g. force
    /// the uniform heuristic for the fragmentation ablation).
    pub fn with_fabric(mut self, fabric: FabricKind) -> Self {
        self.fabric = fabric;
        self
    }

    /// Acquire the ONE consistent fabric snapshot a solve runs against:
    /// mesh occupancy and the replayable hint census are read once, so
    /// the whole search — and the estimates the pipeline's one-step-ahead
    /// prewarm and the trainer consume — derive from a single coherent
    /// mesh view rather than a view that drifted mid-search.
    fn snapshot_fabric(&self) -> FabricModel {
        match self.fabric {
            FabricKind::Uniform => FabricModel::uniform(&self.mesh),
            FabricKind::MeshBacked => {
                let hint = self.hint.lock().unwrap_or_else(|e| e.into_inner());
                FabricModel::mesh_backed(&self.mesh, Some(&hint))
            }
        }
    }

    /// Run the full two-stage algorithm on one micro-batch.
    ///
    /// The balance-target outer search: packing is memory-driven, but the
    /// *granularity* of atomic groups trades ring-communication overhead
    /// (few fat groups → long rings) against load-balance freedom (many
    /// thin groups → DP can spread). We run Stage 1 + Stage 2 for a small
    /// set of group-count targets plus uniform static-grid anchors (a
    /// dynamic scheduler must never lose to a static grid it can emulate)
    /// and keep the best estimated schedule. Candidates are solved in
    /// parallel with incumbent pruning; see the module docs for why the
    /// result is nevertheless deterministic.
    ///
    /// # Examples
    ///
    /// Schedule a toy micro-batch on an 8-replica cluster:
    ///
    /// ```
    /// use dhp::config::presets::by_name;
    /// use dhp::config::{ClusterConfig, TrainStage};
    /// use dhp::cost::{CostCoeffs, CostModel, HardwareSpec, MemoryModel};
    /// use dhp::data::sequence::Sequence;
    /// use dhp::parallel::DeviceMesh;
    /// use dhp::scheduler::Scheduler;
    ///
    /// let cluster = ClusterConfig::default().with_npus(8);
    /// let preset = by_name("InternVL3-2B").unwrap();
    /// let cost = CostModel {
    ///     coeffs: CostCoeffs::analytic(
    ///         &preset,
    ///         TrainStage::Full,
    ///         &HardwareSpec::default(),
    ///     ),
    ///     memory: MemoryModel {
    ///         e_bytes: 8192.0 * preset.act_bytes_per_token() + 1e9,
    ///         m_states: 1e9,
    ///         m_token: preset.act_bytes_per_token(),
    ///     },
    /// };
    /// let scheduler = Scheduler::new(cost, DeviceMesh::new(&cluster));
    ///
    /// // Four sequences of mixed vision/text token counts.
    /// let batch: Vec<Sequence> = (0..4)
    ///     .map(|i| Sequence::new(i, 2048 * (i + 1), 256))
    ///     .collect();
    /// let schedule = scheduler.schedule(&batch);
    ///
    /// // Every sequence is covered exactly once and every group carries
    /// // a concrete, disjoint, in-budget rank set.
    /// schedule.validate(&batch, 8).unwrap();
    /// assert!(!schedule.waves.is_empty());
    /// for wave in &schedule.waves {
    ///     for group in &wave.groups {
    ///         assert_eq!(group.ranks.len(), group.degree);
    ///     }
    /// }
    /// ```
    pub fn schedule(&self, seqs: &[Sequence]) -> Schedule {
        let t0 = Instant::now();
        let fabric = self.snapshot_fabric();
        // Cross-step reuse front (ISSUE-9, [`schedule_cache`]): exact-
        // hit cache probe → opt-in ε fast path → warm-start-seeded
        // (guarded, exact) search. Placement always runs fresh below —
        // only the pre-placement search is ever skipped or seeded.
        let (draft, stats) = self.plan_with_reuse(seqs, &fabric);
        let mut out = self.realize(draft, true);
        out.stats = stats;
        out.solve_time_s = t0.elapsed().as_secs_f64();
        out
    }

    /// Bind a draft to physical ranks and re-derive placement-aware
    /// estimates. With `reuse` set, placement is steered by (and then
    /// refreshes) the scheduler's cross-step hint — the reuse-aware
    /// policy that keeps the communication-group pool hot; without it
    /// the draft is placed fresh (diagnostic/reference paths).
    fn realize(&self, draft: Draft, reuse: bool) -> Schedule {
        let mut waves = Vec::with_capacity(draft.waves.len());
        if reuse {
            let mut hint = self.hint.lock().unwrap_or_else(|e| e.into_inner());
            for (wi, plan) in draft.waves.iter().enumerate() {
                waves.push(place_plan(plan, &self.mesh, hint.wave(wi), &self.cost));
            }
            // Remember this step's blocks for the next one (per wave
            // slot, in placement order — replaying an unchanged degree
            // vector reproduces this placement exactly).
            hint.clear();
            for placed in &waves {
                let mut wh = WaveHint::default();
                for g in &placed.groups {
                    wh.remember(&g.ranks);
                }
                hint.waves.push(wh);
            }
        } else {
            for plan in &draft.waves {
                waves.push(place_plan(plan, &self.mesh, None, &self.cost));
            }
        }
        Schedule {
            est_time_s: waves.iter().map(|w| w.est_makespan_s).sum(),
            search_est_time_s: draft.est_time_s,
            waves,
            solve_time_s: 0.0,
            stats: SolveStats::default(),
        }
    }

    /// Build the candidate list: every integer target up to 16 (cheap, and
    /// covers every static-grid shape at small N), powers of two beyond, N
    /// itself, then the uniform-grid anchors.
    ///
    /// Satellite fix over the seed: group-count targets beyond what the
    /// batch can realize (e.g. more groups than sequences, or caps the BFD
    /// never hits) collapse to packings another target already produced —
    /// each such duplicate previously burned a full DP solve. Packing is
    /// cheap relative to the DP, so every target is packed here once,
    /// policy-rounded, and deduplicated (first occurrence wins, preserving
    /// the seed's tie-break order) by fingerprint pre-filter plus an
    /// *exact* group comparison on hash match — a distinct packing is
    /// never dropped, even under a 64-bit collision, so the searched set —
    /// and therefore the chosen schedule — matches the seed's sequential
    /// search exactly. Surviving packings are carried inside the
    /// [`Candidate`] for the claiming worker, so nothing is packed twice.
    /// The rank budget is the fabric snapshot's capacity (free replicas),
    /// so packing and the grid anchors never plan onto occupied slots.
    fn candidates(
        &self,
        seqs: &[Sequence],
        fabric: &FabricModel,
        pack: &mut scratch::PackScratch,
    ) -> Vec<Candidate> {
        let n = fabric.capacity();
        let mut targets: Vec<usize> = (1..=n.min(16)).collect();
        let mut p = 32usize;
        while p <= n {
            targets.push(p);
            p *= 2;
        }
        if !targets.contains(&n) {
            targets.push(n);
        }
        // (fingerprint, target, policy-rounded groups) for each keeper.
        let mut kept: Vec<(u64, usize, Vec<AtomicGroup>)> =
            Vec::with_capacity(targets.len());
        // Incremental Stage-1 (ISSUE-7): targets ascend, so the sweep
        // proves most adjacent repacks redundant and returns `None` —
        // which is exactly a duplicate of the previous packing and
        // therefore of something already offered to the dedupe below.
        let mut sweep = packing::TargetSweep::new(seqs, &self.cost.memory, n, pack);
        for t in targets {
            let Some(mut groups) = sweep.pack(t, pack) else {
                continue;
            };
            // Policy-restricted systems must round minimum degrees up to
            // the admissible set (e.g. pow2) BEFORE wave feasibility is
            // decided; doing it here (identical for every candidate) lets
            // workers consume the groups as-is.
            for g in &mut groups {
                g.d_min = self.policy.min_admissible(g.d_min).min(n);
            }
            let fp = packing::fingerprint(&groups);
            if kept
                .iter()
                .any(|(f, _, g)| *f == fp && packing::same_packing(g, &groups))
            {
                pack.reclaim_groups(&mut groups);
                pack.put_groups(groups);
            } else {
                kept.push((fp, t, groups));
            }
        }
        sweep.finish(pack);
        let mut out: Vec<Candidate> = kept
            .into_iter()
            .map(|(_, target, groups)| Candidate::Target {
                target,
                groups: Mutex::new(Some(groups)),
            })
            .collect();
        let mut d = 1usize;
        while d <= n {
            if n % d == 0 {
                out.push(Candidate::Grid(d));
            }
            d *= 2;
        }
        out
    }

    /// The parallel outer search over all candidates (see module docs).
    ///
    /// `seed` is the warm-start incumbent ([`schedule_cache`]): the
    /// re-costed estimate of the previous step's plan — a *feasible*
    /// solution for this batch, hence an admissible upper bound —
    /// pre-loaded into the atomic incumbent so the sound strict-`>`
    /// pruning fires from candidate 0 instead of ramping up. The result
    /// stays bit-identical to the unseeded search via the acceptance
    /// guard below: when the seeded best lands at or under the seed,
    /// the incumbent never dipped below the cold optimum, so the cold
    /// winner was never pruned and the `(est, index)` selection is
    /// unchanged; otherwise (the previous plan under-cut every
    /// candidate — the only regime where seeding could prune the cold
    /// winner) the search re-runs once, unseeded.
    fn plan_search(
        &self,
        seqs: &[Sequence],
        fabric: &FabricModel,
        seed: Option<f64>,
    ) -> (Draft, SolveStats) {
        if seqs.is_empty() {
            return (Draft::default(), SolveStats::default());
        }
        assert!(
            fabric.capacity() > 0,
            "no free replicas to schedule {} sequences onto",
            seqs.len()
        );
        let model_fp = self.cost.coeffs.fingerprint();
        let mut seed = seed;
        loop {
            // Candidate construction packs every target once (for
            // fingerprint dedupe) on the calling thread; its scratch
            // returns to the pool before the workers draw theirs.
            // Rebuilt per attempt: claimed `Candidate::Target` packings
            // are consumed (`take()`n) by the search.
            let candidates = {
                let mut scratch = SolverScratch::acquire();
                let out = self.candidates(seqs, fabric, &mut scratch.pack);
                scratch.release();
                out
            };
            let n_candidates = candidates.len();
            let seed_bits = seed.unwrap_or(f64::INFINITY).to_bits();
            let workers = solver_threads().min(candidates.len()).max(1);
            let mut results: Vec<(usize, Draft)> = if workers <= 1 {
                // Sequential path: claim indices off a local counter with
                // a local incumbent — the reference discipline the pool
                // reproduces.
                let next = AtomicUsize::new(0);
                // Incumbent best estimate as f64 bits: non-negative
                // IEEE-754 floats order identically to their bit
                // patterns, so a lock-free `fetch_min` maintains the
                // minimum.
                let incumbent = AtomicU64::new(seed_bits);
                self.run_candidates(seqs, &candidates, fabric, model_fp, &next, &incumbent)
            } else {
                // Persistent pool: the attached (pipeline-owned) pool if
                // one was set, else the lazily-created process-global one
                // — no per-solve thread spawn on either path.
                let helpers = workers - 1;
                match &self.search_pool {
                    Some(pool) => pool.search(
                        self, seqs, fabric, model_fp, candidates, helpers, seed_bits,
                    ),
                    None => SearchPool::global().search(
                        self, seqs, fabric, model_fp, candidates, helpers, seed_bits,
                    ),
                }
            };
            // Deterministic selection regardless of worker timing: best
            // estimate, ties to the lowest candidate index (the seed's
            // sequential first-wins order). A pruned candidate's lower
            // bound strictly exceeded a then-current incumbent ≥ the
            // final best, so pruning never removes a potential winner.
            results.sort_by(|a, b| {
                a.1.est_time_s
                    .partial_cmp(&b.1.est_time_s)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.0.cmp(&b.0))
            });
            let solved = results.len();
            let best = results.into_iter().next().map(|(_, s)| s);
            let stats = SolveStats {
                warm_started: seed.is_some(),
                candidates: n_candidates,
                pruned: n_candidates.saturating_sub(solved),
                ..SolveStats::default()
            };
            match (seed, best) {
                // Warm-start acceptance guard (see doc comment): seeded
                // best at or under the admissible upper bound ⇒ exact.
                (Some(u), Some(b)) if b.est_time_s <= u => return (b, stats),
                // The seed under-cut every candidate; re-run unseeded
                // for exactness.
                (Some(_), _) => seed = None,
                (None, b) => return (b.unwrap_or_default(), stats),
            }
        }
    }

    /// Worker loop: pull candidate indices off the shared queue until
    /// drained, solving each with this worker's pooled scratch. The
    /// sequential (`workers <= 1`) search path; the pool's participants
    /// run the same discipline through [`SearchPool`].
    fn run_candidates(
        &self,
        seqs: &[Sequence],
        candidates: &[Candidate],
        fabric: &FabricModel,
        model_fp: u64,
        next: &AtomicUsize,
        incumbent: &AtomicU64,
    ) -> Vec<(usize, Draft)> {
        let fabric_fp = fabric.fingerprint();
        let mut scratch = SolverScratch::acquire();
        let mut out = Vec::new();
        loop {
            let ci = next.fetch_add(1, Ordering::Relaxed);
            if ci >= candidates.len() {
                break;
            }
            let bound = f64::from_bits(incumbent.load(Ordering::Relaxed));
            if let Some(draft) = self.solve_candidate(
                seqs, candidates, ci, fabric, model_fp, fabric_fp, bound,
                &mut scratch,
            ) {
                incumbent.fetch_min(draft.est_time_s.to_bits(), Ordering::Relaxed);
                out.push((ci, draft));
            }
        }
        scratch.release();
        out
    }

    /// Solve one claimed candidate (shared by the sequential loop above
    /// and the pool's participants). Returns `None` when the candidate
    /// was pruned or is inadmissible.
    #[allow(clippy::too_many_arguments)]
    fn solve_candidate(
        &self,
        seqs: &[Sequence],
        candidates: &[Candidate],
        ci: usize,
        fabric: &FabricModel,
        model_fp: u64,
        fabric_fp: u64,
        bound: f64,
        scratch: &mut SolverScratch,
    ) -> Option<Draft> {
        match &candidates[ci] {
            Candidate::Target { groups, .. } => groups
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .take() // each index is claimed by exactly one worker
                .and_then(|g| self.solve_packed(g, fabric, model_fp, bound, scratch)),
            Candidate::Grid(d) => {
                self.uniform_grid_schedule(seqs, *d, fabric, |agg, dd, bw| {
                    scratch
                        .cache
                        .t_total(model_fp, fabric_fp, &self.cost, agg, dd, bw)
                })
            }
        }
    }

    /// One pack→waves→DP candidate solve (the single-target entry; the
    /// outer search packs in `candidates()` and goes through
    /// [`Scheduler::solve_packed`] directly).
    fn solve_target(
        &self,
        seqs: &[Sequence],
        group_target: usize,
        fabric: &FabricModel,
        model_fp: u64,
        bound: f64,
        scratch: &mut SolverScratch,
    ) -> Option<Draft> {
        let n = fabric.capacity();
        let mut groups = packing::pack_with_target_in(
            seqs,
            &self.cost.memory,
            n,
            group_target,
            &mut scratch.pack,
        );
        // Policy-restricted systems must round minimum degrees up to the
        // admissible set (e.g. pow2) BEFORE wave feasibility is decided.
        for g in &mut groups {
            g.d_min = self.policy.min_admissible(g.d_min).min(n);
        }
        self.solve_packed(groups, fabric, model_fp, bound, scratch)
    }

    /// Waves→DP over an already-packed, already-policy-rounded group set.
    /// Returns `None` when the candidate's lower bound proves it cannot
    /// beat `bound` (the current incumbent; `f64::INFINITY` disables
    /// pruning).
    fn solve_packed(
        &self,
        mut groups: Vec<AtomicGroup>,
        fabric: &FabricModel,
        model_fp: u64,
        bound: f64,
        scratch: &mut SolverScratch,
    ) -> Option<Draft> {
        let n = fabric.capacity();
        let mut waves = packing::waves_in(&mut groups, n, &mut scratch.pack);
        scratch.pack.put_groups(groups);
        if bound.is_finite()
            && self.lower_bound(&waves, fabric, model_fp, &scratch.cache) > bound
        {
            scratch.pack.reclaim_waves(&mut waves);
            return None;
        }
        let draft = self.solve_waves(&waves, fabric, model_fp, scratch);
        scratch.pack.reclaim_waves(&mut waves);
        Some(draft)
    }

    /// Sound lower bound on a candidate's estimated time, before any DP
    /// work: per wave, the larger of
    ///
    /// * the aggregate-work bound — even with all N ranks the wave cannot
    ///   finish its total compute faster than `t_compute(Σagg, N)`
    ///   (Eq. 10's overlap never dips below pure compute, and
    ///   `max_g w_g/d_g ≥ Σw/Σd ≥ Σw/N`);
    /// * the best-single-group bound — the heaviest group cannot beat its
    ///   own best admissible degree, evaluated at the fabric's *maximum*
    ///   bandwidth per degree ([`FabricModel::max_bw_for_degree`]): under
    ///   a non-uniform fabric the objective's bandwidth depends on
    ///   placement, so only the best-case bandwidth yields an admissible
    ///   bound. On the uniform oracle max-bw equals the costing
    ///   bandwidth, so these evaluations also warm the cache for the DP
    ///   if the candidate survives (and pruning matches the seed
    ///   bit-for-bit);
    /// * the communication floor (ISSUE-7) — any group forced to span
    ///   `d_min ≥ 2` ranks pays ring communication no allocation can
    ///   remove: Eq. 10 gives `T = T_cp + T_cm − min(T_cpa, T_cma) ≥
    ///   T_cm` (the overlap term never exceeds `T_cp`), and `t_comm` is
    ///   monotone increasing in the degree and decreasing in bandwidth,
    ///   so `t_comm(agg, d_min, v*)` at the fabric's best bandwidth over
    ///   ALL degrees bounds every admissible choice from below. This is
    ///   what rejects over-fragmented balance targets (many thin forced-
    ///   multi-rank groups) before any DP work.
    fn lower_bound(
        &self,
        waves: &[Vec<AtomicGroup>],
        fabric: &FabricModel,
        model_fp: u64,
        cache: &CostCache,
    ) -> f64 {
        let fabric_fp = fabric.fingerprint();
        let n = fabric.capacity();
        // Best-case ring bandwidth over every degree — hoisted once per
        // candidate; the communication floor below is only admissible at
        // the fabric's most optimistic answer.
        let mut v_star = 0.0f64;
        for d in 2..=n {
            let v = fabric.max_bw_for_degree(d);
            if v > v_star {
                v_star = v;
            }
        }
        let mut total = 0.0;
        for wave in waves {
            let mut agg = WorkloadAgg::default();
            let mut heaviest: Option<&AtomicGroup> = None;
            let mut comm_floor = 0.0f64;
            for g in wave {
                agg.merge(&g.agg);
                match heaviest {
                    Some(h) if h.agg.quad >= g.agg.quad => {}
                    _ => heaviest = Some(g),
                }
                // Communication floor of a forced-multi-rank group (see
                // doc comment); 1e-9 shave so floating-point rounding in
                // the monotonicity argument can never make it unsound.
                let dm = g.d_min.min(n).max(1);
                if dm >= 2 && v_star > 0.0 {
                    let f = self.cost.t_comm(&g.agg, dm, v_star) * (1.0 - 1e-9);
                    if f > comm_floor {
                        comm_floor = f;
                    }
                }
            }
            // The work bound holds by real-valued algebra; shave 1e-9 so
            // floating-point rounding can never make it unsound (the
            // single-group bound below is float-exact — it is a min over
            // the very T values the DP maximizes over).
            let mut lb = self.cost.t_compute(&agg, n) * (1.0 - 1e-9);
            lb = lb.max(comm_floor);
            if let Some(h) = heaviest {
                let dmin = h.d_min.min(n).max(1);
                let mut best = f64::INFINITY;
                for d in dmin..=n {
                    if self.policy.admits(d) {
                        let t = cache.t_total(
                            model_fp,
                            fabric_fp,
                            &self.cost,
                            &h.agg,
                            d,
                            fabric.max_bw_for_degree(d),
                        );
                        if t < best {
                            best = t;
                        }
                    }
                }
                if best.is_finite() {
                    lb = lb.max(best);
                }
            }
            total += lb;
        }
        total
    }

    /// DP-solve each wave and assemble the schedule (scratch-threaded,
    /// memoized cost evaluations, every transition costed at the fabric
    /// oracle's bandwidth for its candidate degree).
    fn solve_waves(
        &self,
        waves: &[Vec<AtomicGroup>],
        fabric: &FabricModel,
        model_fp: u64,
        scratch: &mut SolverScratch,
    ) -> Draft {
        let n = fabric.capacity();
        let fabric_fp = fabric.fingerprint();
        let SolverScratch {
            dp: dp_bufs,
            cache,
            ..
        } = scratch;
        let mut out = Draft::default();
        for wave in waves {
            let policy = self.policy;
            let sol = dp::allocate_degrees_in(
                dp_bufs,
                wave,
                n,
                |i, d| {
                    cache.t_total(
                        model_fp,
                        fabric_fp,
                        &self.cost,
                        &wave[i].agg,
                        d,
                        fabric.bw_for_degree(d),
                    )
                },
                |d| policy.admits(d),
            );
            let mut plan = Plan::default();
            for (g, &d) in wave.iter().zip(&sol.degrees) {
                plan.groups.push(PlannedGroup {
                    degree: d,
                    seq_idxs: g.seq_idxs.clone(),
                    agg: g.agg,
                    est_time_s: cache.t_total(
                        model_fp,
                        fabric_fp,
                        &self.cost,
                        &g.agg,
                        d,
                        fabric.bw_for_degree(d),
                    ),
                });
            }
            plan.est_makespan_s = sol.makespan_s;
            out.est_time_s += sol.makespan_s;
            out.waves.push(plan);
        }
        out
    }

    /// Build a uniform-grid candidate: N/d groups of degree d per wave,
    /// sequences LPT-assigned by quadratic work subject to Eq. 3's memory
    /// cap. Returns None if the longest sequence cannot fit degree d.
    /// `eval` abstracts the cost query so the hot path can memoize while
    /// the reference baseline computes directly (identical values either
    /// way).
    fn uniform_grid_schedule<E>(
        &self,
        seqs: &[Sequence],
        d: usize,
        fabric: &FabricModel,
        eval: E,
    ) -> Option<Draft>
    where
        E: Fn(&WorkloadAgg, usize, f64) -> f64,
    {
        let n = fabric.capacity();
        if !self.policy.admits(d) {
            return None;
        }
        let cap_tokens = {
            let budget = self.cost.memory.rank_budget() * d as f64;
            (budget / self.cost.memory.m_token).floor() as u64
        };
        if seqs.iter().any(|s| s.len() > cap_tokens) {
            return None;
        }
        let n_groups = (n / d).max(1);
        let mut order: Vec<usize> = (0..seqs.len()).collect();
        order.sort_by(|&a, &b| seqs[b].len().cmp(&seqs[a].len()).then(a.cmp(&b)));

        struct Bin {
            idxs: Vec<usize>,
            tokens: u64,
            agg: WorkloadAgg,
        }
        let mut waves: Vec<Vec<Bin>> = vec![(0..n_groups)
            .map(|_| Bin {
                idxs: vec![],
                tokens: 0,
                agg: Default::default(),
            })
            .collect()];
        for &i in &order {
            let s = &seqs[i];
            loop {
                let wave = waves.last_mut().unwrap();
                let mut best: Option<usize> = None;
                for (bi, b) in wave.iter().enumerate() {
                    if b.tokens + s.len() <= cap_tokens || b.idxs.is_empty() {
                        match best {
                            Some(p) if wave[p].agg.quad <= b.agg.quad => {}
                            _ => best = Some(bi),
                        }
                    }
                }
                if let Some(bi) = best {
                    let b = &mut wave[bi];
                    b.idxs.push(i);
                    b.tokens += s.len();
                    b.agg.add(s);
                    break;
                }
                waves.push(
                    (0..n_groups)
                        .map(|_| Bin {
                            idxs: vec![],
                            tokens: 0,
                            agg: Default::default(),
                        })
                        .collect(),
                );
            }
        }

        let bw = fabric.bw_for_degree(d);
        let mut out = Draft::default();
        for wave in waves {
            let mut plan = Plan::default();
            for b in wave {
                if b.idxs.is_empty() {
                    continue;
                }
                let est = eval(&b.agg, d, bw);
                plan.groups.push(PlannedGroup {
                    degree: d,
                    seq_idxs: b.idxs,
                    agg: b.agg,
                    est_time_s: est,
                });
            }
            plan.est_makespan_s = plan
                .groups
                .iter()
                .map(|g| g.est_time_s)
                .fold(0.0f64, f64::max);
            out.est_time_s += plan.est_makespan_s;
            out.waves.push(plan);
        }
        Some(out)
    }

    /// One pack→DP pass at a fixed group-count target (public for
    /// ablation benches and diagnostics). Draws a pooled scratch; the
    /// steady-state path is [`Scheduler::schedule_with_target_in`].
    pub fn schedule_with_target(&self, seqs: &[Sequence], group_target: usize) -> Schedule {
        let mut scratch = SolverScratch::acquire();
        let out = self.schedule_with_target_in(seqs, group_target, &mut scratch);
        scratch.release();
        out
    }

    /// [`Scheduler::schedule_with_target`] with caller-owned scratch:
    /// packing buffers, DP tables, and memoized cost evaluations all come
    /// from `scratch`, so repeated calls allocate only the returned plan.
    pub fn schedule_with_target_in(
        &self,
        seqs: &[Sequence],
        group_target: usize,
        scratch: &mut SolverScratch,
    ) -> Schedule {
        let fabric = self.snapshot_fabric();
        let model_fp = self.cost.coeffs.fingerprint();
        let draft = self
            .solve_target(seqs, group_target, &fabric, model_fp, f64::INFINITY, scratch)
            .expect("unpruned solve always yields a schedule");
        // Diagnostic entry: fresh placement, no cross-step reuse memory.
        self.realize(draft, false)
    }

    // ------------------------------------------------------------------
    // Pre-overhaul reference path (the measured "before" of ISSUE-1).
    // ------------------------------------------------------------------

    /// The seed's sequential solver, retained verbatim: ~20 serial
    /// pack→DP candidate solves through the exact-j reference DP, with
    /// per-call allocations and unmemoized cost evaluations, ALWAYS
    /// costed against the uniform-fabric heuristic (the reference
    /// oracle, regardless of the scheduler's configured fabric). It is
    /// the "before" case in `benches/solver_micro.rs` and a behavioral
    /// oracle for tests; never used on the hot path.
    pub fn schedule_reference(&self, seqs: &[Sequence]) -> Schedule {
        let t0 = Instant::now();
        let fabric = FabricModel::uniform(&self.mesh);
        let n = fabric.capacity();
        let mut targets: Vec<usize> = (1..=n.min(16)).collect();
        let mut p = 32usize;
        while p <= n {
            targets.push(p);
            p *= 2;
        }
        if !targets.contains(&n) {
            targets.push(n);
        }
        let mut best: Option<Draft> = None;
        let consider = |candidate: Draft, best: &mut Option<Draft>| match best {
            Some(b) if b.est_time_s <= candidate.est_time_s => {}
            _ => *best = Some(candidate),
        };
        for target in targets {
            consider(
                self.draft_with_target_reference(seqs, target, &fabric),
                &mut best,
            );
        }
        let mut d = 1usize;
        while d <= n {
            if n % d == 0 {
                if let Some(candidate) =
                    self.uniform_grid_schedule(seqs, d, &fabric, |agg, dd, bw| {
                        self.cost.t_total(agg, dd, bw)
                    })
                {
                    consider(candidate, &mut best);
                }
            }
            d *= 2;
        }
        // Fresh placement (no reuse memory): the reference is an oracle,
        // not a training-path participant.
        let mut out = self.realize(best.unwrap_or_default(), false);
        out.solve_time_s = t0.elapsed().as_secs_f64();
        out
    }

    /// Reference single-target pass: fresh allocations, exact-j DP,
    /// direct cost-model evaluations (the seed's `schedule_with_target`),
    /// costed against the uniform reference oracle.
    pub fn schedule_with_target_reference(
        &self,
        seqs: &[Sequence],
        group_target: usize,
    ) -> Schedule {
        let fabric = FabricModel::uniform(&self.mesh);
        self.realize(
            self.draft_with_target_reference(seqs, group_target, &fabric),
            false,
        )
    }

    fn draft_with_target_reference(
        &self,
        seqs: &[Sequence],
        group_target: usize,
        fabric: &FabricModel,
    ) -> Draft {
        let n = fabric.capacity();
        let mut groups = packing::pack_with_target(seqs, &self.cost.memory, n, group_target);
        for g in &mut groups {
            g.d_min = self.policy.min_admissible(g.d_min).min(n);
        }
        let waves = packing::waves(groups, n);

        let mut out = Draft::default();
        for wave in waves {
            let policy = self.policy;
            let sol = dp::allocate_degrees_reference(
                &wave,
                n,
                |i, d| self.cost.t_total(&wave[i].agg, d, fabric.bw_for_degree(d)),
                |d| policy.admits(d),
            );
            let mut plan = Plan::default();
            for (g, &d) in wave.iter().zip(&sol.degrees) {
                plan.groups.push(PlannedGroup {
                    degree: d,
                    seq_idxs: g.seq_idxs.clone(),
                    agg: g.agg,
                    est_time_s: self.cost.t_total(&g.agg, d, fabric.bw_for_degree(d)),
                });
            }
            plan.est_makespan_s = sol.makespan_s;
            out.est_time_s += sol.makespan_s;
            out.waves.push(plan);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::by_name;
    use crate::config::{ClusterConfig, TrainStage};
    use crate::cost::{CostCoeffs, HardwareSpec, MemoryModel};
    use crate::data::datasets::{DatasetKind, DatasetSampler, TokenizerSpec};
    use crate::util::quickcheck::forall;
    use crate::util::rng::Rng;

    /// High-res video tokenization (2 fps × 256 tokens/frame): the
    /// long-context regime where sequences span 1k-180k tokens and mixed
    /// CP degrees pay off.
    fn sampler(kind: DatasetKind, seed: u64) -> DatasetSampler {
        DatasetSampler::new(kind, seed).with_spec(TokenizerSpec {
            fps: 2.0,
            tokens_per_frame: 256.0,
            text_min: 32,
            text_max: 512,
        })
    }

    fn scheduler(replicas: usize) -> Scheduler {
        // Paper regime: one replica = TP×PP = 4 NPUs, 2 replicas/node —
        // CP degrees ≥ 3 cross nodes and ride the slow interconnect.
        let mut cluster = ClusterConfig::default().with_npus(replicas * 4);
        cluster.tp = 2;
        cluster.pp = 2;
        let preset = by_name("InternVL3-8B").unwrap();
        // Per-replica FLOPs aggregate the TP*PP member NPUs.
        let hw = HardwareSpec {
            peak_flops: 376e12 * 4.0,
            ..HardwareSpec::default()
        };
        let cost = CostModel {
            coeffs: CostCoeffs::analytic(&preset, TrainStage::Full, &hw),
            memory: MemoryModel {
                e_bytes: 8192.0 * preset.act_bytes_per_token() + 2e9,
                m_states: 2e9,
                m_token: preset.act_bytes_per_token(),
            },
        };
        Scheduler::new(cost, DeviceMesh::new(&cluster))
    }

    #[test]
    fn schedule_covers_all_sequences() {
        let sch = scheduler(16);
        let mut sampler = sampler(DatasetKind::OpenVid, 31);
        let seqs = sampler.sample_batch(64);
        let schedule = sch.schedule(&seqs);
        schedule.validate(&seqs, 16).unwrap();
        assert!(!schedule.waves.is_empty());
        assert!(schedule.solve_time_s < 1.0);
    }

    #[test]
    fn skewed_data_produces_mixed_degrees() {
        // The Table 4 phenomenon: OpenVid's skew should yield a rich
        // multiset of degrees, not a uniform one. Uses the realistic
        // cluster context (calibrated cost model, paper memory budget).
        use crate::experiments::harness::ExpContext;
        let ctx = ExpContext::new(
            by_name("InternVL3-8B").unwrap(),
            DatasetKind::OpenVid,
            32,
            TrainStage::Full,
        );
        let sch = ctx.dhp();
        // Heterogeneity is workload-dependent; over a few draws at least
        // one schedule must use mixed degrees (a static mesh never can).
        let mut saw_mixed = false;
        let mut all_degrees = Vec::new();
        for seed in [0xD4Bu64, 0x7AB4, 37] {
            let mut ctx2 = ctx.clone();
            ctx2.seed = seed;
            // Schedule at micro-batch granularity (the planner's output):
            // memory-full micro-batches are where heterogeneity pays off.
            let mut sampler = ctx2.sampler();
            let batch = crate::data::batch::GlobalBatch {
                step: 0,
                sequences: sampler.sample_batch(128),
            };
            for mb in ctx2.micro_batch_planner().plan(&batch) {
                let schedule = sch.schedule(&mb.sequences);
                let degrees = schedule.degree_multiset();
                let distinct: std::collections::HashSet<usize> =
                    degrees.iter().copied().collect();
                saw_mixed |= distinct.len() >= 2;
                all_degrees.push(degrees);
            }
        }
        assert!(
            saw_mixed,
            "expected heterogeneous degrees in at least one draw: {all_degrees:?}"
        );
    }

    #[test]
    fn pow2_policy_restricts_degrees() {
        let sch = scheduler(8).with_policy(DegreePolicy::PowerOfTwo);
        let mut sampler = sampler(DatasetKind::OpenVid, 41);
        let seqs = sampler.sample_batch(32);
        let schedule = sch.schedule(&seqs);
        for d in schedule.degree_multiset() {
            assert!(d.is_power_of_two(), "degree {d} not a power of two");
        }
    }

    #[test]
    fn any_integer_beats_pow2_on_average() {
        // DHP's generalized degrees must never lose to the pow2-restricted
        // search, must exploit non-pow2 degrees on skewed data, and must
        // win measurably over a workload sample.
        use crate::experiments::harness::ExpContext;
        let ctx = ExpContext::new(
            by_name("InternVL3-8B").unwrap(),
            DatasetKind::OpenVid,
            32,
            TrainStage::Full,
        );
        let dhp = ctx.dhp();
        let pow2 = ctx.dhp().with_policy(DegreePolicy::PowerOfTwo);
        let mut total_dhp = 0.0;
        let mut total_pow2 = 0.0;
        let mut used_non_pow2 = false;
        for seed in 0..10 {
            let mut sampler = ctx.sampler();
            let mut skip = Rng::new(seed);
            let _ = skip.next_u64();
            let seqs = sampler.sample_batch(32 + (seed as usize) * 4);
            let s_dhp = dhp.schedule(&seqs);
            used_non_pow2 |= s_dhp
                .degree_multiset()
                .iter()
                .any(|d| !d.is_power_of_two());
            // Compare on the search objective: the relaxation claim is
            // about the degree search space, not placement fragmentation.
            total_dhp += s_dhp.search_est_time_s;
            total_pow2 += pow2.schedule(&seqs).search_est_time_s;
        }
        assert!(
            total_dhp <= total_pow2 * 1.0001,
            "dhp {total_dhp} vs pow2 {total_pow2}"
        );
        assert!(
            total_dhp < total_pow2 * 0.999,
            "expected measurable gain: dhp {total_dhp} vs pow2 {total_pow2}"
        );
        assert!(used_non_pow2, "DHP never used a non-pow2 degree");
    }

    #[test]
    fn property_schedule_always_valid() {
        forall(25, 0x5CED, |rng| {
            let npus = *rng.choose(&[8usize, 16, 32, 64]);
            let sch = scheduler(npus);
            let kind = *rng.choose(&DatasetKind::all());
            let n = rng.range_usize(1, 96);
            let mut sampler = sampler(kind, rng.next_u64());
            let seqs = sampler.sample_batch(n);
            let schedule = sch.schedule(&seqs);
            schedule
                .validate(&seqs, npus)
                .map_err(|e| format!("{e} (npus={npus}, n={n})"))?;
            // Makespan estimates must be positive and finite.
            for p in &schedule.waves {
                if !(p.est_makespan_s.is_finite() && p.est_makespan_s > 0.0) {
                    return Err(format!("bad makespan {}", p.est_makespan_s));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn empty_batch_schedules_to_nothing() {
        let sch = scheduler(8);
        let schedule = sch.schedule(&[]);
        assert!(schedule.waves.is_empty());
        schedule.validate(&[], 8).unwrap();
    }

    #[test]
    fn scratch_reuse_is_bit_identical_to_fresh() {
        // The ISSUE-1 regression gate: reusing pooled scratches across
        // consecutive schedule() calls must be invisible — bit-identical
        // plans and estimates vs the first (cold) solve, and the
        // single-target path must agree between pooled and caller-owned
        // scratch.
        let sch = scheduler(16);
        let mut sampler = sampler(DatasetKind::OpenVid, 77);
        let seqs = sampler.sample_batch(48);
        let first = sch.schedule(&seqs);
        for round in 0..3 {
            let again = sch.schedule(&seqs);
            assert_eq!(first.waves, again.waves, "round {round} diverged");
            assert_eq!(
                first.est_time_s.to_bits(),
                again.est_time_s.to_bits(),
                "round {round} estimate drifted"
            );
        }
        let mut scratch = SolverScratch::acquire();
        for target in [1usize, 4, 9, 16, 48] {
            let pooled = sch.schedule_with_target(&seqs, target);
            let reused = sch.schedule_with_target_in(&seqs, target, &mut scratch);
            assert_eq!(pooled.waves, reused.waves, "target {target}");
            assert_eq!(
                pooled.est_time_s.to_bits(),
                reused.est_time_s.to_bits(),
                "target {target}"
            );
        }
        scratch.release();
    }

    #[test]
    fn optimized_target_pass_matches_reference() {
        // Same packing, same candidate degrees: the optimized DP +
        // memoized costs must reproduce the reference pass's wave
        // makespans and total estimate (the DPs may pick different —
        // equally optimal — degree vectors, so plans are compared on
        // estimates, not degrees).
        let sch = scheduler(16);
        for seed in [3u64, 19, 101] {
            let mut sampler = sampler(DatasetKind::OpenVid, seed);
            let seqs = sampler.sample_batch(40);
            for target in [1usize, 2, 5, 8, 16, 40] {
                let fast = sch.schedule_with_target(&seqs, target);
                let reference = sch.schedule_with_target_reference(&seqs, target);
                assert_eq!(fast.waves.len(), reference.waves.len());
                // The DPs may pick different — equally optimal — degree
                // vectors, whose PLACED makespans can then legitimately
                // differ; the search objective is what must agree.
                for (f, r) in fast.waves.iter().zip(&reference.waves) {
                    assert!(
                        (f.search_makespan_s - r.search_makespan_s).abs()
                            <= 1e-9 * r.search_makespan_s.max(1.0),
                        "target {target} seed {seed}: {} vs {}",
                        f.search_makespan_s,
                        r.search_makespan_s
                    );
                }
            }
        }
    }

    #[test]
    fn schedules_are_placed_with_actual_bandwidth_estimates() {
        // The placed layer: every group carries a rank set of its degree,
        // waves are disjoint/in-budget, and each estimate is the cost
        // model evaluated at the ring bandwidth of the ACTUAL rank set.
        let sch = scheduler(16);
        let mut sampler = sampler(DatasetKind::OpenVid, 91);
        let seqs = sampler.sample_batch(48);
        let schedule = sch.schedule(&seqs);
        schedule.validate(&seqs, 16).unwrap();
        for wave in &schedule.waves {
            for g in &wave.groups {
                assert_eq!(g.ranks.len(), g.degree);
                assert_eq!(g.ring_bw, sch.mesh.ring_bandwidth(&g.ranks));
                let expected = sch.cost.t_total(&g.agg, g.degree, g.ring_bw);
                assert_eq!(g.est_time_s.to_bits(), expected.to_bits());
            }
        }
        assert!(
            (schedule.est_time_s
                - schedule
                    .waves
                    .iter()
                    .map(|w| w.est_makespan_s)
                    .sum::<f64>())
            .abs()
                < 1e-15
        );
    }

    #[test]
    fn reuse_aware_placement_replays_previous_blocks() {
        // Consecutive schedules of similar shape must key into the same
        // rank blocks (the pool-reuse mechanism). Identical inputs replay
        // exactly; here we just assert the second pass reuses the first
        // pass's blocks wholesale.
        let sch = scheduler(16);
        let mut sampler = sampler(DatasetKind::OpenVid, 93);
        let seqs = sampler.sample_batch(40);
        let first = sch.schedule(&seqs);
        let second = sch.schedule(&seqs);
        let keys = |s: &Schedule| -> Vec<(usize, Vec<usize>)> {
            s.waves
                .iter()
                .flat_map(|w| w.groups.iter().map(|g| (g.degree, g.ranks.clone())))
                .collect()
        };
        assert_eq!(keys(&first), keys(&second));
    }

    #[test]
    fn parallel_search_matches_sequential_reference_estimate() {
        // Fingerprint dedupe only removes candidates whose packing — and
        // therefore whose whole solve — duplicates a kept one, so the
        // parallel pruned search must land on the same best estimate as
        // the seed's sequential reference solver for ANY batch size.
        let sch = scheduler(16);
        for (seed, k) in [(5u64, 32usize), (23, 32), (41, 10), (43, 3)] {
            let mut sampler = sampler(DatasetKind::InternVid, seed);
            let seqs = sampler.sample_batch(k);
            let fast = sch.schedule(&seqs);
            let reference = sch.schedule_reference(&seqs);
            assert!(
                (fast.search_est_time_s - reference.search_est_time_s).abs()
                    <= 1e-9 * reference.search_est_time_s.max(1.0),
                "seed {seed} k {k}: parallel {} vs reference {}",
                fast.search_est_time_s,
                reference.search_est_time_s
            );
        }
    }

    #[test]
    fn fragmented_mesh_mesh_backed_search_beats_uniform_heuristic() {
        // The ISSUE-4 acceptance criterion. 16 replicas, 2 per node
        // (8 nodes); occupy one rank of EVERY node — 50% of the mesh is
        // pre-held by concurrent jobs and no node can host a group of
        // degree ≥ 2, so every multi-rank ring rides the slow inter-node
        // fabric. The uniform heuristic still prices degree-2 groups at
        // intra bandwidth and can crown a candidate that loses after
        // placement; the mesh-backed oracle prices the fabric the
        // placement will actually deliver.
        let occupied: Vec<usize> = (0..16).step_by(2).collect();
        let mk = |kind: FabricKind| {
            let mut s = scheduler(16).with_fabric(kind);
            s.mesh.occupy(&occupied);
            s
        };
        let mesh_backed = mk(FabricKind::MeshBacked);
        let uniform = mk(FabricKind::Uniform);
        for seed in [7u64, 4242, 90_001] {
            let mut sampler = sampler(DatasetKind::OpenVid, seed);
            let seqs = sampler.sample_batch(24);
            let placed_mb = mesh_backed.schedule(&seqs);
            let placed_uni = uniform.schedule(&seqs);
            placed_mb.validate(&seqs, 16).unwrap();
            placed_uni.validate(&seqs, 16).unwrap();
            // Pre-occupied ranks are untouchable on both paths.
            for s in [&placed_mb, &placed_uni] {
                for wave in &s.waves {
                    for g in &wave.groups {
                        for &r in &g.ranks {
                            assert!(r % 2 == 1, "seed {seed}: occupied rank {r} placed");
                        }
                    }
                }
            }
            // The fabric-aware search must never lose to the uniform
            // heuristic on the PLACED estimate — the metric that counts.
            assert!(
                placed_mb.est_time_s <= placed_uni.est_time_s * (1.0 + 1e-9),
                "seed {seed}: mesh-backed {} vs uniform {}",
                placed_mb.est_time_s,
                placed_uni.est_time_s
            );
            // On this mesh the free-slot census fully determines every
            // group's locality, so the search objective and the placed
            // estimate are literally one lineage.
            assert!(
                (placed_mb.est_time_s - placed_mb.search_est_time_s).abs()
                    <= 1e-9 * placed_mb.est_time_s.max(1.0),
                "seed {seed}: placed {} diverged from search {}",
                placed_mb.est_time_s,
                placed_mb.search_est_time_s
            );
            // And the uniform path still matches the sequential reference
            // solver on the fragmented mesh (both cost the same heuristic
            // over the same free-rank budget).
            let reference = uniform.schedule_reference(&seqs);
            assert!(
                (placed_uni.search_est_time_s - reference.search_est_time_s).abs()
                    <= 1e-9 * reference.search_est_time_s.max(1.0),
                "seed {seed}: uniform {} vs reference {}",
                placed_uni.search_est_time_s,
                reference.search_est_time_s
            );
        }
    }

    #[test]
    fn mesh_backed_is_uniform_on_an_empty_mesh() {
        // The default-fabric switch must be invisible on an unfragmented
        // mesh: identical search objectives, bit-identical plans.
        let mesh_backed = scheduler(16);
        let uniform = scheduler(16).with_fabric(FabricKind::Uniform);
        let mut sampler = sampler(DatasetKind::InternVid, 271);
        let seqs = sampler.sample_batch(40);
        let a = mesh_backed.schedule(&seqs);
        let b = uniform.schedule(&seqs);
        assert_eq!(a.waves, b.waves);
        assert_eq!(a.search_est_time_s.to_bits(), b.search_est_time_s.to_bits());
        assert_eq!(a.est_time_s.to_bits(), b.est_time_s.to_bits());
    }

    #[test]
    fn attached_pool_search_matches_reference_and_never_respawns() {
        // ISSUE-7: an explicitly attached persistent pool must (a) leave
        // the search result exactly on the sequential reference estimate
        // and (b) spawn all of its threads at construction — repeated
        // solves reuse them, so the spawn counter never moves again.
        let pool = Arc::new(SearchPool::new(3));
        assert_eq!(pool.threads_spawned(), 3);
        let mut sch = scheduler(16);
        sch.set_search_pool(Arc::clone(&pool));
        let bare = scheduler(16);
        for seed in [11u64, 57, 1234] {
            let mut sampler = sampler(DatasetKind::OpenVid, seed);
            let seqs = sampler.sample_batch(32);
            let pooled = sch.schedule(&seqs);
            pooled.validate(&seqs, 16).unwrap();
            let reference = bare.schedule_reference(&seqs);
            assert!(
                (pooled.search_est_time_s - reference.search_est_time_s).abs()
                    <= 1e-9 * reference.search_est_time_s.max(1.0),
                "seed {seed}: pooled {} vs reference {}",
                pooled.search_est_time_s,
                reference.search_est_time_s
            );
        }
        assert_eq!(
            pool.threads_spawned(),
            3,
            "pool re-spawned threads after construction"
        );
    }

    #[test]
    fn property_lower_bound_never_exceeds_solved_estimate() {
        // Soundness of ALL pruning terms (aggregate work, best single
        // group, and the ISSUE-7 communication floor): the pre-DP bound
        // must never exceed the estimate the full DP solve achieves —
        // an unsound bound would silently prune the true winner.
        forall(30, 0xB0DD, |rng| {
            let npus = *rng.choose(&[8usize, 16, 32]);
            let sch = scheduler(npus);
            let kind = *rng.choose(&DatasetKind::all());
            let mut sampler = sampler(kind, rng.next_u64());
            let seqs = sampler.sample_batch(rng.range_usize(1, 64));
            let fabric = sch.snapshot_fabric();
            let n = fabric.capacity();
            let model_fp = sch.cost.coeffs.fingerprint();
            let mut scratch = SolverScratch::acquire();
            for target in [1usize, 3, 8, npus] {
                let mut groups = packing::pack_with_target_in(
                    &seqs,
                    &sch.cost.memory,
                    n,
                    target,
                    &mut scratch.pack,
                );
                for g in &mut groups {
                    g.d_min = sch.policy.min_admissible(g.d_min).min(n);
                }
                let mut waves = packing::waves_in(&mut groups, n, &mut scratch.pack);
                scratch.pack.put_groups(groups);
                let lb = sch.lower_bound(&waves, &fabric, model_fp, &scratch.cache);
                let draft = sch.solve_waves(&waves, &fabric, model_fp, &mut scratch);
                scratch.pack.reclaim_waves(&mut waves);
                if lb > draft.est_time_s {
                    return Err(format!(
                        "unsound bound {lb} > solved {} (npus={npus}, \
                         target={target}, kind={kind:?})",
                        draft.est_time_s
                    ));
                }
            }
            scratch.release();
            Ok(())
        });
    }

    #[test]
    fn tiny_batches_dedupe_and_stay_valid() {
        // K < 16 makes most group-count targets collapse to identical
        // packings; the deduped search must stay valid and keep the
        // reference estimate exactly.
        let sch = scheduler(16);
        for k in [1usize, 2, 3, 7, 15] {
            let mut sampler = sampler(DatasetKind::OpenVid, 1000 + k as u64);
            let seqs = sampler.sample_batch(k);
            let schedule = sch.schedule(&seqs);
            schedule.validate(&seqs, 16).unwrap();
            let reference = sch.schedule_reference(&seqs);
            assert!(
                (schedule.search_est_time_s - reference.search_est_time_s).abs()
                    <= 1e-9 * reference.search_est_time_s.max(1.0),
                "k {k}: {} vs {}",
                schedule.search_est_time_s,
                reference.search_est_time_s
            );
        }
    }
}
