//! Asynchronous scheduling pipeline (paper §5, implementation detail 2):
//! "while the NPU processes the current batch, the CPU concurrently
//! analyzes the token lengths of the next batch, predicts costs via the
//! Profiler, solves for the optimal plan, and prepares the necessary
//! communication groups" — a producer–consumer pattern that hides the
//! scheduling latency behind accelerator compute.
//!
//! The pipeline drives any [`SchedulePolicy`] (DHP or a baseline) on its
//! background thread and, in its historical owned-pool mode
//! ([`SchedulePipeline::spawn_with_pool`]), also owns an MPU-style
//! parallel state: after solving a batch's PLACED schedule it
//! immediately prepares (prewarms) every communication group the
//! schedule needs through [`ParallelState::prepare_schedule`] — one step
//! ahead of execution, so pool-miss creation cost is paid on this CPU
//! thread while the accelerator is busy with the previous batch, exactly
//! the paper's CPU-side overlap. [`ScheduledBatch`] reports that prepare
//! cost as the FULLY-SERIAL `reconfig_serial_s` (the consumer charges
//! only the non-hidden remainder after overlap), plus the schedule's
//! hint-replay rate and the pool's cumulative statistics.
//!
//! [`crate::session::DhpSession`] instead spawns the pipeline WITHOUT a
//! pool ([`SchedulePipeline::spawn_policy`] with `prewarm_pool = None`):
//! the session owns the run's single communication-group pool, so group
//! creation is accounted exactly once, and mesh-occupancy changes reach
//! the policy through the ordered [`SchedulePipeline::sync_mesh`]
//! control message.
//!
//! Built on std threads + mpsc channels (tokio is unavailable offline;
//! a single scheduling thread matches the paper's design anyway). Solver
//! scratches (DP tables, packing buffers, the memoized cost cache) return
//! to a process-wide pool with their capacity intact, so from the second
//! micro-batch onward every solve on this thread reuses warm buffers
//! instead of allocating (see `scheduler::scratch`).

use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::baselines::{ScheduleError, SchedulePolicy};
use crate::data::sequence::Sequence;
use crate::parallel::group::GROUP_BUFFER_BYTES_PER_RANK;
use crate::parallel::mesh::DeviceMesh;
use crate::parallel::pool::{PoolCapacity, PoolStats};
use crate::parallel::ParallelState;

use super::{Schedule, Scheduler, SearchPool};

/// A message to the scheduling thread: either a batch to plan, or a
/// control update applied in submission order.
enum Job {
    /// Plan one micro-batch (step id + the sequence lengths).
    Schedule {
        step: u64,
        seqs: Vec<Sequence>,
        submitted_at: Instant,
    },
    /// Install an updated mesh (occupancy changed mid-run) into the
    /// policy — and the prewarm MPU, when the pipeline owns one — before
    /// any subsequently submitted batch is solved.
    SyncMesh(DeviceMesh),
}

/// A finished schedule with latency + group-preparation accounting.
pub struct ScheduledBatch {
    /// Step id this schedule belongs to (matches the submit order).
    pub step: u64,
    /// The placed schedule, groups already prewarmed through the pool —
    /// or the policy's typed refusal (a static grid on a shrunk mesh),
    /// which [`crate::session::DhpSession::step`] surfaces as a failed
    /// step instead of a process abort.
    pub schedule: Result<Schedule, ScheduleError>,
    /// End-to-end scheduling-phase latency (queueing + packing + DP +
    /// placement + group prewarm) — Tables 1–2 "Schedule Time".
    pub schedule_latency_s: f64,
    /// Pure solver wall time for this batch, measured on the scheduling
    /// thread around the policy's `schedule` call — no queueing, no
    /// prewarm. This is the number the paper's "millisecond-level
    /// scheduling overhead" claim is about, and what
    /// [`crate::session::StepReport::solver_time_s`] reports. Measured
    /// even when the policy refuses (the refusal check still costs its
    /// wall time).
    pub solve_time_s: f64,
    /// FULLY-SERIAL simulated group-creation seconds paid preparing this
    /// schedule's pool misses. The prepare runs one step ahead on this
    /// CPU thread, so the consumer charges only the non-hidden remainder
    /// `max(0, reconfig_serial_s − prev_step_compute)` — see the trainer's
    /// `reconfig_charged_s` column; this field retains the serial number
    /// for the overlap ablation. Always 0 when the pipeline was spawned
    /// without a pool (`spawn_policy(.., None)`): the session then owns
    /// the pool and accounts creation itself.
    pub reconfig_serial_s: f64,
    /// Hint-quality telemetry: fraction of this schedule's groups that
    /// replayed the previous step's rank blocks
    /// ([`Schedule::replay_rate`]).
    pub replay_rate: f64,
    /// Groups evicted from the pipeline's capacity-capped pool while
    /// preparing THIS batch (0 on an unbounded pool). A persistent
    /// non-zero stream here means the configured [`PoolCapacity`] is
    /// below the workload's working set — the prewarm is thrashing.
    pub evictions: u64,
    /// Cumulative pool statistics after preparing this batch.
    pub pool: PoolStats,
}

/// Handle to the background scheduling thread.
pub struct SchedulePipeline {
    tx: Option<SyncSender<Job>>,
    rx: Receiver<ScheduledBatch>,
    handle: Option<JoinHandle<()>>,
    /// The persistent outer-search worker pool attached to this
    /// pipeline's policy: all workers are spawned here, once, so
    /// steady-state solves never create threads
    /// ([`SearchPool::threads_spawned`] stays constant across steps).
    search_pool: Arc<SearchPool>,
}

impl SchedulePipeline {
    /// Spawn the scheduling thread with an UNBOUNDED pipeline pool (the
    /// seed behavior). `depth` bounds how many batches may be in flight
    /// (the paper schedules exactly one step ahead ⇒ depth 1).
    pub fn spawn(scheduler: Scheduler, depth: usize) -> Self {
        Self::spawn_with_pool(
            scheduler,
            depth,
            PoolCapacity::Unbounded,
            GROUP_BUFFER_BYTES_PER_RANK,
        )
    }

    /// [`SchedulePipeline::spawn`] with the pipeline-side pool budgeted
    /// like the harness path: `capacity` bounds the pipeline's
    /// `ParallelState` pool (LRU eviction on overflow — prewarm then runs
    /// in reverse-wave order so the groups needed soonest stay warmest),
    /// and `group_buffer_bytes` is the cluster's per-member-rank
    /// communicator footprint
    /// ([`crate::config::ClusterConfig::group_buffer_bytes`]) the byte
    /// accounting charges. Per-batch eviction counts surface in
    /// [`ScheduledBatch::evictions`].
    pub fn spawn_with_pool(
        scheduler: Scheduler,
        depth: usize,
        capacity: PoolCapacity,
        group_buffer_bytes: u64,
    ) -> Self {
        let mesh = scheduler.mesh.clone();
        Self::spawn_policy(
            Box::new(scheduler),
            mesh,
            depth,
            Some((capacity, group_buffer_bytes)),
        )
    }

    /// Spawn the scheduling thread around ANY [`SchedulePolicy`] — the
    /// form [`crate::session::DhpSession`] uses, so DHP and the static
    /// baselines all flow through the same producer–consumer pipeline.
    ///
    /// `mesh` is the physical topology the pipeline-side prewarm
    /// validates placements against (and the initial mesh the
    /// [`SchedulePipeline::sync_mesh`] control path updates). With
    /// `prewarm_pool = Some((capacity, group_buffer_bytes))` the thread
    /// owns a [`ParallelState`] and prewarms every schedule one step
    /// ahead (the historical [`SchedulePipeline::spawn_with_pool`]
    /// behavior); with `None` the thread only solves — the caller (the
    /// session) owns the single communication-group pool, so creation
    /// cost is accounted exactly once.
    pub fn spawn_policy(
        policy: Box<dyn SchedulePolicy>,
        mesh: DeviceMesh,
        depth: usize,
        prewarm_pool: Option<(PoolCapacity, u64)>,
    ) -> Self {
        let (tx, job_rx) = mpsc::sync_channel::<Job>(depth.max(1));
        let (done_tx, rx) = mpsc::sync_channel::<ScheduledBatch>(depth.max(1));
        // One persistent search pool per scheduling thread: every worker
        // this pipeline will ever use is spawned right here, before the
        // first batch, so steady-state `step()` is spawn-free.
        let search_pool = Arc::new(SearchPool::with_default_size());
        let policy_pool = Arc::clone(&search_pool);
        let handle = std::thread::Builder::new()
            .name("dhp-scheduler".into())
            .spawn(move || {
                let mut policy = policy;
                policy.attach_search_pool(policy_pool);
                // The pipeline's optional MPU: communication groups are
                // pooled here, across every batch this thread schedules.
                let mut mpu = prewarm_pool.map(|(capacity, bytes)| {
                    ParallelState::new(mesh, 1, 1)
                        .with_pool_capacity(capacity)
                        .with_group_buffer_bytes(bytes)
                });
                while let Ok(job) = job_rx.recv() {
                    let (step, seqs, submitted_at) = match job {
                        Job::SyncMesh(m) => {
                            if let Some(mpu) = mpu.as_mut() {
                                // Ranks newly surrendered to a co-tenant
                                // invalidate any pooled communicator that
                                // spans them — same rule as the session
                                // path, so an owned-pool pipeline never
                                // carries phantom buffer footprint.
                                let surrendered: Vec<_> = (0..m.replicas)
                                    .filter(|&r| {
                                        !m.is_rank_free(r)
                                            && mpu.mesh.is_rank_free(r)
                                    })
                                    .collect();
                                if !surrendered.is_empty() {
                                    mpu.pool_mut()
                                        .invalidate_ranks(&surrendered);
                                }
                                mpu.mesh = m.clone();
                            }
                            // Ordered invalidation: `sync_mesh` both
                            // re-snapshots the policy's mesh AND clears
                            // the scheduler's exact-hit schedule cache
                            // ([`crate::scheduler::schedule_cache`]) in
                            // this same control message, so every batch
                            // submitted after a mesh event is re-solved
                            // — a stale cached placement onto a now-
                            // occupied rank would be a correctness bug.
                            policy.sync_mesh(&m);
                            continue;
                        }
                        Job::Schedule {
                            step,
                            seqs,
                            submitted_at,
                        } => (step, seqs, submitted_at),
                    };
                    let solve_started = Instant::now();
                    let schedule = policy.schedule(&seqs);
                    let solve_time_s = solve_started.elapsed().as_secs_f64();
                    // Prepare the groups one step ahead (CPU-side
                    // overlap). A schedule the policy just validated
                    // cannot fail placement checks; a failure here would
                    // be a policy bug, so surface it loudly. A typed
                    // schedule refusal skips the prewarm entirely — there
                    // is nothing to place.
                    let (reconfig_serial_s, evictions, pool) = match (mpu.as_mut(), schedule.as_ref()) {
                        (Some(mpu), Ok(schedule)) => {
                            let evictions_before = mpu.pool_stats().evictions;
                            let paid = mpu
                                .prepare_schedule(schedule)
                                .expect("policy emitted an invalid placement");
                            (
                                paid,
                                mpu.pool_stats().evictions - evictions_before,
                                mpu.pool_stats(),
                            )
                        }
                        _ => (0.0, 0, PoolStats::default()),
                    };
                    let replay_rate =
                        schedule.as_ref().map(|s| s.replay_rate()).unwrap_or(0.0);
                    let out = ScheduledBatch {
                        step,
                        schedule,
                        schedule_latency_s: submitted_at.elapsed().as_secs_f64(),
                        solve_time_s,
                        reconfig_serial_s,
                        replay_rate,
                        evictions,
                        pool,
                    };
                    if done_tx.send(out).is_err() {
                        break; // consumer gone
                    }
                }
            })
            .expect("spawn scheduler thread");
        SchedulePipeline {
            tx: Some(tx),
            rx,
            handle: Some(handle),
            search_pool,
        }
    }

    /// The persistent search pool this pipeline's policy solves on. The
    /// session uses this to assert the zero-spawn steady state
    /// ([`SearchPool::threads_spawned`] must not move after spawn).
    pub fn search_pool(&self) -> &Arc<SearchPool> {
        &self.search_pool
    }

    /// Submit the next batch's sequences for background scheduling.
    /// Blocks only if `depth` batches are already in flight.
    pub fn submit(&self, step: u64, seqs: Vec<Sequence>) {
        self.tx
            .as_ref()
            .expect("pipeline closed")
            .send(Job::Schedule {
                step,
                seqs,
                submitted_at: Instant::now(),
            })
            .expect("scheduler thread died");
    }

    /// Non-blocking [`SchedulePipeline::submit`]: returns the sequences
    /// back when the job channel is full so the caller can retry later
    /// (the session's deadlock-free submission pump). Panics, like
    /// `submit`, if the scheduling thread died.
    pub fn try_submit(
        &self,
        step: u64,
        seqs: Vec<Sequence>,
    ) -> Result<(), Vec<Sequence>> {
        let job = Job::Schedule {
            step,
            seqs,
            submitted_at: Instant::now(),
        };
        match self.tx.as_ref().expect("pipeline closed").try_send(job) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(Job::Schedule { seqs, .. })) => Err(seqs),
            Err(TrySendError::Full(Job::SyncMesh(_))) => {
                unreachable!("try_submit only enqueues Schedule jobs")
            }
            Err(TrySendError::Disconnected(_)) => panic!("scheduler thread died"),
        }
    }

    /// Install an updated mesh into the scheduling thread. Ordered with
    /// submissions: batches submitted after this call are solved against
    /// the new occupancy, batches already in flight keep the old view —
    /// which is why [`crate::session::DhpSession::apply`] requires the
    /// pipeline to be drained first.
    pub fn sync_mesh(&self, mesh: DeviceMesh) {
        self.tx
            .as_ref()
            .expect("pipeline closed")
            .send(Job::SyncMesh(mesh))
            .expect("scheduler thread died");
    }

    /// Receive the next completed schedule (blocking).
    pub fn recv(&self) -> Option<ScheduledBatch> {
        self.rx.recv().ok()
    }

    /// Close the submission side and join the thread.
    pub fn shutdown(mut self) {
        self.tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for SchedulePipeline {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::by_name;
    use crate::config::{ClusterConfig, TrainStage};
    use crate::cost::{CostCoeffs, CostModel, HardwareSpec, MemoryModel};
    use crate::data::datasets::{DatasetKind, DatasetSampler};
    use crate::parallel::mesh::DeviceMesh;

    fn scheduler() -> Scheduler {
        let cluster = ClusterConfig::default().with_npus(8);
        let preset = by_name("InternVL3-2B").unwrap();
        let hw = HardwareSpec::default();
        let cost = CostModel {
            coeffs: CostCoeffs::analytic(&preset, TrainStage::Full, &hw),
            memory: MemoryModel {
                e_bytes: 8192.0 * preset.act_bytes_per_token() + 1e9,
                m_states: 1e9,
                m_token: preset.act_bytes_per_token(),
            },
        };
        Scheduler::new(cost, DeviceMesh::new(&cluster))
    }

    #[test]
    fn pipeline_preserves_order_and_coverage() {
        let pipe = SchedulePipeline::spawn(scheduler(), 2);
        let mut sampler = DatasetSampler::new(DatasetKind::OpenVid, 51);
        let batches: Vec<Vec<_>> = (0..5).map(|_| sampler.sample_batch(16)).collect();
        for (i, b) in batches.iter().enumerate() {
            pipe.submit(i as u64, b.clone());
        }
        for (i, b) in batches.iter().enumerate() {
            let done = pipe.recv().expect("schedule");
            assert_eq!(done.step, i as u64);
            let schedule = done.schedule.as_ref().unwrap();
            schedule.validate(b, 8).unwrap();
            // Nesting: end-to-end latency ⊇ thread-side solve wall time
            // ⊇ the scheduler's own internal solve measurement.
            assert!(done.schedule_latency_s >= done.solve_time_s);
            assert!(done.solve_time_s >= schedule.solve_time_s);
        }
        assert!(
            pipe.search_pool().threads_spawned() == pipe.search_pool().workers(),
            "search pool must spawn exactly its worker count, once"
        );
        pipe.shutdown();
    }

    #[test]
    fn scheduling_overlaps_with_consumer_work() {
        // Submit batch t+1 before "executing" batch t: the schedule for
        // t+1 must be ready with ~zero additional wait after the consumer
        // finishes its simulated compute.
        let pipe = SchedulePipeline::spawn(scheduler(), 1);
        let mut sampler = DatasetSampler::new(DatasetKind::InternVid, 53);
        pipe.submit(0, sampler.sample_batch(32));
        let first = pipe.recv().unwrap();
        // Pipeline ahead: submit next, then pretend to compute.
        pipe.submit(1, sampler.sample_batch(32));
        std::thread::sleep(std::time::Duration::from_millis(100));
        let t0 = Instant::now();
        let second = pipe.recv().unwrap();
        let wait = t0.elapsed().as_secs_f64();
        assert_eq!(first.step, 0);
        assert_eq!(second.step, 1);
        // Generous bound: the solve itself is sub-ms; the margin absorbs
        // scheduler-thread starvation when the test box is contended.
        assert!(
            wait < 0.08,
            "schedule was not hidden behind compute: waited {wait}s"
        );
        pipe.shutdown();
    }

    #[test]
    fn prewarm_one_step_ahead_makes_pool_hot() {
        // Stationary workload (the trainer's shape: identical batch
        // geometry every step): after the first step establishes the
        // groups, every later prepare must hit the pool — creation cost
        // is paid once, up front, on the scheduler thread.
        // Depth covers every in-flight batch: this test submits the whole
        // stream before receiving, which with a shallow depth would block
        // the submitter against a scheduler blocked on the full result
        // channel (mutual sync-channel deadlock).
        let steps = 12u64;
        let pipe = SchedulePipeline::spawn(scheduler(), steps as usize);
        let mut sampler = DatasetSampler::new(DatasetKind::Msrvtt, 57);
        let batch = sampler.sample_batch(16);
        for i in 0..steps {
            pipe.submit(i, batch.clone());
        }
        let mut last = None;
        for i in 0..steps {
            let done = pipe.recv().expect("schedule");
            assert_eq!(done.step, i);
            if i == 0 {
                assert!(
                    done.reconfig_serial_s > 0.0,
                    "first step must create its groups"
                );
            } else {
                assert_eq!(
                    done.reconfig_serial_s, 0.0,
                    "step {i} re-created groups for an identical batch"
                );
                assert!(
                    done.replay_rate > 0.99,
                    "step {i}: identical batch must fully replay, got {}",
                    done.replay_rate
                );
            }
            last = Some(done);
        }
        let pool = last.unwrap().pool;
        assert!(
            pool.hit_rate() > 0.8,
            "pool hit-rate {:.2} after {steps} stationary steps",
            pool.hit_rate()
        );
        pipe.shutdown();
    }

    #[test]
    fn capped_pipeline_pool_surfaces_evictions() {
        // A capacity far below the workload's working set must thrash —
        // and the thrash must be visible per batch via
        // `ScheduledBatch::evictions`, not silently absorbed.
        use crate::parallel::PoolCapacity;
        let run = |capacity: PoolCapacity,
                   batches: &[Vec<crate::data::sequence::Sequence>]|
         -> Vec<ScheduledBatch> {
            // Depth covers the whole stream (see the prewarm test's note
            // on submit-ahead deadlock with shallow sync channels).
            let pipe = SchedulePipeline::spawn_with_pool(
                scheduler(),
                batches.len(),
                capacity,
                64 << 20,
            );
            for (i, b) in batches.iter().enumerate() {
                pipe.submit(i as u64, b.clone());
            }
            let out: Vec<ScheduledBatch> = (0..batches.len())
                .map(|_| pipe.recv().expect("schedule"))
                .collect();
            pipe.shutdown();
            out
        };
        // Drifting workload (batch geometry changes every step) under a
        // 1-group cap: leftover groups from the previous step's prepare
        // are evicted as soon as the next step's misses arrive.
        let mut sampler = DatasetSampler::new(DatasetKind::OpenVid, 59);
        let drifting: Vec<_> =
            [8usize, 16, 24, 32].iter().map(|&k| sampler.sample_batch(k)).collect();
        let tight = run(PoolCapacity::MaxGroups(1), &drifting);
        assert!(
            tight.iter().map(|b| b.evictions).sum::<u64>() > 0,
            "a 1-group cap on a drifting workload must evict"
        );
        // Per-batch deltas reconcile with the cumulative pool stats.
        assert_eq!(
            tight.last().unwrap().pool.evictions,
            tight.iter().map(|b| b.evictions).sum::<u64>(),
        );
        // A stationary workload under a generous cap never evicts and
        // stays hot.
        let stationary: Vec<_> = (0..8).map(|_| drifting[2].clone()).collect();
        let roomy = run(PoolCapacity::MaxGroups(1024), &stationary);
        assert!(roomy.iter().all(|b| b.evictions == 0));
        assert!(roomy.last().unwrap().pool.hit_rate() > 0.8);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pipe = SchedulePipeline::spawn(scheduler(), 1);
        pipe.submit(0, vec![]);
        drop(pipe); // must not hang or panic
    }
}
