//! Reusable solver scratch (the §Perf arena): DP tables, packing buffers,
//! and a memoized cost-model cache, recycled across candidate solves and
//! across micro-batches so the steady-state planner stops allocating on
//! the hot path.
//!
//! Three pieces:
//!
//! * [`DpTables`] — the flat DP/path/t_of_d buffers `dp::allocate_degrees_in`
//!   writes into. One wave solve at GBS 512 / N 64 previously allocated
//!   ~4 tables × (K′+1)·(N+1) cells per candidate target; now the buffers
//!   persist and only `resize` (no-op once capacity is reached).
//! * [`PackScratch`] — the BFD packing's sort-order buffer plus free-lists
//!   for bin index vectors and wave containers, reclaimed after each
//!   candidate's plan is assembled.
//! * [`CostCache`] — memoized `T(agg, d, bw)` evaluations keyed on the
//!   *content* of the workload aggregate plus a cost-model fingerprint
//!   ([`crate::cost::CostCoeffs::fingerprint`]) plus a fabric-state
//!   fingerprint ([`super::FabricModel::fingerprint`]). The same atomic
//!   groups recur across the balance-target outer search (singleton bins
//!   in particular are shared by most targets), so candidate solves after
//!   the first hit the cache for the bulk of their cost-model queries.
//!   Because keys are content-addressed, entries stay valid across
//!   micro-batches and across schedulers (the model fingerprint isolates
//!   different coefficient sets; the fabric fingerprint keeps entries
//!   memoized under one mesh occupancy state from ever being served
//!   under a state whose bandwidth oracle answers differ); the map is
//!   bounded and cleared wholesale at capacity.
//!
//! A process-wide pool ([`SolverScratch::acquire`]/[`SolverScratch::release`])
//! hands scratches to the outer-search worker threads; after the first few
//! batches every worker draws a warm scratch, which is what makes the
//! per-micro-batch solve allocation-free in steady state (the returned
//! `Schedule` itself still owns its plan vectors — that output allocation
//! is inherent).

use std::cell::RefCell;
use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};
use std::sync::{Mutex, OnceLock};

use super::packing::AtomicGroup;
use crate::cost::{CostModel, WorkloadAgg};

/// Flat DP buffers for one `allocate_degrees_in` solve (reused across
/// waves, candidates, and micro-batches).
#[derive(Debug, Default)]
pub struct DpTables {
    /// `DP[i][j]` row-major, `(k+1) × (n+1)`.
    pub(crate) dp: Vec<f64>,
    /// Rank budget consumed by the transition at each cell (backtrack step).
    pub(crate) slot: Vec<u32>,
    /// Actual degree chosen at each cell (≤ slot; the prefix-min argmin).
    pub(crate) deg: Vec<u32>,
    /// Prefix-min of the admissible cost curve for the current group.
    pub(crate) tmin: Vec<f64>,
    /// Argmin degree behind each `tmin` entry.
    pub(crate) argt: Vec<u32>,
    /// Prefix sums of minimum degrees.
    pub(crate) prefix: Vec<usize>,
    /// Clamped minimum degrees.
    pub(crate) dmin: Vec<usize>,
}

/// Reusable buffers for BFD packing and wave splitting.
#[derive(Debug, Default)]
pub struct PackScratch {
    /// Sequence indices sorted by memory demand (reused sort buffer).
    pub(crate) order: Vec<usize>,
    /// Free-list of bin index vectors (cleared, capacity retained).
    pub(crate) idx_pool: Vec<Vec<usize>>,
    /// Free-list of `Vec<AtomicGroup>` containers (groups and waves).
    pub(crate) group_pool: Vec<Vec<AtomicGroup>>,
}

const IDX_POOL_CAP: usize = 1024;
const GROUP_POOL_CAP: usize = 64;

impl PackScratch {
    /// Pop a recycled index vector (or a fresh one).
    pub fn take_idxs(&mut self) -> Vec<usize> {
        self.idx_pool.pop().unwrap_or_default()
    }

    /// Pop a recycled group container (or a fresh one).
    pub fn take_groups(&mut self) -> Vec<AtomicGroup> {
        self.group_pool.pop().unwrap_or_default()
    }

    /// Return a drained group container to the free-list.
    pub fn put_groups(&mut self, mut v: Vec<AtomicGroup>) {
        debug_assert!(v.is_empty());
        if self.group_pool.len() < GROUP_POOL_CAP {
            v.clear();
            self.group_pool.push(v);
        }
    }

    /// Reclaim the index vectors of a drained-in-place group list (the
    /// container itself stays with the caller — hand it back via
    /// [`PackScratch::put_groups`]).
    pub fn reclaim_groups(&mut self, groups: &mut Vec<AtomicGroup>) {
        for g in groups.drain(..) {
            let mut idxs = g.seq_idxs;
            idxs.clear();
            if self.idx_pool.len() < IDX_POOL_CAP {
                self.idx_pool.push(idxs);
            }
        }
    }

    /// Reclaim every buffer inside a wave set once the candidate's plan
    /// has been assembled (plans clone the index lists they keep).
    pub fn reclaim_waves(&mut self, waves: &mut Vec<Vec<AtomicGroup>>) {
        for mut wave in waves.drain(..) {
            for g in wave.drain(..) {
                let mut idxs = g.seq_idxs;
                idxs.clear();
                if self.idx_pool.len() < IDX_POOL_CAP {
                    self.idx_pool.push(idxs);
                }
            }
            self.put_groups(wave);
        }
    }
}

/// FNV/SplitMix-style hasher for the cost-cache keys (the keys are
/// already well-mixed 64-bit pairs; SipHash would dominate the lookup).
#[derive(Default)]
pub struct KeyHasher(u64);

impl Hasher for KeyHasher {
    fn finish(&self) -> u64 {
        self.0
    }

    fn write(&mut self, bytes: &[u8]) {
        let mut h = if self.0 == 0 { 0xcbf29ce484222325 } else { self.0 };
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        self.0 = h;
    }

    fn write_u64(&mut self, x: u64) {
        let mut h = self.0 ^ x;
        h = h.wrapping_mul(0x9E3779B97F4A7C15);
        h ^= h >> 32;
        self.0 = h;
    }
}

/// SplitMix64 finalizer — used to build content keys here and the
/// fabric-oracle fingerprint in [`super::fabric`].
pub(crate) fn mix(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

const CACHE_CAP: usize = 1 << 17;

/// Memoized cost-model evaluations, content-keyed (see module docs).
#[derive(Debug, Default)]
pub struct CostCache {
    map: RefCell<HashMap<(u64, u64), f64, BuildHasherDefault<KeyHasher>>>,
}

impl CostCache {
    fn key(
        model_fp: u64,
        fabric_fp: u64,
        agg: &WorkloadAgg,
        d: usize,
        bw: f64,
    ) -> (u64, u64) {
        let a = mix(model_fp ^ agg.quad.to_bits())
            .wrapping_add(mix(agg.tokens.to_bits() ^ (d as u64).rotate_left(32)))
            .wrapping_add(mix(fabric_fp ^ 0xA5A5_5A5A_C3C3_3C3C));
        let b = mix(agg.quad_base.to_bits() ^ bw.to_bits())
            .wrapping_add(mix((agg.count as u64) ^ (d as u64) ^ model_fp.rotate_left(17)))
            .wrapping_add(mix(fabric_fp.rotate_left(29)));
        (a, b)
    }

    /// `T(agg, d, bw)` through the memo table. `model_fp` must be
    /// [`crate::cost::CostCoeffs::fingerprint`] of `cost.coeffs` — it keeps
    /// entries from different cost models apart in the shared pool.
    /// `fabric_fp` must be the [`super::FabricModel::fingerprint`] of the
    /// fabric snapshot the query is costed against — entries memoized
    /// under one fabric state are never served under a state whose
    /// oracle answers differ (scratches are pooled process-wide and
    /// outlive any single mesh state; the fingerprint is semantic, so
    /// states with identical answers deliberately share entries).
    pub fn t_total(
        &self,
        model_fp: u64,
        fabric_fp: u64,
        cost: &CostModel,
        agg: &WorkloadAgg,
        d: usize,
        bw: f64,
    ) -> f64 {
        let key = Self::key(model_fp, fabric_fp, agg, d, bw);
        if let Some(&t) = self.map.borrow().get(&key) {
            return t;
        }
        let t = cost.t_total(agg, d, bw);
        let mut map = self.map.borrow_mut();
        if map.len() >= CACHE_CAP {
            map.clear();
        }
        map.insert(key, t);
        t
    }

    /// Number of resident entries (diagnostics/tests).
    pub fn len(&self) -> usize {
        self.map.borrow().len()
    }

    /// True when no entries are resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The full per-worker solver arena.
#[derive(Debug, Default)]
pub struct SolverScratch {
    pub(crate) dp: DpTables,
    pub(crate) pack: PackScratch,
    pub(crate) cache: CostCache,
}

const POOL_CAP: usize = 64;

static SCRATCH_POOL: Mutex<Vec<SolverScratch>> = Mutex::new(Vec::new());

impl SolverScratch {
    /// Draw a warm scratch from the process-wide pool (or a cold one).
    pub fn acquire() -> SolverScratch {
        SCRATCH_POOL
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .pop()
            .unwrap_or_default()
    }

    /// Return a scratch to the pool for the next solve.
    pub fn release(self) {
        let mut pool = SCRATCH_POOL.lock().unwrap_or_else(|e| e.into_inner());
        if pool.len() < POOL_CAP {
            pool.push(self);
        }
    }

}

/// Worker count for the parallel plan search: `DHP_SOLVER_THREADS`
/// overrides; otherwise available parallelism capped at 8 (the outer
/// search has ~20 candidates — more threads than that just contend).
pub fn solver_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(v) = std::env::var("DHP_SOLVER_THREADS") {
            if let Ok(x) = v.parse::<usize>() {
                return x.clamp(1, 64);
            }
        }
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(8)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::by_name;
    use crate::config::TrainStage;
    use crate::cost::{CostCoeffs, HardwareSpec, MemoryModel};

    fn cost_model() -> CostModel {
        let preset = by_name("InternVL3-8B").unwrap();
        CostModel {
            coeffs: CostCoeffs::analytic(&preset, TrainStage::Full, &HardwareSpec::default()),
            memory: MemoryModel::new(&preset, 64e9, 8),
        }
    }

    #[test]
    fn cache_returns_exact_model_values() {
        let cost = cost_model();
        let fp = cost.coeffs.fingerprint();
        let cache = CostCache::default();
        let mut agg = WorkloadAgg::default();
        agg.add(&crate::data::sequence::Sequence::new(0, 2000, 1000));
        for d in 1..=16usize {
            let want = cost.t_total(&agg, d, 12.5e9);
            // First call computes, second must hit and return the bit-same value.
            assert_eq!(cache.t_total(fp, 7, &cost, &agg, d, 12.5e9).to_bits(), want.to_bits());
            assert_eq!(cache.t_total(fp, 7, &cost, &agg, d, 12.5e9).to_bits(), want.to_bits());
        }
        assert_eq!(cache.len(), 16);
    }

    #[test]
    fn cache_separates_models_by_fingerprint() {
        let cost_a = cost_model();
        let mut cost_b = cost_model();
        cost_b.coeffs.alpha1 *= 2.0;
        assert_ne!(cost_a.coeffs.fingerprint(), cost_b.coeffs.fingerprint());
        let cache = CostCache::default();
        let mut agg = WorkloadAgg::default();
        agg.add(&crate::data::sequence::Sequence::new(0, 512, 512));
        let ta = cache.t_total(cost_a.coeffs.fingerprint(), 7, &cost_a, &agg, 4, 12.5e9);
        let tb = cache.t_total(cost_b.coeffs.fingerprint(), 7, &cost_b, &agg, 4, 12.5e9);
        assert!(ta != tb, "fingerprints failed to separate models");
    }

    #[test]
    fn cache_isolates_fabric_states() {
        // The ISSUE-4 isolation gate: an entry memoized under one fabric
        // fingerprint must never be served under another. Probe it the
        // adversarial way — same model fingerprint, same (agg, d, bw)
        // key ingredients, but genuinely different cost models: only the
        // fabric fingerprint separates them, so a cross-serve would
        // return the wrong model's value.
        let cost_a = cost_model();
        let mut cost_b = cost_model();
        cost_b.coeffs.alpha2 *= 3.0;
        let shared_model_fp = cost_a.coeffs.fingerprint();
        let cache = CostCache::default();
        let mut agg = WorkloadAgg::default();
        agg.add(&crate::data::sequence::Sequence::new(0, 1024, 256));
        let fab_a = 0xAAAA_0001u64;
        let fab_b = 0xBBBB_0002u64;
        let ta = cache.t_total(shared_model_fp, fab_a, &cost_a, &agg, 4, 12.5e9);
        let tb = cache.t_total(shared_model_fp, fab_b, &cost_b, &agg, 4, 12.5e9);
        assert_eq!(cache.len(), 2, "fabric states must key separate entries");
        assert_ne!(
            ta.to_bits(),
            tb.to_bits(),
            "entry from fabric A was served under fabric B"
        );
        // And each fabric keeps returning its own memoized value.
        assert_eq!(
            cache.t_total(shared_model_fp, fab_a, &cost_b, &agg, 4, 12.5e9).to_bits(),
            ta.to_bits()
        );
        assert_eq!(
            cache.t_total(shared_model_fp, fab_b, &cost_a, &agg, 4, 12.5e9).to_bits(),
            tb.to_bits()
        );
    }

    #[test]
    fn pool_roundtrips_scratches() {
        // The pool is process-global and shared with concurrently running
        // tests, so only the round-trip contract is asserted here (buffer
        // capacity retention is covered deterministically by the
        // DpTables/PackScratch tests, which own their scratches).
        let mut s = SolverScratch::acquire();
        s.dp.dp.resize(1024, 0.0);
        s.release();
        let s2 = SolverScratch::acquire();
        s2.release();
    }

    #[test]
    fn pack_scratch_reclaims_buffers() {
        let mut p = PackScratch::default();
        let mut waves = vec![vec![AtomicGroup {
            seq_idxs: vec![1, 2, 3],
            d_min: 1,
            mem_bytes: 0.0,
            capacity_bytes: 1.0,
            work_cap: 1.0,
            agg: WorkloadAgg::default(),
        }]];
        p.reclaim_waves(&mut waves);
        assert!(waves.is_empty());
        assert_eq!(p.idx_pool.len(), 1);
        assert_eq!(p.group_pool.len(), 1);
        let idxs = p.take_idxs();
        assert!(idxs.is_empty() && idxs.capacity() >= 3);
    }

    #[test]
    fn solver_threads_positive() {
        assert!(solver_threads() >= 1);
    }
}
