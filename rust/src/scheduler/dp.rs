//! Stage 2 — Optimal Resource Assignment via 2D Dynamic Programming
//! (paper §4.3, Algorithm 1).
//!
//! `DP[i][j]` = minimum achievable makespan for the first `i` atomic
//! groups using `j` ranks in total; transition
//!
//! ```text
//! DP[i][j] = min over d in [d_min_i, j − Σ_{m<i} d_min_m]
//!            of max(DP[i−1][j−d], T(G_i, d))
//! ```
//!
//! with a `Path` table for backtracking. Complexity O(K′·N²) — the
//! millisecond-scale solve the paper's Tables 1–2 measure.
//!
//! One deliberate refinement over the paper's pseudocode: because per-hop
//! ring overheads make T(G, d) non-monotone in d, using *all* N ranks is
//! not always optimal; we therefore backtrack from `argmin_j DP[K′][j]`
//! (Cond. 6 is an inequality, Σd_p ≤ N, so this stays within the paper's
//! constraint set and can only improve the objective).

use super::packing::AtomicGroup;

/// Outcome of a DP solve.
#[derive(Debug, Clone, PartialEq)]
pub struct DpSolution {
    /// Chosen CP degree per atomic group (input order).
    pub degrees: Vec<usize>,
    /// Predicted makespan (max per-group estimated time).
    pub makespan_s: f64,
    /// Total ranks used (≤ N).
    pub ranks_used: usize,
}

/// Solve the degree-allocation problem for one wave of atomic groups.
///
/// * `n` — available ranks (paper's N).
/// * `time` — T(G_i, d): estimated execution time of group `i` at degree
///   `d` (the cost model closure; kept abstract so baselines and tests can
///   inject their own).
/// * `allowed` — degree admissibility filter (DHP: any integer → always
///   true; FlexSP-style baselines: powers of two only).
///
/// Panics if Σ d_min > n (the wave planner guarantees feasibility).
pub fn allocate_degrees<T, A>(
    groups: &[AtomicGroup],
    n: usize,
    time: T,
    allowed: A,
) -> DpSolution
where
    T: Fn(usize, usize) -> f64,
    A: Fn(usize) -> bool,
{
    let k = groups.len();
    if k == 0 {
        return DpSolution {
            degrees: vec![],
            makespan_s: 0.0,
            ranks_used: 0,
        };
    }
    // Effective minimum degrees, clamped to the cluster.
    let d_min: Vec<usize> = groups.iter().map(|g| g.d_min.min(n).max(1)).collect();
    // Prefix sums of d_min: prefix[i] = Σ_{m<i} d_min_m.
    let mut prefix = vec![0usize; k + 1];
    for i in 0..k {
        prefix[i + 1] = prefix[i] + d_min[i];
    }
    assert!(
        prefix[k] <= n,
        "wave infeasible: sum of min degrees {} > N = {n}",
        prefix[k]
    );

    const INF: f64 = f64::INFINITY;
    // Flat DP + Path tables, row-major [(k+1) × (n+1)].
    let width = n + 1;
    let mut dp = vec![INF; (k + 1) * width];
    let mut path = vec![0usize; (k + 1) * width];
    dp[0] = 0.0; // DP[0][0]

    for i in 1..=k {
        let dmin_i = d_min[i - 1];
        // Ranks that must be reserved for the remaining groups.
        let remain: usize = prefix[k] - prefix[i];
        let j_lo = prefix[i];
        let j_hi = n - remain;
        // Precompute T(G_i, d) for all candidate degrees once per group —
        // the same value is reused across all j (perf: avoids O(N²) cost-
        // model calls per group).
        let d_max_global = j_hi - prefix[i - 1];
        let mut t_of_d = vec![INF; d_max_global + 1];
        for (d, slot) in t_of_d.iter_mut().enumerate().skip(dmin_i) {
            if allowed(d) {
                *slot = time(i - 1, d);
            }
        }
        for j in j_lo..=j_hi {
            let d_hi = j - prefix[i - 1];
            let mut best = INF;
            let mut best_d = 0;
            for d in dmin_i..=d_hi {
                let t = t_of_d[d];
                if !t.is_finite() {
                    continue;
                }
                let prev = dp[(i - 1) * width + (j - d)];
                if !prev.is_finite() {
                    continue;
                }
                let cost = prev.max(t);
                if cost < best {
                    best = cost;
                    best_d = d;
                }
            }
            dp[i * width + j] = best;
            path[i * width + j] = best_d;
        }
    }

    // Backtrack from the best total rank usage (see module docs).
    let mut best_j = prefix[k];
    for j in prefix[k]..=n {
        if dp[k * width + j] < dp[k * width + best_j] {
            best_j = j;
        }
    }
    let makespan = dp[k * width + best_j];
    assert!(
        makespan.is_finite(),
        "DP found no feasible allocation (degree filter too strict?)"
    );
    let mut degrees = vec![0usize; k];
    let mut j = best_j;
    for i in (1..=k).rev() {
        let d = path[i * width + j];
        degrees[i - 1] = d;
        j -= d;
    }
    debug_assert_eq!(j, 0);
    DpSolution {
        ranks_used: degrees.iter().sum(),
        degrees,
        makespan_s: makespan,
    }
}

/// Degree filter admitting every positive integer (DHP's relaxation).
pub fn any_degree(_d: usize) -> bool {
    true
}

/// Degree filter admitting powers of two only (Ulysses/FlexSP-style
/// head-divisibility restriction the paper §4.1 contrasts against).
pub fn pow2_degree(d: usize) -> bool {
    d.is_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::WorkloadAgg;
    use crate::util::quickcheck::forall;

    fn mk_groups(d_mins: &[usize], works: &[f64]) -> Vec<AtomicGroup> {
        d_mins
            .iter()
            .zip(works)
            .enumerate()
            .map(|(i, (&d, &w))| AtomicGroup {
                seq_idxs: vec![i],
                d_min: d,
                mem_bytes: 0.0,
                capacity_bytes: 1.0,
                work_cap: 1.0,
                agg: WorkloadAgg {
                    quad: w,
                    quad_base: w,
                    tokens: w,
                    count: 1,
                },
            })
            .collect()
    }

    /// Idealized cost: perfectly divisible work, no comm penalty.
    fn ideal(groups: &[AtomicGroup]) -> impl Fn(usize, usize) -> f64 + '_ {
        move |i, d| groups[i].agg.quad / d as f64
    }

    #[test]
    fn single_group_gets_all_useful_ranks() {
        let groups = mk_groups(&[1], &[100.0]);
        let sol = allocate_degrees(&groups, 8, ideal(&groups), any_degree);
        assert_eq!(sol.degrees, vec![8]);
        assert!((sol.makespan_s - 12.5).abs() < 1e-9);
    }

    #[test]
    fn proportional_split_between_unequal_groups() {
        // Work 300 vs 100 over 8 ranks: optimal split 6/2 (makespan 50).
        let groups = mk_groups(&[1, 1], &[300.0, 100.0]);
        let sol = allocate_degrees(&groups, 8, ideal(&groups), any_degree);
        assert_eq!(sol.degrees, vec![6, 2]);
        assert!((sol.makespan_s - 50.0).abs() < 1e-9);
    }

    #[test]
    fn non_power_of_two_degrees_win() {
        // The paper's headline relaxation: with 3 equal groups on 9 ranks,
        // DHP picks 3+3+3; a pow2-restricted solver must accept worse.
        let groups = mk_groups(&[1, 1, 1], &[90.0, 90.0, 90.0]);
        let dhp = allocate_degrees(&groups, 9, ideal(&groups), any_degree);
        assert_eq!(dhp.degrees, vec![3, 3, 3]);
        let pow2 = allocate_degrees(&groups, 9, ideal(&groups), pow2_degree);
        assert!(pow2.makespan_s > dhp.makespan_s, "{pow2:?} vs {dhp:?}");
    }

    #[test]
    fn respects_min_degrees() {
        let groups = mk_groups(&[4, 2, 1], &[10.0, 10.0, 1000.0]);
        let sol = allocate_degrees(&groups, 8, ideal(&groups), any_degree);
        assert!(sol.degrees[0] >= 4);
        assert!(sol.degrees[1] >= 2);
        assert!(sol.degrees[2] >= 1);
        assert!(sol.ranks_used <= 8);
    }

    #[test]
    fn may_leave_ranks_idle_when_degrees_hurt() {
        // Cost grows past d=2 (hop overheads dominate): the solver must
        // NOT burn all ranks.
        let groups = mk_groups(&[1], &[10.0]);
        let time = |_i: usize, d: usize| {
            if d <= 2 {
                10.0 / d as f64
            } else {
                5.0 + (d as f64 - 2.0) * 3.0
            }
        };
        let sol = allocate_degrees(&groups, 64, time, any_degree);
        assert_eq!(sol.degrees, vec![2]);
        assert_eq!(sol.ranks_used, 2);
    }

    #[test]
    #[should_panic(expected = "infeasible")]
    fn infeasible_wave_panics() {
        let groups = mk_groups(&[8, 8], &[1.0, 1.0]);
        allocate_degrees(&groups, 8, ideal(&groups), any_degree);
    }

    #[test]
    fn empty_input() {
        let sol = allocate_degrees(&[], 8, |_, _| 0.0, any_degree);
        assert!(sol.degrees.is_empty());
        assert_eq!(sol.makespan_s, 0.0);
    }

    #[test]
    fn dp_beats_uniform_on_skewed_work() {
        // Skewed workload: DP's makespan must beat the uniform static
        // split (Fig. 2's message).
        let works = [640.0, 80.0, 40.0, 40.0];
        let groups = mk_groups(&[1, 1, 1, 1], &works);
        let sol = allocate_degrees(&groups, 16, ideal(&groups), any_degree);
        // Uniform static: 4 groups × degree 4 → makespan 640/4 = 160.
        assert!(
            sol.makespan_s < 160.0 * 0.7,
            "DP {0} vs uniform 160",
            sol.makespan_s
        );
    }

    #[test]
    fn property_dp_optimality_vs_bruteforce() {
        // For small instances, the DP must match exhaustive search.
        forall(40, 0x2DDF, |rng| {
            let k = rng.range_usize(1, 4);
            let n = rng.range_usize(k, 9);
            let d_mins: Vec<usize> = (0..k).map(|_| 1).collect();
            let works: Vec<f64> =
                (0..k).map(|_| rng.range_f64(1.0, 100.0)).collect();
            let groups = mk_groups(&d_mins, &works);
            // Non-trivial cost: parallel speedup + per-degree overhead.
            let time =
                |i: usize, d: usize| works[i] / d as f64 + 0.7 * d as f64;
            let sol = allocate_degrees(&groups, n, time, any_degree);

            // Brute force over all degree vectors with Σd ≤ n.
            fn rec(
                k: usize,
                n_left: usize,
                idx: usize,
                cur: f64,
                time: &dyn Fn(usize, usize) -> f64,
                best: &mut f64,
            ) {
                if idx == k {
                    *best = best.min(cur);
                    return;
                }
                let reserve = k - idx - 1; // 1 rank per remaining group
                for d in 1..=(n_left - reserve) {
                    rec(k, n_left - d, idx + 1, cur.max(time(idx, d)), time, best);
                }
            }
            let mut best = f64::INFINITY;
            rec(k, n, 0, 0.0, &time, &mut best);
            if (sol.makespan_s - best).abs() > 1e-9 {
                return Err(format!(
                    "dp {} != brute {} (works {works:?}, n={n})",
                    sol.makespan_s, best
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn property_solution_always_valid() {
        forall(50, 0xA110C, |rng| {
            let k = rng.range_usize(1, 12);
            let n = rng.range_usize(12, 65);
            let d_mins: Vec<usize> =
                (0..k).map(|_| rng.range_usize(1, 4)).collect();
            if d_mins.iter().sum::<usize>() > n {
                return Ok(()); // infeasible waves are the planner's job
            }
            let works: Vec<f64> =
                (0..k).map(|_| rng.range_f64(1.0, 1000.0)).collect();
            let groups = mk_groups(&d_mins, &works);
            let time = |i: usize, d: usize| works[i] / d as f64 + d as f64;
            let sol = allocate_degrees(&groups, n, time, any_degree);
            if sol.degrees.len() != k {
                return Err("wrong arity".into());
            }
            if sol.ranks_used > n {
                return Err(format!("over budget: {} > {n}", sol.ranks_used));
            }
            for (i, &d) in sol.degrees.iter().enumerate() {
                if d < d_mins[i] {
                    return Err(format!("d[{i}]={d} < dmin {}", d_mins[i]));
                }
            }
            // Makespan consistency.
            let ms = sol
                .degrees
                .iter()
                .enumerate()
                .map(|(i, &d)| time(i, d))
                .fold(0.0f64, f64::max);
            if (ms - sol.makespan_s).abs() > 1e-9 {
                return Err(format!("makespan mismatch {ms} vs {}", sol.makespan_s));
            }
            Ok(())
        });
    }
}
