//! Stage 2 — Optimal Resource Assignment via 2D Dynamic Programming
//! (paper §4.3, Algorithm 1), reformulated for near-linear solves.
//!
//! The paper's pseudocode uses an *exact-j* state — `DP[i][j]` = best
//! makespan for the first `i` atomic groups using exactly `j` ranks — with
//! an O(N) inner minimization, i.e. O(K′·N²) total. The production solver
//! here ([`allocate_degrees`]) restates the problem as **"at most j
//! ranks"**:
//!
//! ```text
//! DP≤[i][j] = min over slots d in [d_min_i, j − Σ_{m<i} d_min_m]
//!             of max(DP≤[i−1][j−d], Tmin_i(d))
//! Tmin_i(d) = min over admissible d' in [d_min_i, d] of T(G_i, d')
//! ```
//!
//! Two structural facts make this fast:
//!
//! 1. every row of `DP≤` is monotone **non-increasing** in `j` (more rank
//!    budget can only help, because budget may be left idle), so the
//!    previous-row term `DP≤[i−1][j−d]` is non-decreasing in `d`;
//! 2. `Tmin` (the prefix-min of the raw, possibly non-monotone cost curve
//!    — per-hop ring overheads make `T(G, d)` rise again at large `d`) is
//!    non-increasing in `d` by construction.
//!
//! The inner objective is therefore the max of one non-decreasing and one
//! non-increasing function of `d`, minimized at their crossing. The matrix
//! `M[j][d] = max(DP≤[i−1][j−d], Tmin_i(d))` is totally monotone, and for
//! valley-shaped rows with a monotone crossing the full SMAWK machinery
//! degenerates to something even simpler: the crossing slot is
//! **non-decreasing in `j`** (raising `j` only lowers the previous-row
//! term at a fixed `d`, pushing the crossing right), so one cursor swept
//! left-to-right across the row finds every cell's crossing in O(1)
//! amortized — O(K′·N) per wave total, the log factor gone. The
//! prefix-min + per-cell binary-search transition (O(K′·N·log N)) is
//! retained verbatim as [`allocate_degrees_prefixmin`]: the monotone
//! sweep is regression-tested **bit-identical** to it (and both to the
//! exact-j oracle) on randomized non-monotone cost tables. On rows where
//! a degree filter with gaps leaves ∞ cells in the table (impossible for
//! the scheduler's real waves — policy rounding guarantees an admissible
//! degree at every `d_min`), the sweep's monotonicity certificate fails
//! and the hot path falls back to the bisection for the remaining rows,
//! so the two paths agree on *every* input by construction.
//!
//! Substituting `Tmin` for `T` is exact:
//! any slot `d` with argmin `d' ≤ d` yields a feasible allocation (group
//! `i` really uses `d'` ranks and simply leaves `d − d'` idle — Cond. 6 is
//! an inequality, Σd_p ≤ N), and conversely every allocation is dominated
//! by the slot at its own degree. The backtrack records both the slot (to
//! walk the table) and the argmin degree (the group's actual assignment).
//!
//! The at-most formulation also absorbs the seed's argmin-over-`j`
//! refinement for free: `DP≤[K′][N]` already considers leaving ranks idle
//! when hop overheads make full utilization counterproductive.
//!
//! The paper-faithful exact-j solver is retained as
//! [`allocate_degrees_reference`] — it is the equivalence oracle for the
//! property tests below and the "before" case in `benches/solver_micro.rs`.

use super::packing::AtomicGroup;
use super::scratch::DpTables;

/// Outcome of a DP solve.
#[derive(Debug, Clone, PartialEq)]
pub struct DpSolution {
    /// Chosen CP degree per atomic group (input order).
    pub degrees: Vec<usize>,
    /// Predicted makespan (max per-group estimated time).
    pub makespan_s: f64,
    /// Total ranks used (≤ N).
    pub ranks_used: usize,
}

/// Solve the degree-allocation problem for one wave of atomic groups.
///
/// * `n` — available ranks (paper's N; the scheduler passes its fabric
///   snapshot's capacity — free replicas — so a fragmented mesh shrinks
///   the budget).
/// * `time` — T(G_i, d): estimated execution time of group `i` at degree
///   `d` (the cost model closure; the scheduler evaluates it at the
///   fabric oracle's bandwidth for `d`,
///   [`crate::scheduler::FabricModel::bw_for_degree`] — kept abstract so
///   baselines and tests can inject their own).
/// * `allowed` — degree admissibility filter (DHP: any integer → always
///   true; FlexSP-style baselines: powers of two only).
///
/// Panics if Σ d_min > n (the wave planner guarantees feasibility).
///
/// Allocates fresh DP tables; the hot path threads a reused
/// [`DpTables`] through [`allocate_degrees_in`] instead.
pub fn allocate_degrees<T, A>(
    groups: &[AtomicGroup],
    n: usize,
    time: T,
    allowed: A,
) -> DpSolution
where
    T: Fn(usize, usize) -> f64,
    A: Fn(usize) -> bool,
{
    allocate_degrees_in(&mut DpTables::default(), groups, n, time, allowed)
}

/// [`allocate_degrees`] writing into caller-owned scratch tables (zero
/// table allocations once the buffers are warm). Uses the O(K′·N)
/// monotone row-minima sweep (see module docs).
pub fn allocate_degrees_in<T, A>(
    bufs: &mut DpTables,
    groups: &[AtomicGroup],
    n: usize,
    time: T,
    allowed: A,
) -> DpSolution
where
    T: Fn(usize, usize) -> f64,
    A: Fn(usize) -> bool,
{
    solve_at_most_in(bufs, groups, n, time, allowed, true)
}

/// The retained prefix-min + per-cell binary-search transition
/// (O(K′·N·log N) per wave): the production path before the monotone
/// sweep landed, kept as a bit-equivalence reference alongside the
/// exact-j [`allocate_degrees_reference`]. Allocates fresh tables; see
/// [`allocate_degrees_prefixmin_in`] for the scratch-threaded form.
pub fn allocate_degrees_prefixmin<T, A>(
    groups: &[AtomicGroup],
    n: usize,
    time: T,
    allowed: A,
) -> DpSolution
where
    T: Fn(usize, usize) -> f64,
    A: Fn(usize) -> bool,
{
    allocate_degrees_prefixmin_in(&mut DpTables::default(), groups, n, time, allowed)
}

/// [`allocate_degrees_prefixmin`] writing into caller-owned scratch
/// tables.
pub fn allocate_degrees_prefixmin_in<T, A>(
    bufs: &mut DpTables,
    groups: &[AtomicGroup],
    n: usize,
    time: T,
    allowed: A,
) -> DpSolution
where
    T: Fn(usize, usize) -> f64,
    A: Fn(usize) -> bool,
{
    solve_at_most_in(bufs, groups, n, time, allowed, false)
}

/// The shared at-most-j solver. `sweep` selects the transition: the
/// O(K′·N) monotone-crossing cursor (hot path) or the O(K′·N·log N)
/// per-cell bisection (retained reference). Both find the same crossing
/// slot for every cell, so the two paths produce bit-identical tables —
/// the sweep additionally certifies its own preconditions row by row and
/// downgrades to the bisection when they fail (∞-bearing rows under
/// gapped degree filters), making the equivalence unconditional.
fn solve_at_most_in<T, A>(
    bufs: &mut DpTables,
    groups: &[AtomicGroup],
    n: usize,
    time: T,
    allowed: A,
    sweep: bool,
) -> DpSolution
where
    T: Fn(usize, usize) -> f64,
    A: Fn(usize) -> bool,
{
    let k = groups.len();
    if k == 0 {
        return DpSolution {
            degrees: vec![],
            makespan_s: 0.0,
            ranks_used: 0,
        };
    }
    // Effective minimum degrees (clamped to the cluster) + prefix sums.
    bufs.dmin.clear();
    bufs.dmin.extend(groups.iter().map(|g| g.d_min.min(n).max(1)));
    bufs.prefix.clear();
    bufs.prefix.push(0);
    for i in 0..k {
        let p = bufs.prefix[i] + bufs.dmin[i];
        bufs.prefix.push(p);
    }
    assert!(
        bufs.prefix[k] <= n,
        "wave infeasible: sum of min degrees {} > N = {n}",
        bufs.prefix[k]
    );

    const INF: f64 = f64::INFINITY;
    let width = n + 1;
    let cells = (k + 1) * width;
    bufs.dp.clear();
    bufs.dp.resize(cells, INF);
    bufs.slot.clear();
    bufs.slot.resize(cells, 0);
    bufs.deg.clear();
    bufs.deg.resize(cells, 0);
    // Row 0: zero groups fit in any budget with zero makespan.
    for cell in bufs.dp.iter_mut().take(width) {
        *cell = 0.0;
    }

    // The sweep's certificate: every row stored so far is ∞-free over its
    // valid span. Inductively that guarantees (a) the previous row is
    // monotone non-increasing in j, and (b) the crossing predicate
    // `Tmin(d) ≤ DP≤[i−1][j−d]` is monotone in d — the two preconditions
    // under which one forward cursor finds every cell's crossing exactly
    // where the bisection would. An ∞ cell (a degree window with no
    // admissible degree — impossible for the scheduler's policy-rounded
    // waves) voids the certificate, and all remaining rows bisect
    // instead: bit-identical to [`allocate_degrees_prefixmin`] either way.
    let mut sweep_ok = sweep;
    for i in 1..=k {
        let dmin_i = bufs.dmin[i - 1];
        // Ranks that must stay reserved for the remaining groups.
        let remain: usize = bufs.prefix[k] - bufs.prefix[i];
        let j_lo = bufs.prefix[i];
        let j_hi = n - remain;
        let off = bufs.prefix[i - 1];
        let d_cap = j_hi - off;
        let base_prev = (i - 1) * width;
        let base = i * width;

        // Prefix-min transform of the admissible cost curve: one T(G_i, d)
        // evaluation per degree (memoized upstream by the CostCache).
        bufs.tmin.clear();
        bufs.tmin.resize(d_cap + 1, INF);
        bufs.argt.clear();
        bufs.argt.resize(d_cap + 1, 0);
        {
            let mut best_t = INF;
            let mut best_d = 0u32;
            for d in dmin_i..=d_cap {
                if allowed(d) {
                    let t = time(i - 1, d);
                    if t < best_t {
                        best_t = t;
                        best_d = d as u32;
                    }
                }
                bufs.tmin[d] = best_t;
                bufs.argt[d] = best_d;
            }
        }

        // Crossing cursor for the monotone sweep: raising j lowers
        // DP≤[i−1][j−d] at fixed d, so the crossing never moves left —
        // the cursor only ever advances, O(d_cap + row width) per row.
        let mut cursor = dmin_i;
        let mut row_has_inf = false;
        for j in j_lo..=j_hi {
            let d_hi = j - off;
            // Smallest slot d with Tmin(d) ≤ DP≤[i−1][j−d] (the predicate
            // is monotone: LHS non-increasing, RHS non-decreasing),
            // clamped to d_hi when no slot in range satisfies it.
            let lo = if sweep_ok {
                while cursor < d_hi
                    && bufs.tmin[cursor] > bufs.dp[base_prev + (j - cursor)]
                {
                    cursor += 1;
                }
                cursor
            } else {
                let mut lo = dmin_i;
                let mut hi = d_hi;
                while lo < hi {
                    let mid = (lo + hi) / 2;
                    if bufs.tmin[mid] <= bufs.dp[base_prev + (j - mid)] {
                        hi = mid;
                    } else {
                        lo = mid + 1;
                    }
                }
                lo
            };
            // The optimum sits at the crossing: candidate `lo` (first slot
            // where Tmin dips under the prev row) or `lo − 1`.
            let mut best_slot = lo;
            let mut best_cost = bufs.tmin[lo].max(bufs.dp[base_prev + (j - lo)]);
            if lo > dmin_i {
                let c2 = bufs.tmin[lo - 1].max(bufs.dp[base_prev + (j - lo + 1)]);
                if c2 < best_cost {
                    best_cost = c2;
                    best_slot = lo - 1;
                }
            }
            row_has_inf |= best_cost == INF;
            bufs.dp[base + j] = best_cost;
            bufs.slot[base + j] = best_slot as u32;
            bufs.deg[base + j] = bufs.argt[best_slot];
        }
        if row_has_inf {
            sweep_ok = false;
        }
    }

    let makespan = bufs.dp[k * width + n];
    assert!(
        makespan.is_finite(),
        "DP found no feasible allocation (degree filter too strict?)"
    );
    let mut degrees = vec![0usize; k];
    let mut j = n;
    for i in (1..=k).rev() {
        let cell = i * width + j;
        degrees[i - 1] = bufs.deg[cell] as usize;
        j -= bufs.slot[cell] as usize;
    }
    DpSolution {
        ranks_used: degrees.iter().sum(),
        degrees,
        makespan_s: makespan,
    }
}

/// The paper-faithful exact-j DP (the seed implementation, O(K′·N²)):
/// `DP[i][j]` = best makespan using exactly `j` ranks, backtracked from
/// `argmin_j DP[K′][j]`. Kept as the reference oracle for the equivalence
/// property tests and as the "before" case for the solver micro-bench —
/// do not call it on the hot path.
pub fn allocate_degrees_reference<T, A>(
    groups: &[AtomicGroup],
    n: usize,
    time: T,
    allowed: A,
) -> DpSolution
where
    T: Fn(usize, usize) -> f64,
    A: Fn(usize) -> bool,
{
    let k = groups.len();
    if k == 0 {
        return DpSolution {
            degrees: vec![],
            makespan_s: 0.0,
            ranks_used: 0,
        };
    }
    let d_min: Vec<usize> = groups.iter().map(|g| g.d_min.min(n).max(1)).collect();
    let mut prefix = vec![0usize; k + 1];
    for i in 0..k {
        prefix[i + 1] = prefix[i] + d_min[i];
    }
    assert!(
        prefix[k] <= n,
        "wave infeasible: sum of min degrees {} > N = {n}",
        prefix[k]
    );

    const INF: f64 = f64::INFINITY;
    let width = n + 1;
    let mut dp = vec![INF; (k + 1) * width];
    let mut path = vec![0usize; (k + 1) * width];
    dp[0] = 0.0; // DP[0][0]

    for i in 1..=k {
        let dmin_i = d_min[i - 1];
        let remain: usize = prefix[k] - prefix[i];
        let j_lo = prefix[i];
        let j_hi = n - remain;
        let d_max_global = j_hi - prefix[i - 1];
        let mut t_of_d = vec![INF; d_max_global + 1];
        for (d, slot) in t_of_d.iter_mut().enumerate().skip(dmin_i) {
            if allowed(d) {
                *slot = time(i - 1, d);
            }
        }
        for j in j_lo..=j_hi {
            let d_hi = j - prefix[i - 1];
            let mut best = INF;
            let mut best_d = 0;
            for d in dmin_i..=d_hi {
                let t = t_of_d[d];
                if !t.is_finite() {
                    continue;
                }
                let prev = dp[(i - 1) * width + (j - d)];
                if !prev.is_finite() {
                    continue;
                }
                let cost = prev.max(t);
                if cost < best {
                    best = cost;
                    best_d = d;
                }
            }
            dp[i * width + j] = best;
            path[i * width + j] = best_d;
        }
    }

    let mut best_j = prefix[k];
    for j in prefix[k]..=n {
        if dp[k * width + j] < dp[k * width + best_j] {
            best_j = j;
        }
    }
    let makespan = dp[k * width + best_j];
    assert!(
        makespan.is_finite(),
        "DP found no feasible allocation (degree filter too strict?)"
    );
    let mut degrees = vec![0usize; k];
    let mut j = best_j;
    for i in (1..=k).rev() {
        let d = path[i * width + j];
        degrees[i - 1] = d;
        j -= d;
    }
    debug_assert_eq!(j, 0);
    DpSolution {
        ranks_used: degrees.iter().sum(),
        degrees,
        makespan_s: makespan,
    }
}

/// Degree filter admitting every positive integer (DHP's relaxation).
pub fn any_degree(_d: usize) -> bool {
    true
}

/// Degree filter admitting powers of two only (Ulysses/FlexSP-style
/// head-divisibility restriction the paper §4.1 contrasts against).
pub fn pow2_degree(d: usize) -> bool {
    d.is_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::WorkloadAgg;
    use crate::util::quickcheck::forall;

    fn mk_groups(d_mins: &[usize], works: &[f64]) -> Vec<AtomicGroup> {
        d_mins
            .iter()
            .zip(works)
            .enumerate()
            .map(|(i, (&d, &w))| AtomicGroup {
                seq_idxs: vec![i],
                d_min: d,
                mem_bytes: 0.0,
                capacity_bytes: 1.0,
                work_cap: 1.0,
                agg: WorkloadAgg {
                    quad: w,
                    quad_base: w,
                    tokens: w,
                    count: 1,
                },
            })
            .collect()
    }

    /// Idealized cost: perfectly divisible work, no comm penalty.
    fn ideal(groups: &[AtomicGroup]) -> impl Fn(usize, usize) -> f64 + '_ {
        move |i, d| groups[i].agg.quad / d as f64
    }

    #[test]
    fn single_group_gets_all_useful_ranks() {
        let groups = mk_groups(&[1], &[100.0]);
        let sol = allocate_degrees(&groups, 8, ideal(&groups), any_degree);
        assert_eq!(sol.degrees, vec![8]);
        assert!((sol.makespan_s - 12.5).abs() < 1e-9);
    }

    #[test]
    fn proportional_split_between_unequal_groups() {
        // Work 300 vs 100 over 8 ranks: optimal split 6/2 (makespan 50).
        let groups = mk_groups(&[1, 1], &[300.0, 100.0]);
        let sol = allocate_degrees(&groups, 8, ideal(&groups), any_degree);
        assert_eq!(sol.degrees, vec![6, 2]);
        assert!((sol.makespan_s - 50.0).abs() < 1e-9);
    }

    #[test]
    fn non_power_of_two_degrees_win() {
        // The paper's headline relaxation: with 3 equal groups on 9 ranks,
        // DHP picks 3+3+3; a pow2-restricted solver must accept worse.
        let groups = mk_groups(&[1, 1, 1], &[90.0, 90.0, 90.0]);
        let dhp = allocate_degrees(&groups, 9, ideal(&groups), any_degree);
        assert_eq!(dhp.degrees, vec![3, 3, 3]);
        let pow2 = allocate_degrees(&groups, 9, ideal(&groups), pow2_degree);
        assert!(pow2.makespan_s > dhp.makespan_s, "{pow2:?} vs {dhp:?}");
    }

    #[test]
    fn respects_min_degrees() {
        let groups = mk_groups(&[4, 2, 1], &[10.0, 10.0, 1000.0]);
        let sol = allocate_degrees(&groups, 8, ideal(&groups), any_degree);
        assert!(sol.degrees[0] >= 4);
        assert!(sol.degrees[1] >= 2);
        assert!(sol.degrees[2] >= 1);
        assert!(sol.ranks_used <= 8);
    }

    #[test]
    fn may_leave_ranks_idle_when_degrees_hurt() {
        // Cost grows past d=2 (hop overheads dominate): the solver must
        // NOT burn all ranks.
        let groups = mk_groups(&[1], &[10.0]);
        let time = |_i: usize, d: usize| {
            if d <= 2 {
                10.0 / d as f64
            } else {
                5.0 + (d as f64 - 2.0) * 3.0
            }
        };
        let sol = allocate_degrees(&groups, 64, time, any_degree);
        assert_eq!(sol.degrees, vec![2]);
        assert_eq!(sol.ranks_used, 2);
    }

    #[test]
    #[should_panic(expected = "infeasible")]
    fn infeasible_wave_panics() {
        let groups = mk_groups(&[8, 8], &[1.0, 1.0]);
        allocate_degrees(&groups, 8, ideal(&groups), any_degree);
    }

    #[test]
    fn empty_input() {
        let sol = allocate_degrees(&[], 8, |_, _| 0.0, any_degree);
        assert!(sol.degrees.is_empty());
        assert_eq!(sol.makespan_s, 0.0);
    }

    #[test]
    fn dp_beats_uniform_on_skewed_work() {
        // Skewed workload: DP's makespan must beat the uniform static
        // split (Fig. 2's message).
        let works = [640.0, 80.0, 40.0, 40.0];
        let groups = mk_groups(&[1, 1, 1, 1], &works);
        let sol = allocate_degrees(&groups, 16, ideal(&groups), any_degree);
        // Uniform static: 4 groups × degree 4 → makespan 640/4 = 160.
        assert!(
            sol.makespan_s < 160.0 * 0.7,
            "DP {0} vs uniform 160",
            sol.makespan_s
        );
    }

    #[test]
    fn property_dp_optimality_vs_bruteforce() {
        // For small instances, the DP must match exhaustive search.
        forall(40, 0x2DDF, |rng| {
            let k = rng.range_usize(1, 4);
            let n = rng.range_usize(k, 9);
            let d_mins: Vec<usize> = (0..k).map(|_| 1).collect();
            let works: Vec<f64> =
                (0..k).map(|_| rng.range_f64(1.0, 100.0)).collect();
            let groups = mk_groups(&d_mins, &works);
            // Non-trivial cost: parallel speedup + per-degree overhead.
            let time =
                |i: usize, d: usize| works[i] / d as f64 + 0.7 * d as f64;
            let sol = allocate_degrees(&groups, n, time, any_degree);

            // Brute force over all degree vectors with Σd ≤ n.
            fn rec(
                k: usize,
                n_left: usize,
                idx: usize,
                cur: f64,
                time: &dyn Fn(usize, usize) -> f64,
                best: &mut f64,
            ) {
                if idx == k {
                    *best = best.min(cur);
                    return;
                }
                let reserve = k - idx - 1; // 1 rank per remaining group
                for d in 1..=(n_left - reserve) {
                    rec(k, n_left - d, idx + 1, cur.max(time(idx, d)), time, best);
                }
            }
            let mut best = f64::INFINITY;
            rec(k, n, 0, 0.0, &time, &mut best);
            if (sol.makespan_s - best).abs() > 1e-9 {
                return Err(format!(
                    "dp {} != brute {} (works {works:?}, n={n})",
                    sol.makespan_s, best
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn property_solution_always_valid() {
        forall(50, 0xA110C, |rng| {
            let k = rng.range_usize(1, 12);
            let n = rng.range_usize(12, 65);
            let d_mins: Vec<usize> =
                (0..k).map(|_| rng.range_usize(1, 4)).collect();
            if d_mins.iter().sum::<usize>() > n {
                return Ok(()); // infeasible waves are the planner's job
            }
            let works: Vec<f64> =
                (0..k).map(|_| rng.range_f64(1.0, 1000.0)).collect();
            let groups = mk_groups(&d_mins, &works);
            let time = |i: usize, d: usize| works[i] / d as f64 + d as f64;
            let sol = allocate_degrees(&groups, n, time, any_degree);
            if sol.degrees.len() != k {
                return Err("wrong arity".into());
            }
            if sol.ranks_used > n {
                return Err(format!("over budget: {} > {n}", sol.ranks_used));
            }
            for (i, &d) in sol.degrees.iter().enumerate() {
                if d < d_mins[i] {
                    return Err(format!("d[{i}]={d} < dmin {}", d_mins[i]));
                }
            }
            // Makespan consistency.
            let ms = sol
                .degrees
                .iter()
                .enumerate()
                .map(|(i, &d)| time(i, d))
                .fold(0.0f64, f64::max);
            if (ms - sol.makespan_s).abs() > 1e-9 {
                return Err(format!("makespan mismatch {ms} vs {}", sol.makespan_s));
            }
            Ok(())
        });
    }

    #[test]
    fn property_optimized_matches_reference() {
        // The ISSUE-1 equivalence gate: the at-most-j binary-search DP must
        // return makespans identical (1e-9) to the retained exact-j
        // reference across randomized instances with NON-MONOTONE costs
        // (hop overheads make T(G, d) dip then rise) and both degree
        // policies, and its degree vector must actually achieve that
        // makespan under the same constraints.
        forall(120, 0x0_D1FF, |rng| {
            let k = rng.range_usize(1, 13);
            let n = rng.range_usize(k.max(4), 65);
            let d_mins: Vec<usize> =
                (0..k).map(|_| rng.range_usize(1, 5)).collect();
            if d_mins.iter().sum::<usize>() > n {
                return Ok(());
            }
            let works: Vec<f64> =
                (0..k).map(|_| rng.range_f64(1.0, 1000.0)).collect();
            let hops: Vec<f64> = (0..k).map(|_| rng.range_f64(0.0, 8.0)).collect();
            let bases: Vec<f64> = (0..k).map(|_| rng.range_f64(0.0, 3.0)).collect();
            let jagged = rng.bool(0.3);
            let time = |i: usize, d: usize| {
                let smooth = works[i] / d as f64 + hops[i] * (d as f64 - 1.0) + bases[i];
                if jagged {
                    // Aggressively non-monotone: parity + modulo kinks.
                    smooth + hops[i] * ((d % 3) as f64) + bases[i] * ((d & 1) as f64)
                } else {
                    smooth
                }
            };
            let pow2 = rng.bool(0.25);
            let allowed = |d: usize| !pow2 || d.is_power_of_two();
            // pow2 rounds every group's effective minimum degree up to a
            // power of two; if any group has no admissible degree at all,
            // or the rounded minimums jointly exceed the rank budget, the
            // instance is infeasible and both solvers assert — skip it
            // (the scheduler proper rounds d_min BEFORE wave splitting,
            // so it never hands the DP such a wave).
            if pow2 {
                let mut need = 0usize;
                let mut impossible = false;
                for &dm in &d_mins {
                    match (dm..=n).find(|d| d.is_power_of_two()) {
                        Some(d) => need += d,
                        None => {
                            impossible = true;
                            break;
                        }
                    }
                }
                if impossible || need > n {
                    return Ok(());
                }
            }
            let groups = mk_groups(&d_mins, &works);
            let fast = allocate_degrees(&groups, n, time, allowed);
            let reference = allocate_degrees_reference(&groups, n, time, allowed);
            if (fast.makespan_s - reference.makespan_s).abs() > 1e-9 {
                return Err(format!(
                    "optimized {} != reference {} (works {works:?}, hops {hops:?}, \
                     d_mins {d_mins:?}, n={n}, pow2={pow2}, jagged={jagged})",
                    fast.makespan_s, reference.makespan_s
                ));
            }
            // The optimized solution must be self-consistent and feasible.
            if fast.ranks_used > n {
                return Err(format!("over budget {} > {n}", fast.ranks_used));
            }
            let ms = fast
                .degrees
                .iter()
                .enumerate()
                .map(|(i, &d)| time(i, d))
                .fold(0.0f64, f64::max);
            if (ms - fast.makespan_s).abs() > 1e-9 {
                return Err(format!("achieved {ms} != claimed {}", fast.makespan_s));
            }
            for (i, &d) in fast.degrees.iter().enumerate() {
                if d < d_mins[i] || !allowed(d) {
                    return Err(format!("degree {d} invalid at group {i}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_sweep_matches_prefixmin_and_reference() {
        // The ISSUE-7 equivalence gate: the monotone-sweep transition must
        // be BIT-identical (not 1e-9-close) to the retained prefix-min +
        // binary-search path — same makespan bits, same degrees, same rank
        // count — and bit-identical in makespan to the exact-j oracle, on
        // randomized non-monotone tables up to k = 12, n = 128, under both
        // degree policies. pow2 instances exercise the ∞-certificate
        // fallback (gapped admissible sets can park ∞ in a row's valid
        // span, after which the sweep must bisect like the reference).
        forall(160, 0x5_33ED, |rng| {
            let k = rng.range_usize(1, 13);
            let n = rng.range_usize(k.max(4), 129);
            let d_mins: Vec<usize> =
                (0..k).map(|_| rng.range_usize(1, 6)).collect();
            if d_mins.iter().sum::<usize>() > n {
                return Ok(());
            }
            let works: Vec<f64> =
                (0..k).map(|_| rng.range_f64(1.0, 1000.0)).collect();
            let hops: Vec<f64> = (0..k).map(|_| rng.range_f64(0.0, 8.0)).collect();
            let bases: Vec<f64> = (0..k).map(|_| rng.range_f64(0.0, 3.0)).collect();
            let jagged = rng.bool(0.5);
            let time = |i: usize, d: usize| {
                let smooth = works[i] / d as f64 + hops[i] * (d as f64 - 1.0) + bases[i];
                if jagged {
                    smooth + hops[i] * ((d % 3) as f64) + bases[i] * ((d & 1) as f64)
                } else {
                    smooth
                }
            };
            let pow2 = rng.bool(0.5);
            let allowed = |d: usize| !pow2 || d.is_power_of_two();
            if pow2 {
                let mut need = 0usize;
                let mut impossible = false;
                for &dm in &d_mins {
                    match (dm..=n).find(|d| d.is_power_of_two()) {
                        Some(d) => need += d,
                        None => {
                            impossible = true;
                            break;
                        }
                    }
                }
                if impossible || need > n {
                    return Ok(());
                }
            }
            let groups = mk_groups(&d_mins, &works);
            let sweep = allocate_degrees(&groups, n, time, allowed);
            let prefixmin = allocate_degrees_prefixmin(&groups, n, time, allowed);
            if sweep.makespan_s.to_bits() != prefixmin.makespan_s.to_bits() {
                return Err(format!(
                    "sweep {} != prefixmin {} bits (works {works:?}, hops {hops:?}, \
                     d_mins {d_mins:?}, n={n}, pow2={pow2}, jagged={jagged})",
                    sweep.makespan_s, prefixmin.makespan_s
                ));
            }
            if sweep.degrees != prefixmin.degrees {
                return Err(format!(
                    "degree vectors diverged: sweep {:?} vs prefixmin {:?} \
                     (d_mins {d_mins:?}, n={n}, pow2={pow2}, jagged={jagged})",
                    sweep.degrees, prefixmin.degrees
                ));
            }
            if sweep.ranks_used != prefixmin.ranks_used {
                return Err(format!(
                    "ranks_used diverged: {} vs {}",
                    sweep.ranks_used, prefixmin.ranks_used
                ));
            }
            let reference = allocate_degrees_reference(&groups, n, time, allowed);
            if sweep.makespan_s.to_bits() != reference.makespan_s.to_bits() {
                return Err(format!(
                    "sweep {} != exact-j reference {} bits (works {works:?}, \
                     d_mins {d_mins:?}, n={n}, pow2={pow2}, jagged={jagged})",
                    sweep.makespan_s, reference.makespan_s
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        // Re-solving different instances through one DpTables must give
        // exactly the answers fresh tables give (stale cells never leak).
        let mut bufs = DpTables::default();
        let mut seed = 1u64;
        for case in 0..40 {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let k = 1 + (seed >> 33) as usize % 10;
            let n = k + 8 + (seed >> 13) as usize % 40;
            let works: Vec<f64> = (0..k)
                .map(|i| 1.0 + ((seed.rotate_left(i as u32 * 7) >> 40) as f64))
                .collect();
            let d_mins = vec![1usize; k];
            let groups = mk_groups(&d_mins, &works);
            let time = |i: usize, d: usize| works[i] / d as f64 + 0.3 * d as f64;
            let reused = allocate_degrees_in(&mut bufs, &groups, n, time, any_degree);
            let fresh = allocate_degrees(&groups, n, time, any_degree);
            assert_eq!(
                reused.makespan_s.to_bits(),
                fresh.makespan_s.to_bits(),
                "case {case}: reused tables diverged"
            );
            assert_eq!(reused.degrees, fresh.degrees, "case {case}");
        }
    }
}
