//! Stage 1 — Atomic Sequence Grouping via Best-Fit Decreasing (paper
//! §4.3): sort sequences by memory demand descending; long sequences open
//! "bins" of capacity d_min·E′ (their minimum CP degree times the usable
//! per-rank budget); shorter sequences are best-fit packed into the
//! remaining headroom. The result is K′ ≤ K *atomic groups*, each a single
//! scheduling unit with a minimum degree — this collapses the DP's
//! decision-variable count and avoids "communication redundancy caused by
//! packing massive short sequences" into oversized CP groups.

use super::scratch::PackScratch;
use crate::cost::{MemoryModel, WorkloadAgg};
use crate::data::sequence::Sequence;

/// One atomic group: sequences that will share a CP group.
#[derive(Debug, Clone, PartialEq)]
pub struct AtomicGroup {
    /// Indices into the micro-batch's sequence list.
    pub seq_idxs: Vec<usize>,
    /// Minimum CP degree needed to satisfy Eq. 3.
    pub d_min: usize,
    /// Total memory demand (bytes).
    pub mem_bytes: f64,
    /// Memory capacity (bytes — feasibility bound, N·E′ at most).
    pub capacity_bytes: f64,
    /// Work-balance capacity (token² units): the bin closes when its
    /// quadratic workload reaches ~1/target of the batch.
    pub work_cap: f64,
    /// Workload aggregates for O(1) cost queries in the DP.
    pub agg: WorkloadAgg,
}

impl AtomicGroup {
    /// Remaining memory capacity at the current minimum degree (bytes).
    pub fn headroom(&self) -> f64 {
        self.capacity_bytes - self.mem_bytes
    }

    /// Remaining capacity in quadratic-work units (BFD's balance key).
    pub fn work_headroom(&self) -> f64 {
        self.work_cap - self.agg.quad
    }
}

/// Best-Fit-Decreasing packing of a micro-batch into atomic groups.
///
/// Bins are established by LONG sequences only (d_min ≥ 2, the paper's
/// "for each long sequence ... effectively initializing a bin"): their
/// ranks already pay the ring-communication cost, so filling their memory
/// headroom with short sequences is free parallelism. Short sequences
/// (d_min = 1) that fit no long bin become their own atomic groups —
/// merging them into ever-larger degree-1 bins would serialize unrelated
/// work and re-introduce exactly the "communication redundancy caused by
/// packing massive short sequences" the paper avoids.
///
/// `max_degree` caps d_min at the rank budget N — the scheduler passes
/// its fabric snapshot's capacity ([`crate::scheduler::FabricModel::capacity`]:
/// the *free* replicas, which on a fragmented mesh is less than the mesh
/// total), so bins are never sized against ranks concurrent jobs hold. A
/// sequence whose memory exceeds N·E′ is infeasible; we clamp and let
/// the memory constraint surface in validation — mirroring what a real
/// system would OOM on.
pub fn pack(
    seqs: &[Sequence],
    memory: &MemoryModel,
    max_degree: usize,
) -> Vec<AtomicGroup> {
    pack_with_target(seqs, memory, max_degree, max_degree)
}

/// BFD packing with a workload-balance target: bin capacity is capped at
/// ~1/`group_target` of the batch so roughly `group_target` atomic groups
/// come out (requirement 1, "Workload Balance") — pure memory-driven bins
/// would otherwise coalesce the whole batch into a handful of fat groups
/// whenever per-rank memory is abundant. The scheduler searches over a
/// small set of `group_target` candidates and keeps the best DP outcome
/// (see `Scheduler::schedule`); the memory constraint (Eq. 3) always
/// rules via d_min.
pub fn pack_with_target(
    seqs: &[Sequence],
    memory: &MemoryModel,
    max_degree: usize,
    group_target: usize,
) -> Vec<AtomicGroup> {
    pack_with_target_in(seqs, memory, max_degree, group_target, &mut PackScratch::default())
}

/// [`pack_with_target`] with caller-owned scratch: the sort-order buffer
/// and bin index vectors come from (and return to) the scratch free-lists,
/// so steady-state packing performs no allocations beyond first growth.
/// Produces bit-identical groups to the scratch-free path (recycled
/// buffers are cleared; the BFD order and tie-breaks are unchanged).
pub fn pack_with_target_in(
    seqs: &[Sequence],
    memory: &MemoryModel,
    max_degree: usize,
    group_target: usize,
    scratch: &mut PackScratch,
) -> Vec<AtomicGroup> {
    // Work-balance cap (token² units): makespan follows the quadratic
    // workload, so bins close on WORK at ~1/target of the batch (5% slack
    // absorbs BFD rounding so a target of G yields G bins, not G+1 with a
    // nearly-empty spill). Memory stays a hard feasibility bound.
    let work_cap = total_quad(seqs) / group_target.max(1) as f64 * 1.05;
    let mut order = std::mem::take(&mut scratch.order);
    sort_order(seqs, &mut order);
    let (groups, _crit) =
        pack_core(seqs, memory, max_degree, work_cap, &order, scratch);
    scratch.order = order;
    groups
}

/// Σ quadratic work over the batch — the sweep cap's numerator.
fn total_quad(seqs: &[Sequence]) -> f64 {
    let mut agg = WorkloadAgg::default();
    for s in seqs {
        agg.add(s);
    }
    agg.quad
}

/// BFD visit order: by memory (≡ token count × M_token) descending. The
/// sort buffer is reused; sort_by is stable, so results match a fresh
/// Vec. Target-independent — [`TargetSweep`] sorts once per batch.
fn sort_order(seqs: &[Sequence], order: &mut Vec<usize>) {
    order.clear();
    order.extend(0..seqs.len());
    order.sort_by(|&a, &b| {
        seqs[b]
            .len()
            .cmp(&seqs[a].len())
            .then_with(|| a.cmp(&b)) // deterministic tie-break
    });
}

/// The shared BFD core: pack `seqs` (visited in `order`) against one
/// work cap. Besides the groups it returns the packing's *reuse
/// threshold* — the smallest sweep cap `c ≤ work_cap` at which every
/// decision this run made provably repeats verbatim (see
/// [`TargetSweep`] for the argument).
fn pack_core(
    seqs: &[Sequence],
    memory: &MemoryModel,
    max_degree: usize,
    work_cap: f64,
    order: &[usize],
    scratch: &mut PackScratch,
) -> (Vec<AtomicGroup>, f64) {
    let budget = memory.rank_budget();
    let mem_cap = max_degree as f64 * budget;
    let mut crit = 0.0f64;
    let mut groups: Vec<AtomicGroup> = scratch.take_groups();
    for &idx in order {
        let seq = &seqs[idx];
        let mem = seq.act_bytes(memory.m_token);
        let l = seq.len() as f64;
        let work = (1.0 + seq.eta()) * l * l;
        let d_min = memory.min_degree(seq.len()).min(max_degree).max(1);
        // Among bins with sufficient memory AND work headroom, choose the
        // least work-loaded (LPT placement): memory decides feasibility
        // (best-fit in the paper), load-aware placement keeps the groups
        // makespan-balanced — requirement 1. With tight memory few bins
        // qualify and this degenerates to classic BFD.
        let mut best: Option<(usize, f64)> = None;
        for (gi, g) in groups.iter().enumerate() {
            if g.headroom() >= mem && g.work_headroom() >= work {
                match best {
                    Some((_, bl)) if bl <= g.agg.quad => {}
                    _ => best = Some((gi, g.agg.quad)),
                }
            }
        }
        match best {
            Some((gi, _)) => {
                let g = &mut groups[gi];
                // Reuse threshold of this placement: shrinking the sweep
                // cap only shrinks every bin's work headroom, so the
                // feasible set at a smaller cap is a subset of today's —
                // the decision repeats iff the CHOSEN bin stays feasible
                // (dropping non-chosen competitors never changes a
                // least-loaded argmin that is still present, and the
                // ties-keep-earliest break is order-preserving). A bin
                // whose cap was raised by its own initiator
                // (`work_cap > sweep cap`) is cap-independent; otherwise
                // the placement needs `c ≥ quad + work`, padded
                // multiplicatively so float rounding of the headroom
                // subtraction can never flip the comparison at a cap
                // that passed this threshold.
                if g.work_cap <= work_cap {
                    let thresh = g.agg.quad + work * (1.0 + 1e-12);
                    if thresh > crit {
                        crit = thresh;
                    }
                }
                g.seq_idxs.push(idx);
                g.mem_bytes += mem;
                g.agg.add(seq);
                // A bin growing past its initiator's memory needs a
                // larger minimum degree (Eq. 3 over the whole group).
                g.d_min = ((g.mem_bytes / budget).ceil() as usize)
                    .clamp(1, max_degree);
            }
            None => {
                // Opening a bin is always cap-independent downward: the
                // feasible set was empty and can only shrink further.
                let mut agg = WorkloadAgg::default();
                agg.add(seq);
                let mut seq_idxs = scratch.take_idxs();
                seq_idxs.push(idx);
                groups.push(AtomicGroup {
                    seq_idxs,
                    d_min,
                    mem_bytes: mem,
                    capacity_bytes: mem_cap.max(mem),
                    work_cap: work_cap.max(work),
                    agg,
                });
            }
        }
    }
    (groups, crit)
}

/// Incremental Stage-1 across the outer search's ascending balance
/// targets (ISSUE-7). Ascending targets mean strictly shrinking work
/// caps, and a BFD run at cap `W` is reproduced verbatim by any cap in
/// `[crit, W]` where `crit` is the largest reuse threshold among its
/// placements ([`pack_core`]): within that interval every chosen bin
/// stays feasible and every rejected set stays rejected. The sweep
/// therefore sorts once, packs only when the next cap drops below
/// `crit`, and answers `None` — "identical to my previous packing" —
/// otherwise, which the candidate dedupe in `Scheduler::candidates`
/// treats exactly like a fingerprint duplicate. Only membership and
/// `d_min` are certified identical (bin bookkeeping like `work_cap`
/// differs with the cap) — precisely the fields anything downstream of
/// packing reads ([`same_packing`]).
pub struct TargetSweep<'s> {
    seqs: &'s [Sequence],
    memory: &'s MemoryModel,
    max_degree: usize,
    total_quad: f64,
    order: Vec<usize>,
    /// Reuse threshold of the latest real packing.
    crit: f64,
    /// The cap that packing ran at.
    last_cap: f64,
    packed_any: bool,
}

impl<'s> TargetSweep<'s> {
    /// Start a sweep: aggregates the batch and sorts the BFD visit order
    /// once (buffer borrowed from `scratch`, returned by
    /// [`TargetSweep::finish`]).
    pub fn new(
        seqs: &'s [Sequence],
        memory: &'s MemoryModel,
        max_degree: usize,
        scratch: &mut PackScratch,
    ) -> Self {
        let mut order = std::mem::take(&mut scratch.order);
        sort_order(seqs, &mut order);
        TargetSweep {
            seqs,
            memory,
            max_degree,
            total_quad: total_quad(seqs),
            order,
            crit: f64::INFINITY,
            last_cap: f64::INFINITY,
            packed_any: false,
        }
    }

    /// Pack the next balance target. `None` means the packing is provably
    /// identical (membership + `d_min`) to the previous `Some` — keep
    /// using that one. Targets must be fed in the caller's search order;
    /// reuse only triggers while caps keep shrinking, so a non-ascending
    /// caller degrades to from-scratch packing, never to a wrong answer.
    pub fn pack(
        &mut self,
        group_target: usize,
        scratch: &mut PackScratch,
    ) -> Option<Vec<AtomicGroup>> {
        let cap = self.total_quad / group_target.max(1) as f64 * 1.05;
        if self.packed_any && cap <= self.last_cap && cap >= self.crit {
            return None;
        }
        let (groups, crit) = pack_core(
            self.seqs,
            self.memory,
            self.max_degree,
            cap,
            &self.order,
            scratch,
        );
        self.crit = crit;
        self.last_cap = cap;
        self.packed_any = true;
        Some(groups)
    }

    /// Return the sweep's sort buffer to the scratch free-list.
    pub fn finish(self, scratch: &mut PackScratch) {
        scratch.order = self.order;
    }
}

/// Do two packings describe the same atomic groups, in the same order?
/// Compares exactly the fields everything downstream of packing reads —
/// membership (`seq_idxs`, which determines the workload aggregates) and
/// minimum degree. Bin bookkeeping (`work_cap`, `capacity_bytes`,
/// `mem_bytes`) is packer-internal and varies with the group-count target
/// even when the resulting groups are identical, so it is deliberately
/// ignored (derived `PartialEq` would never match across targets).
pub fn same_packing(a: &[AtomicGroup], b: &[AtomicGroup]) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(x, y)| x.d_min == y.d_min && x.seq_idxs == y.seq_idxs)
}

/// Content fingerprint of a packing: hashes group boundaries, membership,
/// and minimum degrees (in the packer's deterministic output order). Two
/// targets whose packings collapse to the same groups produce the same
/// fingerprint, letting the outer search skip the redundant DP solve
/// (confirmed by [`same_packing`] before anything is dropped).
pub fn fingerprint(groups: &[AtomicGroup]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |x: u64, h: &mut u64| {
        *h = (*h ^ x).wrapping_mul(0x100000001b3);
        *h ^= *h >> 29;
    };
    for g in groups {
        mix(0x9E37_79B9_7F4A_7C15, &mut h); // group boundary sentinel
        mix(g.d_min as u64, &mut h);
        for &i in &g.seq_idxs {
            mix(i as u64 + 1, &mut h);
        }
    }
    h
}

/// Split atomic groups into feasibility waves (Σ d_min ≤ N per wave,
/// where N is the fabric capacity — free replicas — on the scheduling
/// path), balancing estimated WORK across waves LPT-style so one wave
/// doesn't hoard all the long groups while later waves run nearly empty.
pub fn waves(groups: Vec<AtomicGroup>, replicas: usize) -> Vec<Vec<AtomicGroup>> {
    let mut groups = groups;
    waves_in(&mut groups, replicas, &mut PackScratch::default())
}

/// [`waves`] draining a caller-owned group vector, with wave containers
/// drawn from the scratch free-list. The caller should hand the drained
/// input buffer back via [`PackScratch::put_groups`] and, once the
/// candidate's plan is assembled, pass the result to
/// [`PackScratch::reclaim_waves`] to recycle everything.
pub fn waves_in(
    groups: &mut Vec<AtomicGroup>,
    replicas: usize,
    scratch: &mut PackScratch,
) -> Vec<Vec<AtomicGroup>> {
    if groups.is_empty() {
        return vec![];
    }
    let total_dmin: usize = groups.iter().map(|g| g.d_min.min(replicas)).sum();
    let n_waves = total_dmin.div_ceil(replicas).max(1);

    // LPT over estimated work, respecting each wave's rank budget.
    let sorted = groups;
    sorted.sort_by(|a, b| {
        b.agg
            .quad
            .partial_cmp(&a.agg.quad)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut out: Vec<Vec<AtomicGroup>> =
        (0..n_waves).map(|_| scratch.take_groups()).collect();
    let mut used = vec![0usize; n_waves];
    let mut load = vec![0.0f64; n_waves];
    for g in sorted.drain(..) {
        let need = g.d_min.min(replicas);
        // Least-loaded wave with room.
        let mut best: Option<usize> = None;
        for w in 0..out.len() {
            if used[w] + need <= replicas {
                match best {
                    Some(b) if load[b] <= load[w] => {}
                    _ => best = Some(w),
                }
            }
        }
        let w = match best {
            Some(w) => w,
            None => {
                // All existing waves full: open a new one.
                out.push(scratch.take_groups());
                used.push(0);
                load.push(0.0);
                out.len() - 1
            }
        };
        used[w] += need;
        load[w] += g.agg.quad;
        out[w].push(g);
    }
    // Recycle emptied containers (input buffer + unused waves).
    out.retain_mut(|w| {
        if w.is_empty() {
            scratch.put_groups(std::mem::take(w));
            false
        } else {
            true
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::by_name;
    use crate::data::datasets::{DatasetKind, DatasetSampler};
    use crate::util::quickcheck::forall;

    fn memory() -> MemoryModel {
        // E' chosen so ~4096 tokens fit one rank.
        let preset = by_name("InternVL3-8B").unwrap();
        let m_token = preset.act_bytes_per_token();
        MemoryModel {
            e_bytes: 4096.0 * m_token + 1e9,
            m_states: 1e9,
            m_token,
        }
    }

    fn seq(id: u64, len: u64) -> Sequence {
        Sequence::new(id, len / 2, len - len / 2)
    }

    #[test]
    fn every_sequence_packed_exactly_once() {
        let mm = memory();
        let seqs: Vec<Sequence> =
            (0..50).map(|i| seq(i, 64 + i * 311 % 9000)).collect();
        let groups = pack(&seqs, &mm, 64);
        let mut seen = vec![0usize; seqs.len()];
        for g in &groups {
            for &i in &g.seq_idxs {
                seen[i] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn groups_respect_capacity() {
        let mm = memory();
        let mut sampler = DatasetSampler::new(DatasetKind::OpenVid, 17);
        let seqs = sampler.sample_batch(200);
        for g in pack(&seqs, &mm, 64) {
            assert!(
                g.mem_bytes <= g.capacity_bytes + 1e-6,
                "bin over capacity: {} > {}",
                g.mem_bytes,
                g.capacity_bytes
            );
            assert!(g.d_min >= 1);
        }
    }

    #[test]
    fn short_sequences_fill_long_bins() {
        let mm = memory();
        // One long sequence (needs 2 ranks => capacity 2×4096) and short
        // ones that fit its headroom.
        let seqs = vec![seq(0, 6000), seq(1, 500), seq(2, 500), seq(3, 500)];
        // target = 1 reproduces the paper's pure memory-driven BFD.
        let groups = pack_with_target(&seqs, &mm, 64, 1);
        // All shorts fit in the long bin's headroom (8192−6000 = 2192 tok).
        assert_eq!(groups.len(), 1, "{groups:#?}");
        assert_eq!(groups[0].d_min, 2);
        assert_eq!(groups[0].agg.count, 4);
    }

    #[test]
    fn kprime_never_exceeds_k() {
        let mm = memory();
        let mut sampler = DatasetSampler::new(DatasetKind::InternVid, 23);
        let seqs = sampler.sample_batch(128);
        let groups = pack(&seqs, &mm, 64);
        assert!(groups.len() <= seqs.len());
        // And with realistic data it should genuinely compress.
        assert!(groups.len() < seqs.len(), "BFD should merge short seqs");
    }

    #[test]
    fn dmin_clamped_to_cluster() {
        let mm = memory();
        let seqs = vec![seq(0, 4096 * 200)]; // needs 200 ranks
        let groups = pack(&seqs, &mm, 64);
        assert_eq!(groups[0].d_min, 64);
    }

    #[test]
    fn waves_respect_rank_budget() {
        let mm = memory();
        let seqs: Vec<Sequence> = (0..30).map(|i| seq(i, 3000 + i * 500)).collect();
        let groups = pack(&seqs, &mm, 8);
        let n_groups = groups.len();
        let waves = waves(groups, 8);
        assert_eq!(
            waves.iter().map(|w| w.len()).sum::<usize>(),
            n_groups
        );
        for w in &waves {
            let total: usize = w.iter().map(|g| g.d_min).sum();
            assert!(total <= 8 || w.len() == 1, "wave over budget: {total}");
        }
    }

    #[test]
    fn property_target_sweep_matches_from_scratch() {
        // The ISSUE-7 incremental-packing gate: at EVERY target of an
        // ascending sweep — including the ones the sweep skipped as
        // provably-identical — the sweep's current packing must equal
        // the from-scratch packing on exactly the fields downstream
        // consumers read (membership + d_min), and across the trials
        // the sweep must actually skip repacks (that is the perf claim
        // being purchased).
        let mut total_skips = 0usize;
        forall(60, 0x57EE9, |rng| {
            let mm = memory();
            let nseq = rng.range_usize(1, 60);
            let seqs: Vec<Sequence> = (0..nseq)
                .map(|i| {
                    let len = rng.range_u64(16, 20_000);
                    seq(i as u64, len)
                })
                .collect();
            let max_degree = rng.range_usize(1, 65);
            let mut scratch = PackScratch::default();
            let mut sweep = TargetSweep::new(&seqs, &mm, max_degree, &mut scratch);
            let mut current: Vec<AtomicGroup> = Vec::new();
            let mut skips = 0usize;
            for t in 1..=32usize {
                match sweep.pack(t, &mut scratch) {
                    Some(g) => current = g,
                    None => skips += 1,
                }
                let fresh = pack_with_target(&seqs, &mm, max_degree, t);
                if !same_packing(&current, &fresh) {
                    return Err(format!(
                        "sweep diverged from scratch at target {t} \
                         (nseq={nseq}, max_degree={max_degree}): \
                         sweep {} groups, fresh {} groups",
                        current.len(),
                        fresh.len()
                    ));
                }
            }
            sweep.finish(&mut scratch);
            total_skips += skips;
            Ok(())
        });
        // Adjacent targets collapse constantly (always once the target
        // exceeds the sequence count) — a sweep that never skips is not
        // incremental at all.
        assert!(
            total_skips > 0,
            "TargetSweep never skipped a repack across 60 random batches"
        );
    }

    #[test]
    fn property_packing_invariants() {
        forall(60, 0xBFD, |rng| {
            let mm = memory();
            let n = rng.range_usize(1, 80);
            let seqs: Vec<Sequence> = (0..n)
                .map(|i| {
                    let len = rng.range_u64(16, 20_000);
                    seq(i as u64, len)
                })
                .collect();
            let groups = pack(&seqs, &mm, 64);
            // (a) exclusive total assignment
            let assigned: usize = groups.iter().map(|g| g.seq_idxs.len()).sum();
            if assigned != n {
                return Err(format!("{assigned} != {n}"));
            }
            // (b) capacity respected
            for g in &groups {
                if g.mem_bytes > g.capacity_bytes + 1e-6 {
                    return Err(format!(
                        "bin over capacity {} > {}",
                        g.mem_bytes, g.capacity_bytes
                    ));
                }
                // (c) aggregates consistent with membership
                let mut agg = WorkloadAgg::default();
                for &i in &g.seq_idxs {
                    agg.add(&seqs[i]);
                }
                if (agg.quad - g.agg.quad).abs() > 1e-6 * agg.quad.max(1.0) {
                    return Err("agg mismatch".into());
                }
            }
            Ok(())
        });
    }
}
