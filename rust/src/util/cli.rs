//! A small command-line argument parser (clap is unavailable offline).
//!
//! Supports `program <subcommand> [--flag] [--key value] [--key=value]
//! [positional...]` with typed accessors and defaults.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed arguments for one invocation.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// The subcommand (first non-flag token), if any.
    pub command: Option<String>,
    /// `--key value` and `--key=value` pairs; bare `--flag` maps to "true".
    pub options: BTreeMap<String, String>,
    /// Remaining positional arguments (after the subcommand).
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    // `--` terminator: rest is positional.
                    args.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else {
                    // Lookahead: next token is the value unless it is
                    // another flag (then this is a boolean switch).
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            args.options.insert(stripped.to_string(), v);
                        }
                        _ => {
                            args.options
                                .insert(stripped.to_string(), "true".into());
                        }
                    }
                }
            } else if tok.starts_with('-') && tok.len() > 1 {
                bail!("short flags are not supported: {tok}");
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    /// Raw value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// String value of `--key`, or `default`.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Is the boolean switch `--key` set (true/1/yes)?
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// `usize` value of `--key`, or `default` (error on non-integer).
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects an integer, got {v:?}")),
        }
    }

    /// `u64` value of `--key`, or `default` (error on non-integer).
    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects an integer, got {v:?}")),
        }
    }

    /// `f64` value of `--key`, or `default` (error on non-number).
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects a number, got {v:?}")),
        }
    }

    /// Comma-separated list of integers, e.g. `--npus 8,16,32`.
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .with_context(|| format!("--{key}: bad element {s:?}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("reproduce fig5 --npus 8,16 --seed=42 --verbose");
        assert_eq!(a.command.as_deref(), Some("reproduce"));
        assert_eq!(a.positional, vec!["fig5"]);
        assert_eq!(a.get("npus"), Some("8,16"));
        assert_eq!(a.get("seed"), Some("42"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn typed_accessors_and_defaults() {
        let a = parse("train --steps 100 --lr 0.001");
        assert_eq!(a.usize_or("steps", 5).unwrap(), 100);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        assert!((a.f64_or("lr", 0.1).unwrap() - 0.001).abs() < 1e-12);
        assert_eq!(
            a.usize_list_or("npus", &[8, 64]).unwrap(),
            vec![8, 64]
        );
    }

    #[test]
    fn list_parsing() {
        let a = parse("x --npus 8,16,32,64");
        assert_eq!(
            a.usize_list_or("npus", &[]).unwrap(),
            vec![8, 16, 32, 64]
        );
    }

    #[test]
    fn bool_flag_before_flag() {
        let a = parse("run --fast --steps 3");
        assert!(a.flag("fast"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 3);
    }

    #[test]
    fn bad_integer_is_error() {
        let a = parse("run --steps abc");
        assert!(a.usize_or("steps", 0).is_err());
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse("run -- --not-a-flag pos");
        assert_eq!(a.positional, vec!["--not-a-flag", "pos"]);
    }

    #[test]
    fn short_flags_rejected() {
        assert!(Args::parse(["-x".to_string()]).is_err());
    }
}
