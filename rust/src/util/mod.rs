//! Foundation utilities built in-repo (the offline environment provides no
//! clap / serde / criterion / proptest — these substrates replace them).

pub mod bench;
pub mod cli;
pub mod json;
pub mod logger;
pub mod quickcheck;
pub mod rng;
pub mod stats;

pub use rng::Rng;
