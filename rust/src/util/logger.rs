//! Minimal `log`-crate backend (no env_logger offline): timestamped,
//! level-filtered stderr logging, controlled by `DHP_LOG`
//! (`error|warn|info|debug|trace`, default `info`).

use std::sync::{Once, OnceLock};
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

static START: OnceLock<Instant> = OnceLock::new();
static INIT: Once = Once::new();

fn start() -> Instant {
    *START.get_or_init(Instant::now)
}

struct DhpLogger {
    max: Level,
}

impl log::Log for DhpLogger {
    fn enabled(&self, meta: &Metadata) -> bool {
        meta.level() <= self.max
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = start().elapsed().as_secs_f64();
        eprintln!(
            "[{t:9.3}s {:5} {}] {}",
            record.level(),
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Install the logger once (idempotent). Reads `DHP_LOG` for the level.
pub fn init() {
    INIT.call_once(|| {
        let level = match std::env::var("DHP_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("warn") => Level::Warn,
            Ok("debug") => Level::Debug,
            Ok("trace") => Level::Trace,
            _ => Level::Info,
        };
        let _ = start(); // pin t = 0 at init time
        let _ = log::set_boxed_logger(Box::new(DhpLogger { max: level }));
        log::set_max_level(LevelFilter::Trace);
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke");
    }
}
