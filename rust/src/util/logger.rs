//! Minimal `log`-crate backend (no env_logger offline): timestamped,
//! level-filtered stderr logging, controlled by `DHP_LOG`
//! (`error|warn|info|debug|trace`, default `info`).

use std::sync::Once;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};
use once_cell::sync::Lazy;

static START: Lazy<Instant> = Lazy::new(Instant::now);
static INIT: Once = Once::new();

struct DhpLogger {
    max: Level,
}

impl log::Log for DhpLogger {
    fn enabled(&self, meta: &Metadata) -> bool {
        meta.level() <= self.max
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.elapsed().as_secs_f64();
        eprintln!(
            "[{t:9.3}s {:5} {}] {}",
            record.level(),
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Install the logger once (idempotent). Reads `DHP_LOG` for the level.
pub fn init() {
    INIT.call_once(|| {
        let level = match std::env::var("DHP_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("warn") => Level::Warn,
            Ok("debug") => Level::Debug,
            Ok("trace") => Level::Trace,
            _ => Level::Info,
        };
        Lazy::force(&START);
        let _ = log::set_boxed_logger(Box::new(DhpLogger { max: level }));
        log::set_max_level(LevelFilter::Trace);
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke");
    }
}
