//! Small numerical toolkit: descriptive statistics and linear least
//! squares, used by the [`crate::cost::profiler`] to fit the paper's
//! cost-model coefficients (Eqs. 8–9) from measured execution times.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

/// Coefficient of determination of predictions vs observations.
pub fn r_squared(obs: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(obs.len(), pred.len());
    let m = mean(obs);
    let ss_tot: f64 = obs.iter().map(|y| (y - m).powi(2)).sum();
    let ss_res: f64 = obs
        .iter()
        .zip(pred)
        .map(|(y, f)| (y - f).powi(2))
        .sum();
    if ss_tot == 0.0 {
        return if ss_res == 0.0 { 1.0 } else { 0.0 };
    }
    1.0 - ss_res / ss_tot
}

/// Mean absolute percentage error (%), skipping zero observations.
pub fn mape(obs: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(obs.len(), pred.len());
    let mut total = 0.0;
    let mut n = 0usize;
    for (y, f) in obs.iter().zip(pred) {
        if y.abs() > 1e-12 {
            total += ((y - f) / y).abs();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        100.0 * total / n as f64
    }
}

/// Ordinary least squares: find beta minimizing ||X beta - y||^2.
///
/// `x` is row-major, `n` rows × `k` columns. Solves the normal equations
/// with Gaussian elimination + partial pivoting (tiny k — the cost model
/// has ≤ 4 features). Returns `None` if the system is singular.
pub fn least_squares(x: &[f64], n: usize, k: usize, y: &[f64]) -> Option<Vec<f64>> {
    assert_eq!(x.len(), n * k);
    assert_eq!(y.len(), n);
    // Normal equations: (X^T X) beta = X^T y.
    let mut a = vec![0.0; k * k];
    let mut b = vec![0.0; k];
    for i in 0..n {
        let row = &x[i * k..(i + 1) * k];
        for p in 0..k {
            b[p] += row[p] * y[i];
            for q in 0..k {
                a[p * k + q] += row[p] * row[q];
            }
        }
    }
    solve_dense(&mut a, &mut b, k)
}

/// Solve A x = b in place for a small dense system; returns x.
pub fn solve_dense(a: &mut [f64], b: &mut [f64], n: usize) -> Option<Vec<f64>> {
    for col in 0..n {
        // Partial pivot.
        let mut piv = col;
        for r in (col + 1)..n {
            if a[r * n + col].abs() > a[piv * n + col].abs() {
                piv = r;
            }
        }
        if a[piv * n + col].abs() < 1e-12 {
            return None;
        }
        if piv != col {
            for c in 0..n {
                a.swap(col * n + c, piv * n + c);
            }
            b.swap(col, piv);
        }
        let d = a[col * n + col];
        for r in (col + 1)..n {
            let f = a[r * n + col] / d;
            if f == 0.0 {
                continue;
            }
            for c in col..n {
                a[r * n + c] -= f * a[col * n + c];
            }
            b[r] -= f * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for col in (0..n).rev() {
        let mut acc = b[col];
        for c in (col + 1)..n {
            acc -= a[col * n + c] * x[c];
        }
        x[col] = acc / a[col * n + col];
    }
    Some(x)
}

/// Non-negative least squares via projected coordinate descent.
///
/// The cost-model coefficients (α₁, α₂, α₃, β₁, β₂) are physically
/// non-negative; plain OLS can go negative on noisy profiles, which would
/// let the DP solver exploit nonsensical "negative time" regions.
pub fn nnls(x: &[f64], n: usize, k: usize, y: &[f64], iters: usize) -> Vec<f64> {
    let mut beta = least_squares(x, n, k, y)
        .unwrap_or_else(|| vec![0.0; k])
        .iter()
        .map(|b| b.max(0.0))
        .collect::<Vec<_>>();
    // Precompute Gram matrix and X^T y.
    let mut g = vec![0.0; k * k];
    let mut xty = vec![0.0; k];
    for i in 0..n {
        let row = &x[i * k..(i + 1) * k];
        for p in 0..k {
            xty[p] += row[p] * y[i];
            for q in 0..k {
                g[p * k + q] += row[p] * row[q];
            }
        }
    }
    for _ in 0..iters {
        for p in 0..k {
            if g[p * k + p] < 1e-12 {
                continue;
            }
            let mut grad = -xty[p];
            for q in 0..k {
                grad += g[p * k + q] * beta[q];
            }
            beta[p] = (beta[p] - grad / g[p * k + p]).max(0.0);
        }
    }
    beta
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_std() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(mean(&xs), 3.0);
        assert_eq!(median(&xs), 3.0);
        assert!((std_dev(&xs) - 1.4142).abs() < 1e-3);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
        assert_eq!(percentile(&xs, 50.0), 5.0);
    }

    #[test]
    fn least_squares_exact_line() {
        // y = 2 + 3x
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let mut design = Vec::new();
        let mut y = Vec::new();
        for &x in &xs {
            design.extend_from_slice(&[1.0, x]);
            y.push(2.0 + 3.0 * x);
        }
        let beta = least_squares(&design, 10, 2, &y).unwrap();
        assert!((beta[0] - 2.0).abs() < 1e-9);
        assert!((beta[1] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn least_squares_quadratic_cost_shape() {
        // t = a1*L^2 + a2*L + b (the paper's Eq. 8 shape).
        let mut design = Vec::new();
        let mut y = Vec::new();
        for l in [128.0f64, 256.0, 512.0, 1024.0, 2048.0] {
            design.extend_from_slice(&[l * l, l, 1.0]);
            y.push(3e-9 * l * l + 2e-6 * l + 0.5e-3);
        }
        let beta = least_squares(&design, 5, 3, &y).unwrap();
        assert!((beta[0] - 3e-9).abs() < 1e-12);
        assert!((beta[1] - 2e-6).abs() < 1e-9);
        assert!((beta[2] - 0.5e-3).abs() < 1e-6);
    }

    #[test]
    fn singular_system_returns_none() {
        // Two identical columns.
        let design = [1.0, 1.0, 2.0, 2.0, 3.0, 3.0];
        assert!(least_squares(&design, 3, 2, &[1.0, 2.0, 3.0]).is_none());
    }

    #[test]
    fn nnls_clamps_nonnegative() {
        // Data generated with a negative coefficient: NNLS must clamp to 0.
        let mut design = Vec::new();
        let mut y = Vec::new();
        for l in [1.0f64, 2.0, 3.0, 4.0] {
            design.extend_from_slice(&[l, 1.0]);
            y.push(-2.0 * l + 10.0);
        }
        let beta = nnls(&design, 4, 2, &y, 200);
        assert!(beta.iter().all(|&b| b >= 0.0), "{beta:?}");
    }

    #[test]
    fn nnls_matches_ols_when_positive() {
        let mut design = Vec::new();
        let mut y = Vec::new();
        for l in [1.0f64, 2.0, 3.0, 4.0, 7.0] {
            design.extend_from_slice(&[l, 1.0]);
            y.push(2.5 * l + 1.0);
        }
        let beta = nnls(&design, 5, 2, &y, 500);
        assert!((beta[0] - 2.5).abs() < 1e-6, "{beta:?}");
        assert!((beta[1] - 1.0).abs() < 1e-5, "{beta:?}");
    }

    #[test]
    fn r2_and_mape() {
        let obs = [1.0, 2.0, 3.0];
        assert!((r_squared(&obs, &obs) - 1.0).abs() < 1e-12);
        assert!(mape(&obs, &obs) < 1e-12);
        let pred = [1.1, 2.2, 3.3];
        assert!((mape(&obs, &pred) - 10.0).abs() < 1e-9);
    }
}
