//! Deterministic pseudo-random numbers (SplitMix64 core).
//!
//! Every stochastic component in the repo (dataset generators, simulators,
//! property tests) threads one of these through explicitly, so every
//! experiment is reproducible from a seed printed in its report.

/// SplitMix64 generator: tiny state, excellent statistical quality for
/// simulation workloads, trivially seedable and splittable.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Generator seeded deterministically from `seed`.
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    /// Derive an independent stream (for parallel sub-tasks).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [lo, hi) (hi exclusive, lo < hi).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo < hi);
        // Rejection-free multiply-shift; bias negligible for our ranges.
        lo + (self.next_u64() % (hi - lo))
    }

    /// Uniform integer in [lo, hi) (hi exclusive, lo < hi).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(f64::MIN_POSITIVE);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with given mean / std-dev.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal: exp(N(mu, sigma)). The workhorse for video-duration
    /// long-tail distributions (paper Fig. 1).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Bernoulli(p).
    pub fn bool(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose one element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval_and_mean_half() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_is_positive_and_long_tailed() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..10_000).map(|_| r.lognormal(1.5, 1.0)).collect();
        assert!(xs.iter().all(|&x| x > 0.0));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[xs.len() / 2];
        assert!(mean > median, "long tail: mean {mean} > median {median}");
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac2 = counts[2] as f64 / 30_000.0;
        assert!((frac2 - 0.7).abs() < 0.03);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(13);
        for _ in 0..1000 {
            let x = r.range_usize(3, 10);
            assert!((3..10).contains(&x));
        }
    }

    #[test]
    fn split_streams_are_independent() {
        let mut a = Rng::new(1);
        let mut b = a.split();
        let xs: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
