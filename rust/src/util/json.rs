//! Minimal JSON reader/writer (serde is unavailable offline).
//!
//! Covers the subset the repo needs: the AOT `manifest.json` produced by
//! `python/compile/aot.py` (objects, arrays, strings, numbers, bools,
//! null) plus report emission. Strings support the standard escapes;
//! numbers parse as f64 with integer accessors.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (f64 storage, integer accessors).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (stable key order via BTreeMap).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing characters rejected).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at offset {}", p.pos);
        }
        Ok(v)
    }

    /// Object accessor (errors on any other variant).
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => Err(anyhow!("expected object, got {other:?}")),
        }
    }

    /// Array accessor (errors on any other variant).
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => Err(anyhow!("expected array, got {other:?}")),
        }
    }

    /// String accessor (errors on any other variant).
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(anyhow!("expected string, got {other:?}")),
        }
    }

    /// Number accessor (errors on any other variant).
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            other => Err(anyhow!("expected number, got {other:?}")),
        }
    }

    /// Non-negative integer accessor (errors on fractional values).
    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    /// Boolean accessor (errors on any other variant).
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(anyhow!("expected bool, got {other:?}")),
        }
    }

    /// Object field access: `json.get("a")?.get("b")?`.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Optional object field access (None on missing key/non-object).
    pub fn get_opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize (stable key order via BTreeMap).
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, item) in v.iter().enumerate() {
                    let _ = write!(out, "{pad}  ");
                    item.write(out, indent + 1);
                    if i + 1 < v.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                let _ = write!(out, "{pad}]");
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    let _ = write!(out, "{pad}  ");
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                let _ = write!(out, "{pad}}}");
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for report writing.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Number literal.
pub fn num(x: f64) -> Json {
    Json::Num(x)
}

/// String literal.
pub fn s(text: &str) -> Json {
    Json::Str(text.to_string())
}

/// Array literal.
pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected {:?} at offset {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn literal(&mut self, lit: &str) -> bool {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            true
        } else {
            false
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') if self.literal("true") => Ok(Json::Bool(true)),
            Some(b'f') if self.literal("false") => Ok(Json::Bool(false)),
            Some(b'n') if self.literal("null") => Ok(Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at offset {}", other.map(|c| c as char), self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected , or }} in object, got {other:?}"),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected , or ] in array, got {other:?}"),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Advance over one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{
          "artifacts": {
            "model.hlo.txt": {"kind": "grad_step", "param_count": 146752,
              "inputs": [{"name": "flat", "shape": [146752]}],
              "freeze_vision": false}
          }
        }"#;
        let j = Json::parse(text).unwrap();
        let entry = j.get("artifacts").unwrap().get("model.hlo.txt").unwrap();
        assert_eq!(entry.get("kind").unwrap().as_str().unwrap(), "grad_step");
        assert_eq!(entry.get("param_count").unwrap().as_usize().unwrap(), 146752);
        assert!(!entry.get("freeze_vision").unwrap().as_bool().unwrap());
        let shape = entry.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap();
        assert_eq!(shape.as_arr().unwrap()[0].as_usize().unwrap(), 146752);
    }

    #[test]
    fn roundtrip_serialization() {
        let v = obj(vec![
            ("b", num(2.5)),
            ("a", num(1.0)),
            ("s", s("hi\n\"there\"")),
            ("arr", arr(vec![num(1.0), Json::Bool(true), Json::Null])),
        ]);
        let text = v.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64().unwrap(), -350.0);
        assert_eq!(Json::parse("42").unwrap().as_usize().unwrap(), 42);
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "Aé");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }
}
