//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and drive this: warmup,
//! timed repetitions, mean/p50/min/stddev reporting, plus a `BenchReport`
//! collector that renders a criterion-like summary table and can persist
//! the results as machine-readable JSON (`BENCH_*.json`) so successive
//! PRs can track latency trajectories (see `scripts/bench_smoke.sh`).

use std::path::Path;
use std::time::Instant;

use super::json::{self, Json};
use super::stats;

/// Result of timing one benchmark case.
#[derive(Debug, Clone)]
pub struct Timing {
    /// Case name.
    pub name: String,
    /// Measured repetitions.
    pub reps: usize,
    /// Mean seconds per repetition.
    pub mean_s: f64,
    /// Median seconds per repetition.
    pub p50_s: f64,
    /// 90th-percentile seconds per repetition (the tail the solver-
    /// latency budget gates on — means hide stragglers).
    pub p90_s: f64,
    /// Fastest repetition (seconds).
    pub min_s: f64,
    /// Standard deviation (seconds).
    pub std_s: f64,
}

impl Timing {
    /// One-line human-readable summary (milliseconds).
    pub fn summary(&self) -> String {
        format!(
            "{:<44} {:>10.3} ms/iter (p50 {:>10.3}, p90 {:>10.3}, min {:>10.3}, sd {:>8.3}, n={})",
            self.name,
            self.mean_s * 1e3,
            self.p50_s * 1e3,
            self.p90_s * 1e3,
            self.min_s * 1e3,
            self.std_s * 1e3,
            self.reps
        )
    }

    /// Milliseconds-denominated JSON record (the persisted unit).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("mean_ms", json::num(self.mean_s * 1e3)),
            ("p50_ms", json::num(self.p50_s * 1e3)),
            ("p90_ms", json::num(self.p90_s * 1e3)),
            ("min_ms", json::num(self.min_s * 1e3)),
            ("std_ms", json::num(self.std_s * 1e3)),
            ("reps", json::num(self.reps as f64)),
        ])
    }
}

/// Time `f` with `warmup` discarded runs followed by `reps` measured runs.
pub fn time<F: FnMut()>(name: &str, warmup: usize, reps: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    from_samples(name, &samples)
}

/// Build a [`Timing`] from externally collected per-iteration samples
/// (seconds). For cases whose iterations are NOT interchangeable
/// repetitions of one closure — e.g. a steady-state stream where each
/// step solves a *different* correlated batch and the per-step wall
/// times are gathered by the driver — so the standard
/// warmup-plus-identical-reps protocol of [`time`] does not apply.
/// Empty `samples` yield a zeroed timing with `reps == 0`.
pub fn from_samples(name: &str, samples: &[f64]) -> Timing {
    if samples.is_empty() {
        return Timing {
            name: name.to_string(),
            reps: 0,
            mean_s: 0.0,
            p50_s: 0.0,
            p90_s: 0.0,
            min_s: 0.0,
            std_s: 0.0,
        };
    }
    Timing {
        name: name.to_string(),
        reps: samples.len(),
        mean_s: stats::mean(samples),
        p50_s: stats::percentile(samples, 50.0),
        p90_s: stats::percentile(samples, 90.0),
        min_s: samples.iter().cloned().fold(f64::MAX, f64::min),
        std_s: stats::std_dev(samples),
    }
}

/// Collects timings for a bench binary and prints the final block.
#[derive(Debug, Default)]
pub struct BenchReport {
    /// Report title (the bench binary's name).
    pub title: String,
    timings: Vec<Timing>,
}

impl BenchReport {
    /// Empty report with the given title.
    pub fn new(title: &str) -> Self {
        BenchReport {
            title: title.to_string(),
            timings: Vec::new(),
        }
    }

    /// Time one case and collect + print its summary line.
    pub fn bench<F: FnMut()>(&mut self, name: &str, warmup: usize, reps: usize, f: F) {
        let t = time(name, warmup, reps, f);
        println!("  {}", t.summary());
        self.timings.push(t);
    }

    /// Collect + print a case from externally gathered per-iteration
    /// samples (seconds) — see [`from_samples`].
    pub fn record_samples(&mut self, name: &str, samples: &[f64]) {
        let t = from_samples(name, samples);
        println!("  {}", t.summary());
        self.timings.push(t);
    }

    /// All collected timings (ordered by bench() call).
    pub fn timings(&self) -> &[Timing] {
        &self.timings
    }

    /// Persist the collected cases as `{"bench": ..., "meta": ...,
    /// "cases": {name: {mean_ms, p50_ms, ...}}}`. `meta` carries run
    /// conditions (e.g. quick mode, solver threads) so trajectories
    /// compare like with like.
    pub fn write_json(&self, path: &Path, meta: Vec<(&str, Json)>) -> std::io::Result<()> {
        let cases = Json::Obj(
            self.timings
                .iter()
                .map(|t| (t.name.clone(), t.to_json()))
                .collect(),
        );
        let doc = json::obj(vec![
            ("bench", json::s(&self.title)),
            ("meta", json::obj(meta)),
            ("cases", cases),
        ]);
        std::fs::write(path, doc.to_string_pretty() + "\n")
    }

    /// Print the closing case-count line.
    pub fn finish(self) {
        println!(
            "[bench] {}: {} cases complete",
            self.title,
            self.timings.len()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_measures_something() {
        let t = time("spin", 1, 5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert_eq!(t.reps, 5);
        assert!(t.mean_s > 0.0);
        assert!(t.min_s <= t.mean_s);
        assert!(t.min_s <= t.p50_s);
        assert!(t.p50_s <= t.p90_s);
    }

    #[test]
    fn report_collects() {
        let mut r = BenchReport::new("unit");
        r.bench("noop", 0, 2, || {});
        r.finish();
    }

    #[test]
    fn from_samples_matches_the_timed_protocol_stats() {
        let samples = [0.004, 0.001, 0.002, 0.003, 0.010];
        let t = from_samples("stream", &samples);
        assert_eq!(t.reps, 5);
        assert!((t.mean_s - 0.004).abs() < 1e-12);
        assert_eq!(t.min_s, 0.001);
        assert!(t.p50_s <= t.p90_s);
        let empty = from_samples("empty", &[]);
        assert_eq!(empty.reps, 0);
        assert_eq!(empty.mean_s, 0.0);
        let mut r = BenchReport::new("unit_samples");
        r.record_samples("stream", &samples);
        assert_eq!(r.timings().len(), 1);
        assert_eq!(r.timings()[0].reps, 5);
    }

    #[test]
    fn json_roundtrips_cases() {
        let mut r = BenchReport::new("unit_json");
        r.bench("a_case", 0, 3, || {
            std::hint::black_box(2u64.pow(10));
        });
        let dir = std::env::temp_dir();
        let path = dir.join("dhp_bench_unit.json");
        r.write_json(&path, vec![("quick", Json::Bool(true))]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.get("bench").unwrap().as_str().unwrap(), "unit_json");
        assert!(doc.get("meta").unwrap().get("quick").unwrap().as_bool().unwrap());
        let case = doc.get("cases").unwrap().get("a_case").unwrap();
        assert_eq!(case.get("reps").unwrap().as_usize().unwrap(), 3);
        assert!(case.get("mean_ms").unwrap().as_f64().unwrap() >= 0.0);
        assert!(case.get("p50_ms").unwrap().as_f64().unwrap() >= 0.0);
        assert!(
            case.get("p90_ms").unwrap().as_f64().unwrap()
                >= case.get("p50_ms").unwrap().as_f64().unwrap()
        );
        let _ = std::fs::remove_file(&path);
    }
}
