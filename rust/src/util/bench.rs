//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and drive this: warmup,
//! timed repetitions, mean/min/stddev reporting, plus a `BenchReport`
//! collector that renders a criterion-like summary table.

use std::time::Instant;

use super::stats;

/// Result of timing one benchmark case.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub reps: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub std_s: f64,
}

impl Timing {
    pub fn summary(&self) -> String {
        format!(
            "{:<44} {:>10.3} ms/iter (min {:>10.3}, sd {:>8.3}, n={})",
            self.name,
            self.mean_s * 1e3,
            self.min_s * 1e3,
            self.std_s * 1e3,
            self.reps
        )
    }
}

/// Time `f` with `warmup` discarded runs followed by `reps` measured runs.
pub fn time<F: FnMut()>(name: &str, warmup: usize, reps: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Timing {
        name: name.to_string(),
        reps: samples.len(),
        mean_s: stats::mean(&samples),
        min_s: samples.iter().cloned().fold(f64::MAX, f64::min),
        std_s: stats::std_dev(&samples),
    }
}

/// Collects timings for a bench binary and prints the final block.
#[derive(Debug, Default)]
pub struct BenchReport {
    pub title: String,
    timings: Vec<Timing>,
}

impl BenchReport {
    pub fn new(title: &str) -> Self {
        BenchReport {
            title: title.to_string(),
            timings: Vec::new(),
        }
    }

    pub fn bench<F: FnMut()>(&mut self, name: &str, warmup: usize, reps: usize, f: F) {
        let t = time(name, warmup, reps, f);
        println!("  {}", t.summary());
        self.timings.push(t);
    }

    pub fn finish(self) {
        println!(
            "[bench] {}: {} cases complete",
            self.title,
            self.timings.len()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_measures_something() {
        let t = time("spin", 1, 5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert_eq!(t.reps, 5);
        assert!(t.mean_s > 0.0);
        assert!(t.min_s <= t.mean_s);
    }

    #[test]
    fn report_collects() {
        let mut r = BenchReport::new("unit");
        r.bench("noop", 0, 2, || {});
        r.finish();
    }
}
