//! Mini property-testing harness (proptest is unavailable offline).
//!
//! Usage:
//! ```no_run
//! use dhp::util::quickcheck::forall;
//! forall(100, 0xC0FFEE, |rng| {
//!     let n = rng.range_usize(1, 64);
//!     // ... generate a case from `rng`, assert the property, or return
//!     // Err(msg) to report a counterexample.
//!     if n < 64 { Ok(()) } else { Err(format!("n = {n}")) }
//! });
//! ```
//!
//! On failure the harness panics with the case index and per-case seed so
//! the exact counterexample can be replayed with `replay`.

use super::rng::Rng;

/// Run `cases` random cases of `prop`, panicking on the first failure with
/// a replayable seed.
pub fn forall<F>(cases: usize, seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed ^ ((case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property failed at case {case}/{cases} \
                 (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by its reported seed.
pub fn replay<F>(case_seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(case_seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("replayed failure (seed {case_seed:#x}): {msg}");
    }
}

/// Generate a random vector of length in [min_len, max_len) with elements
/// from `gen`.
pub fn vec_of<T>(
    rng: &mut Rng,
    min_len: usize,
    max_len: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
) -> Vec<T> {
    let n = rng.range_usize(min_len, max_len);
    (0..n).map(|_| gen(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        forall(50, 1, |rng| {
            let x = rng.uniform();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(50, 2, |rng| {
            let x = rng.range_usize(0, 10);
            if x < 5 {
                Ok(())
            } else {
                Err(format!("x = {x}"))
            }
        });
    }

    #[test]
    fn vec_of_respects_bounds() {
        forall(30, 3, |rng| {
            let v = vec_of(rng, 2, 9, |r| r.range_usize(0, 100));
            if (2..9).contains(&v.len()) {
                Ok(())
            } else {
                Err(format!("len = {}", v.len()))
            }
        });
    }
}
