//! AOT artifact manifest: parses `artifacts/manifest.json` emitted by
//! `python/compile/aot.py` so the Rust side knows every artifact's
//! signature (shapes, dtypes, model config) without re-deriving them.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// What an artifact computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// `(params, vis, tok, tgt) -> (loss, grads)`
    GradStep,
    /// `(params, vis, tok, tgt) -> (loss,)`
    FwdLoss,
    /// Raw f32 parameter blob.
    Params,
}

/// Metadata of one artifact (one manifest entry).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// What the artifact computes.
    pub kind: ArtifactKind,
    /// Flat parameter count.
    pub param_count: usize,
    /// Batch dimension the artifact was lowered at.
    pub batch: usize,
    /// Total sequence length (vision + text).
    pub seq_total: usize,
    /// Vision token count per sample.
    pub seq_vision: usize,
    /// Text token count per sample.
    pub seq_text: usize,
    /// Vision patch feature dimension.
    pub patch_dim: usize,
    /// Token-id vocabulary size.
    pub vocab: usize,
    /// Whether the vision tower was frozen at lowering time.
    pub freeze_vision: bool,
}

impl ArtifactMeta {
    fn from_json(j: &Json) -> Result<ArtifactMeta> {
        let kind = match j.get("kind")?.as_str()? {
            "grad_step" => ArtifactKind::GradStep,
            "fwd_loss" => ArtifactKind::FwdLoss,
            "params" => ArtifactKind::Params,
            other => bail!("unknown artifact kind {other:?}"),
        };
        if kind == ArtifactKind::Params {
            return Ok(ArtifactMeta {
                kind,
                param_count: j.get("param_count")?.as_usize()?,
                batch: 0,
                seq_total: 0,
                seq_vision: 0,
                seq_text: 0,
                patch_dim: 0,
                vocab: 0,
                freeze_vision: false,
            });
        }
        let config = j.get("config")?;
        Ok(ArtifactMeta {
            kind,
            param_count: j.get("param_count")?.as_usize()?,
            batch: j.get("batch")?.as_usize()?,
            seq_total: j.get("seq_total")?.as_usize()?,
            seq_vision: j.get("seq_vision")?.as_usize()?,
            seq_text: j.get("seq_text")?.as_usize()?,
            patch_dim: config.get("patch_dim")?.as_usize()?,
            vocab: config.get("vocab")?.as_usize()?,
            freeze_vision: j.get("freeze_vision")?.as_bool()?,
        })
    }
}

/// The parsed artifacts manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest (and its artifacts) live in.
    pub dir: PathBuf,
    entries: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    /// Read and parse `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest JSON text (split out for tests).
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let mut entries = BTreeMap::new();
        for (name, entry) in j.get("artifacts")?.as_obj()? {
            entries.insert(name.clone(), ArtifactMeta::from_json(entry)?);
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            entries,
        })
    }

    /// Metadata of one artifact file, if present.
    pub fn get(&self, file: &str) -> Option<&ArtifactMeta> {
        self.entries.get(file)
    }

    /// All artifact file names in the manifest.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }

    /// All fwd_loss artifacts matching a name prefix, sorted by seq_total
    /// — the Profiler's sweep set (e.g. prefix `prof_fwd_`).
    pub fn sweep(&self, prefix: &str) -> Vec<(String, ArtifactMeta)> {
        let mut out: Vec<(String, ArtifactMeta)> = self
            .entries
            .iter()
            .filter(|(name, _)| name.starts_with(prefix))
            .map(|(name, meta)| (name.clone(), meta.clone()))
            .collect();
        out.sort_by_key(|(_, m)| m.seq_total);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "model.hlo.txt": {
          "config": {"vocab": 512, "hidden": 64, "layers": 2, "heads": 4,
                     "vision_hidden": 32, "vision_layers": 1,
                     "vision_heads": 2, "patch_dim": 16, "mlp_ratio": 4},
          "param_count": 146752, "batch": 2, "seq_total": 64,
          "seq_vision": 16, "seq_text": 48, "freeze_vision": false,
          "inputs": [], "kind": "grad_step", "outputs": [], "bytes": 1},
        "prof_fwd_L256.hlo.txt": {
          "config": {"vocab": 2048, "hidden": 256, "layers": 4, "heads": 8,
                     "vision_hidden": 128, "vision_layers": 2,
                     "vision_heads": 4, "patch_dim": 64, "mlp_ratio": 4},
          "param_count": 4110080, "batch": 1, "seq_total": 256,
          "seq_vision": 64, "seq_text": 192, "freeze_vision": false,
          "inputs": [], "kind": "fwd_loss", "outputs": [], "bytes": 1},
        "prof_fwd_L128.hlo.txt": {
          "config": {"vocab": 2048, "hidden": 256, "layers": 4, "heads": 8,
                     "vision_hidden": 128, "vision_layers": 2,
                     "vision_heads": 4, "patch_dim": 64, "mlp_ratio": 4},
          "param_count": 4110080, "batch": 1, "seq_total": 128,
          "seq_vision": 32, "seq_text": 96, "freeze_vision": false,
          "inputs": [], "kind": "fwd_loss", "outputs": [], "bytes": 1},
        "tiny_params.f32": {"kind": "params", "param_count": 146752, "bytes": 4}
      }
    }"#;

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let e = m.get("model.hlo.txt").unwrap();
        assert_eq!(e.kind, ArtifactKind::GradStep);
        assert_eq!(e.param_count, 146752);
        assert_eq!(e.batch, 2);
        assert_eq!(e.seq_vision, 16);
        assert_eq!(e.patch_dim, 16);
        assert_eq!(e.vocab, 512);
        assert!(m.get("missing.hlo.txt").is_none());
    }

    #[test]
    fn params_entry_minimal() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let p = m.get("tiny_params.f32").unwrap();
        assert_eq!(p.kind, ArtifactKind::Params);
        assert_eq!(p.param_count, 146752);
    }

    #[test]
    fn sweep_sorted_by_length() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let sweep = m.sweep("prof_fwd_");
        assert_eq!(sweep.len(), 2);
        assert_eq!(sweep[0].1.seq_total, 128);
        assert_eq!(sweep[1].1.seq_total, 256);
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // Integration: if `make artifacts` has run, the real manifest must
        // parse and contain the canonical entries.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.get("model.hlo.txt").is_some());
        assert!(!m.sweep("prof_fwd_").is_empty());
    }
}
