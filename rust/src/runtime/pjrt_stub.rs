//! Offline stand-in for the `xla` crate (PJRT C-API bindings).
//!
//! Compiled when the `pjrt` cargo feature is OFF (the default — the real
//! crate cannot be vendored offline). It mirrors exactly the API surface
//! `runtime::mod` uses so the module typechecks unchanged; the only
//! reachable entry point, [`PjRtClient::cpu`], returns an error, so every
//! other method is unreachable by construction (the runtime integration
//! tests skip when artifacts are absent and `Runtime::cpu()` fails fast
//! otherwise).

use std::path::Path;

/// Error type standing in for `xla::Error` (only `Debug` is needed).
#[derive(Debug)]
pub struct Error(pub &'static str);

const UNAVAILABLE: &str =
    "PJRT unavailable: built without the `pjrt` cargo feature (add a local \
     `xla` dependency and build with `--features pjrt`)";

/// Stand-in for `xla::PjRtClient`.
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    /// Mirrors `xla::PjRtClient::cpu`; always errors in the stub.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error(UNAVAILABLE))
    }

    /// Mirrors `xla::PjRtClient::platform_name`.
    pub fn platform_name(&self) -> String {
        "pjrt-stub".to_string()
    }

    /// Mirrors `xla::PjRtClient::compile`; unreachable (cpu() fails).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error(UNAVAILABLE))
    }

    /// Mirrors `xla::PjRtClient::buffer_from_host_buffer`; unreachable.
    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        Err(Error(UNAVAILABLE))
    }
}

/// Stand-in for `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Mirrors `xla::HloModuleProto::from_text_file`; unreachable.
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        Err(Error(UNAVAILABLE))
    }
}

/// Stand-in for `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    /// Mirrors `xla::XlaComputation::from_proto`.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stand-in for `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Mirrors `xla::PjRtBuffer::to_literal_sync`; unreachable.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error(UNAVAILABLE))
    }
}

/// Stand-in for `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Mirrors `xla::PjRtLoadedExecutable::client`.
    pub fn client(&self) -> PjRtClient {
        PjRtClient
    }

    /// Mirrors `xla::PjRtLoadedExecutable::execute_b`; unreachable.
    pub fn execute_b<B>(&self, _inputs: &[B]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error(UNAVAILABLE))
    }
}

/// Stand-in for `xla::Literal`.
pub struct Literal;

impl Literal {
    /// Mirrors `xla::Literal::to_tuple1`; unreachable.
    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Err(Error(UNAVAILABLE))
    }

    /// Mirrors `xla::Literal::to_tuple2`; unreachable.
    pub fn to_tuple2(&self) -> Result<(Literal, Literal), Error> {
        Err(Error(UNAVAILABLE))
    }

    /// Mirrors `xla::Literal::get_first_element`; unreachable.
    pub fn get_first_element<T: Default>(&self) -> Result<T, Error> {
        Err(Error(UNAVAILABLE))
    }

    /// Mirrors `xla::Literal::to_vec`; unreachable.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error(UNAVAILABLE))
    }
}
