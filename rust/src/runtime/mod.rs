//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path via
//! the `xla` crate's PJRT C-API bindings. Python never runs here.
//!
//! Interchange is HLO TEXT (`HloModuleProto::from_text_file`) — the
//! serialized-proto path is rejected by xla_extension 0.5.1 for jax ≥ 0.5
//! modules (64-bit instruction ids). See /opt/xla-example/README.md.

pub mod artifacts;
#[cfg(not(feature = "pjrt"))]
pub mod pjrt_stub;

// The `pjrt` feature swaps the stub for the real `xla` crate, which is
// not vendorable offline and therefore not declared in Cargo.toml. Fail
// with a clear message instead of a wall of E0433s.
#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature requires the `xla` crate (PJRT C-API bindings): add a \
     local `xla` path dependency to rust/Cargo.toml and remove this guard"
);

// Without the `pjrt` feature the real `xla` crate is absent; alias the
// stub under the same name so the whole module typechecks unchanged.
#[cfg(not(feature = "pjrt"))]
use pjrt_stub as xla;

use std::path::Path;

use anyhow::{bail, Context, Result};

pub use artifacts::{ArtifactKind, ArtifactMeta, Manifest};

/// A PJRT execution context (CPU plugin).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client })
    }

    /// The PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact from an artifacts directory.
    pub fn load(&self, dir: &Path, file: &str) -> Result<LoadedModel> {
        let manifest = Manifest::load(dir)?;
        let meta = manifest
            .get(file)
            .with_context(|| format!("artifact {file:?} not in manifest"))?
            .clone();
        self.load_with_meta(dir, file, meta)
    }

    /// Load + compile with explicit metadata (tests, ad-hoc artifacts).
    pub fn load_with_meta(
        &self,
        dir: &Path,
        file: &str,
        meta: ArtifactMeta,
    ) -> Result<LoadedModel> {
        let path = dir.join(file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {file}: {e:?}"))?;
        log::info!(
            "compiled {file} ({} params) in {:.1}s",
            meta.param_count,
            t0.elapsed().as_secs_f64()
        );
        Ok(LoadedModel { exe, meta })
    }
}

/// A compiled model artifact ready for execution.
pub struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    /// Shape/kind metadata from the artifact manifest.
    pub meta: ArtifactMeta,
}

/// Outputs of a gradient step.
pub struct GradOut {
    /// Scalar training loss.
    pub loss: f32,
    /// Flat gradient vector (same layout as the params blob).
    pub grads: Vec<f32>,
}

impl LoadedModel {
    fn check_inputs(
        &self,
        params: &[f32],
        vis: &[f32],
        tok: &[i32],
        tgt: &[i32],
    ) -> Result<()> {
        let m = &self.meta;
        if params.len() != m.param_count {
            bail!("params len {} != {}", params.len(), m.param_count);
        }
        let want_vis = m.batch * m.seq_vision * m.patch_dim;
        if vis.len() != want_vis {
            bail!("vis len {} != {}", vis.len(), want_vis);
        }
        let want_txt = m.batch * m.seq_text;
        if tok.len() != want_txt || tgt.len() != want_txt {
            bail!("tok/tgt len {}/{} != {}", tok.len(), tgt.len(), want_txt);
        }
        Ok(())
    }

    /// Upload inputs as device buffers.
    ///
    /// NOTE: this deliberately avoids `PjRtLoadedExecutable::execute`
    /// (literal inputs): the crate's C shim leaks the input device
    /// buffers it creates (`buffer.release()` with no matching free),
    /// which at ~400 MB of parameters per training step OOMs the host in
    /// minutes. `execute_b` over caller-owned `PjRtBuffer`s (freed by
    /// their Rust `Drop`) keeps the hot loop allocation-neutral — found
    /// and fixed during the §Perf pass (EXPERIMENTS.md).
    fn buffers(
        &self,
        params: &[f32],
        vis: &[f32],
        tok: &[i32],
        tgt: &[i32],
    ) -> Result<[xla::PjRtBuffer; 4]> {
        let m = &self.meta;
        let client = self.exe.client();
        let err = |e: xla::Error, what: &str| anyhow::anyhow!("{what}: {e:?}");
        let p = client
            .buffer_from_host_buffer(params, &[params.len()], None)
            .map_err(|e| err(e, "params upload"))?;
        let v = client
            .buffer_from_host_buffer(
                vis,
                &[m.batch, m.seq_vision, m.patch_dim],
                None,
            )
            .map_err(|e| err(e, "vis upload"))?;
        let t = client
            .buffer_from_host_buffer(tok, &[m.batch, m.seq_text], None)
            .map_err(|e| err(e, "tok upload"))?;
        let g = client
            .buffer_from_host_buffer(tgt, &[m.batch, m.seq_text], None)
            .map_err(|e| err(e, "tgt upload"))?;
        Ok([p, v, t, g])
    }

    /// Execute a `grad_step` artifact: returns (loss, flat gradients).
    pub fn grad_step(
        &self,
        params: &[f32],
        vis: &[f32],
        tok: &[i32],
        tgt: &[i32],
    ) -> Result<GradOut> {
        if self.meta.kind != ArtifactKind::GradStep {
            bail!("artifact {:?} is not a grad_step", self.meta.kind);
        }
        self.check_inputs(params, vis, tok, tgt)?;
        let inputs = self.buffers(params, vis, tok, tgt)?;
        let result = self
            .exe
            .execute_b::<xla::PjRtBuffer>(&inputs)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch: {e:?}"))?;
        let (loss_lit, grads_lit) = result
            .to_tuple2()
            .map_err(|e| anyhow::anyhow!("tuple2: {e:?}"))?;
        let loss = loss_lit
            .get_first_element::<f32>()
            .map_err(|e| anyhow::anyhow!("loss: {e:?}"))?;
        let grads = grads_lit
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("grads: {e:?}"))?;
        Ok(GradOut { loss, grads })
    }

    /// Execute a `fwd_loss` artifact: returns the scalar loss.
    pub fn fwd_loss(
        &self,
        params: &[f32],
        vis: &[f32],
        tok: &[i32],
        tgt: &[i32],
    ) -> Result<f32> {
        if self.meta.kind != ArtifactKind::FwdLoss {
            bail!("artifact {:?} is not a fwd_loss", self.meta.kind);
        }
        self.check_inputs(params, vis, tok, tgt)?;
        let inputs = self.buffers(params, vis, tok, tgt)?;
        let result = self
            .exe
            .execute_b::<xla::PjRtBuffer>(&inputs)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch: {e:?}"))?;
        let loss_lit = result
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("tuple1: {e:?}"))?;
        loss_lit
            .get_first_element::<f32>()
            .map_err(|e| anyhow::anyhow!("loss: {e:?}"))
    }

    /// Wall-clock one execution (for the Profiler). Uses synthetic inputs.
    pub fn time_execution(&self, params: &[f32]) -> Result<f64> {
        let m = &self.meta;
        let vis = vec![0.1f32; m.batch * m.seq_vision * m.patch_dim];
        let tok = vec![1i32; m.batch * m.seq_text];
        let tgt = vec![2i32; m.batch * m.seq_text];
        let t0 = std::time::Instant::now();
        match m.kind {
            ArtifactKind::FwdLoss => {
                self.fwd_loss(params, &vis, &tok, &tgt)?;
            }
            ArtifactKind::GradStep => {
                self.grad_step(params, &vis, &tok, &tgt)?;
            }
            ArtifactKind::Params => bail!("cannot execute a params blob"),
        }
        Ok(t0.elapsed().as_secs_f64())
    }
}

/// Load a raw little-endian f32 parameter file (`*_params.f32`).
pub fn load_params(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    if bytes.len() % 4 != 0 {
        bail!("param file size {} not a multiple of 4", bytes.len());
    }
    let mut out = Vec::with_capacity(bytes.len() / 4);
    for chunk in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
    }
    Ok(out)
}
