//! Device mesh: maps replica ranks to physical (node, device) slots and
//! allocates contiguous, locality-preserving rank ranges to CP groups —
//! a group that fits inside one node rides the fast intra-node fabric
//! (HCCS), a group spanning nodes is bottlenecked by the inter-node link.

use crate::config::ClusterConfig;

use super::group::RankId;

/// Physical placement of replica ranks.
#[derive(Debug, Clone)]
pub struct DeviceMesh {
    pub replicas: usize,
    pub replicas_per_node: usize,
    pub intra_bw: f64,
    pub inter_bw: f64,
}

impl DeviceMesh {
    pub fn new(cluster: &ClusterConfig) -> Self {
        DeviceMesh {
            replicas: cluster.replicas(),
            replicas_per_node: cluster.replicas_per_node().max(1),
            intra_bw: cluster.intra_bw,
            inter_bw: cluster.inter_bw,
        }
    }

    /// Node hosting a replica rank.
    pub fn node_of(&self, rank: RankId) -> usize {
        rank / self.replicas_per_node
    }

    /// Does a rank set stay within one node?
    pub fn is_intra_node(&self, ranks: &[RankId]) -> bool {
        match ranks.first() {
            None => true,
            Some(&r0) => {
                let node = self.node_of(r0);
                ranks.iter().all(|&r| self.node_of(r) == node)
            }
        }
    }

    /// Effective ring P2P bandwidth for a rank set: the slowest link on
    /// the ring (inter-node if the set crosses nodes).
    pub fn ring_bandwidth(&self, ranks: &[RankId]) -> f64 {
        if self.is_intra_node(ranks) {
            self.intra_bw
        } else {
            self.inter_bw
        }
    }

    /// Allocate rank blocks for groups of the given degrees,
    /// LOCALITY-AWARE: a group that fits within one node is placed inside
    /// a single node (riding the fast intra-node fabric); larger groups
    /// take whole-node spans first. This mirrors what a real MPU
    /// reconfiguration does when rebuilding HCCL rings. Returns per-group
    /// rank vectors in the *input* order. Panics if Σ degrees > replicas.
    pub fn allocate(&self, degrees: &[usize]) -> Vec<Vec<RankId>> {
        let total: usize = degrees.iter().sum();
        assert!(
            total <= self.replicas,
            "allocate: need {total} ranks, have {}",
            self.replicas
        );
        let rpn = self.replicas_per_node;
        let n_nodes = self.replicas.div_ceil(rpn);
        // Free slots per node.
        let mut free: Vec<Vec<RankId>> = (0..n_nodes)
            .map(|node| {
                (node * rpn..((node + 1) * rpn).min(self.replicas)).collect()
            })
            .collect();
        // Place largest first (stable order for determinism).
        let mut order: Vec<usize> = (0..degrees.len()).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(degrees[i]));
        let mut out = vec![Vec::new(); degrees.len()];
        for &i in &order {
            let d = degrees[i];
            if d <= rpn {
                // Best fit: the node whose free count is smallest but
                // sufficient (preserves big holes for later groups).
                let node = free
                    .iter()
                    .enumerate()
                    .filter(|(_, f)| f.len() >= d)
                    .min_by_key(|(_, f)| f.len())
                    .map(|(n, _)| n);
                if let Some(n) = node {
                    out[i] = free[n].drain(..d).collect();
                    continue;
                }
            }
            // Node-spanning (or fragmented) group: take the emptiest
            // nodes' slots greedily.
            let mut need = d;
            let mut ranks = Vec::with_capacity(d);
            let mut node_order: Vec<usize> = (0..n_nodes).collect();
            node_order.sort_by_key(|&n| std::cmp::Reverse(free[n].len()));
            for n in node_order {
                if need == 0 {
                    break;
                }
                let take = need.min(free[n].len());
                ranks.extend(free[n].drain(..take));
                need -= take;
            }
            assert_eq!(need, 0, "allocator accounting bug");
            ranks.sort_unstable();
            out[i] = ranks;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn mesh() -> DeviceMesh {
        DeviceMesh::new(&ClusterConfig::default()) // 8 nodes × 8
    }

    #[test]
    fn node_mapping() {
        let m = mesh();
        assert_eq!(m.node_of(0), 0);
        assert_eq!(m.node_of(7), 0);
        assert_eq!(m.node_of(8), 1);
        assert_eq!(m.node_of(63), 7);
    }

    #[test]
    fn intra_vs_inter_bandwidth() {
        let m = mesh();
        assert_eq!(m.ring_bandwidth(&[0, 1, 2, 3]), m.intra_bw);
        assert_eq!(m.ring_bandwidth(&[6, 7, 8]), m.inter_bw);
        assert_eq!(m.ring_bandwidth(&[]), m.intra_bw);
    }

    #[test]
    fn allocate_is_disjoint_and_complete() {
        let m = mesh();
        let groups = m.allocate(&[8, 6, 6, 4, 2, 2, 1, 1, 1, 1]);
        let mut all: Vec<RankId> = groups.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all.len(), 32);
        all.dedup();
        assert_eq!(all.len(), 32, "ranks must be disjoint");
        // Each group's size matches its degree, in input order.
        assert_eq!(groups[0].len(), 8);
        assert_eq!(groups[3].len(), 4);
    }

    #[test]
    fn large_groups_get_aligned_blocks() {
        let m = mesh();
        let groups = m.allocate(&[2, 8]);
        // The degree-8 group is placed first (largest-first) at offset 0:
        // exactly one node → intra-node bandwidth.
        assert_eq!(groups[1], (0..8).collect::<Vec<_>>());
        assert!(m.is_intra_node(&groups[1]));
    }

    #[test]
    #[should_panic(expected = "allocate")]
    fn over_allocation_panics() {
        mesh().allocate(&[60, 10]);
    }
}
