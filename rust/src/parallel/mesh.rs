//! Device mesh: maps replica ranks to physical (node, device) slots and
//! allocates contiguous, locality-preserving rank ranges to CP groups —
//! a group that fits inside one node rides the fast intra-node fabric
//! (HCCS), a group spanning nodes is bottlenecked by the inter-node link.
//!
//! Placement is the bridge between a *logical* plan (degrees only) and a
//! *placed* plan (concrete rank sets): [`DeviceMesh::place`] assigns every
//! group its ranks deterministically, optionally steered by a
//! [`WaveHint`] — the rank blocks the same wave slot used on the previous
//! scheduling step. Preferring those blocks is what makes consecutive
//! steps of a stationary workload key into the same pooled communication
//! groups ([`super::pool::GroupPool`]), which is the paper's §5 claim that
//! reconfiguration cost amortizes to nothing.

use std::collections::HashMap;

use crate::config::ClusterConfig;

use super::group::RankId;

/// Placement preferences for ONE wave slot: the rank blocks the previous
/// realization of this slot used, keyed by group degree, in the order the
/// placer assigned them (largest-degree first). Replaying the same degree
/// vector against the same hint reproduces the previous placement
/// *exactly*, which is both the determinism guarantee the scheduler's
/// bit-identity tests rely on and the mechanism that turns pool misses
/// into hits across steps.
#[derive(Debug, Clone, Default)]
pub struct WaveHint {
    blocks: HashMap<usize, Vec<Vec<RankId>>>,
}

impl WaveHint {
    /// Record one placed block (ranks must be sorted — they come from the
    /// placer, which emits sorted sets).
    pub fn remember(&mut self, ranks: &[RankId]) {
        let entry = self.blocks.entry(ranks.len()).or_default();
        // A block the hint already holds is not re-recorded: duplicate
        // entries would let two groups of one wave race for the same
        // ranks and fall through to fresh allocation.
        if !entry.iter().any(|b| b == ranks) {
            entry.push(ranks.to_vec());
        }
    }

    fn candidates(&self, degree: usize) -> Option<&[Vec<RankId>]> {
        self.blocks.get(&degree).map(|v| v.as_slice())
    }

    /// Degrees for which this hint holds at least one intra-node block
    /// whose ranks are all still free on `mesh`, with the count of such
    /// blocks per degree. These are the blocks a replay-preferring
    /// placement can land on at full intra bandwidth — the fabric
    /// oracle's "hint-replayable" census
    /// ([`crate::scheduler::FabricModel`]).
    pub fn free_intra_degrees(&self, mesh: &DeviceMesh) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for (&d, blocks) in &self.blocks {
            let count = blocks
                .iter()
                .filter(|b| {
                    b.iter()
                        .all(|&r| r < mesh.replicas && mesh.is_rank_free(r))
                        && mesh.is_intra_node(b)
                })
                .count();
            if count > 0 {
                out.push((d, count));
            }
        }
        out
    }
}

/// Placement memory across scheduling steps: one [`WaveHint`] per wave
/// slot of the previously placed schedule. Wave slots are matched by
/// index — waves execute serially over the full cluster, so slot `w` of
/// step `t` reuses slot `w` of step `t-1`.
#[derive(Debug, Clone, Default)]
pub struct PlacementHint {
    /// Per-wave-slot hints, indexed like the previous schedule's waves.
    pub waves: Vec<WaveHint>,
}

impl PlacementHint {
    /// The hint recorded for wave slot `idx`, if any.
    pub fn wave(&self, idx: usize) -> Option<&WaveHint> {
        self.waves.get(idx)
    }

    /// Forget all recorded placements.
    pub fn clear(&mut self) {
        self.waves.clear();
    }
}

/// Outcome of one tracked wave placement ([`DeviceMesh::place_tracked`]):
/// the per-group rank blocks plus hint-quality telemetry — how many
/// groups landed on a block replayed from the [`WaveHint`]. Replayed
/// groups key into already-pooled communication groups, so the replay
/// count separates placement churn from genuine workload drift when the
/// pool's hit-rate drops.
#[derive(Debug, Clone, PartialEq)]
pub struct Placement {
    /// Per-group rank vectors in the input (plan) order, each sorted.
    pub blocks: Vec<Vec<RankId>>,
    /// Number of groups whose block was replayed from the hint (0 when
    /// placing without a hint).
    pub replayed: usize,
}

/// Physical placement of replica ranks.
///
/// A mesh also tracks *occupancy*: replica slots pre-claimed by
/// concurrent jobs (or held back by an external resource manager) are
/// marked via [`DeviceMesh::occupy`] and excluded from every placement
/// and from the fabric oracle's free-slot census — the fragmented-mesh
/// regime where the uniform-bandwidth heuristic and reality diverge.
#[derive(Debug, Clone)]
pub struct DeviceMesh {
    /// Total model replicas (one replica = one full TP×PP grid).
    pub replicas: usize,
    /// Replicas hosted per physical node.
    pub replicas_per_node: usize,
    /// Intra-node fabric bandwidth (HCCS), bytes/s.
    pub intra_bw: f64,
    /// Inter-node fabric bandwidth (IB), bytes/s.
    pub inter_bw: f64,
    /// Per-rank occupancy: `true` marks a slot unavailable to this job.
    occupied: Vec<bool>,
}

impl DeviceMesh {
    /// Mesh over the cluster's replica topology.
    pub fn new(cluster: &ClusterConfig) -> Self {
        let replicas = cluster.replicas();
        DeviceMesh {
            replicas,
            replicas_per_node: cluster.replicas_per_node().max(1),
            intra_bw: cluster.intra_bw,
            inter_bw: cluster.inter_bw,
            occupied: vec![false; replicas],
        }
    }

    /// A degenerate single-fabric mesh: every link runs at `bw`. Used by
    /// baseline policies constructed without cluster topology (their
    /// uniform-bandwidth estimates then match the pre-placement ones).
    pub fn uniform(replicas: usize, bw: f64) -> Self {
        DeviceMesh {
            replicas,
            replicas_per_node: replicas.max(1),
            intra_bw: bw,
            inter_bw: bw,
            occupied: vec![false; replicas],
        }
    }

    /// Mark `ranks` as held by someone else (a concurrent job, an
    /// external reservation): they become invisible to every subsequent
    /// placement and to the fabric oracle's free-slot census. Panics on
    /// an out-of-range or already-occupied rank — double-claiming a slot
    /// is an accounting bug, not a state to paper over.
    pub fn occupy(&mut self, ranks: &[RankId]) {
        for &r in ranks {
            assert!(r < self.replicas, "occupy: rank {r} out of range");
            assert!(!self.occupied[r], "occupy: rank {r} already occupied");
            self.occupied[r] = true;
        }
    }

    /// Return previously [`DeviceMesh::occupy`]-ed ranks to the free
    /// pool. Panics if a rank is not currently occupied.
    pub fn release(&mut self, ranks: &[RankId]) {
        for &r in ranks {
            assert!(r < self.replicas, "release: rank {r} out of range");
            assert!(self.occupied[r], "release: rank {r} is not occupied");
            self.occupied[r] = false;
        }
    }

    /// Builder form of [`DeviceMesh::occupy`] for test/experiment setup.
    pub fn with_occupied(mut self, ranks: &[RankId]) -> Self {
        self.occupy(ranks);
        self
    }

    /// Is `rank` free for this job's placements? (Out-of-range ranks are
    /// not free.)
    pub fn is_rank_free(&self, rank: RankId) -> bool {
        rank < self.replicas && !self.occupied[rank]
    }

    /// Replica slots currently available to this job.
    pub fn free_replicas(&self) -> usize {
        self.occupied.iter().filter(|&&o| !o).count()
    }

    /// Replica slots currently held by others.
    pub fn occupied_replicas(&self) -> usize {
        self.replicas - self.free_replicas()
    }

    /// Free-slot count per physical node (the fabric oracle's census: a
    /// degree can ride the intra-node fabric iff some node's entry here
    /// is at least that large).
    pub fn free_per_node(&self) -> Vec<usize> {
        let rpn = self.replicas_per_node;
        let n_nodes = self.replicas.div_ceil(rpn);
        (0..n_nodes)
            .map(|node| {
                (node * rpn..((node + 1) * rpn).min(self.replicas))
                    .filter(|&r| !self.occupied[r])
                    .count()
            })
            .collect()
    }

    /// Node hosting a replica rank.
    pub fn node_of(&self, rank: RankId) -> usize {
        rank / self.replicas_per_node
    }

    /// Does a rank set stay within one node?
    pub fn is_intra_node(&self, ranks: &[RankId]) -> bool {
        match ranks.first() {
            None => true,
            Some(&r0) => {
                let node = self.node_of(r0);
                ranks.iter().all(|&r| self.node_of(r) == node)
            }
        }
    }

    /// Effective ring P2P bandwidth for a rank set: the slowest link on
    /// the ring (inter-node if the set crosses nodes).
    pub fn ring_bandwidth(&self, ranks: &[RankId]) -> f64 {
        if self.is_intra_node(ranks) {
            self.intra_bw
        } else {
            self.inter_bw
        }
    }

    /// Allocate rank blocks for groups of the given degrees,
    /// LOCALITY-AWARE: a group that fits within one node is placed inside
    /// a single node (riding the fast intra-node fabric); larger groups
    /// take whole-node spans first. This mirrors what a real MPU
    /// reconfiguration does when rebuilding HCCL rings. Returns per-group
    /// rank vectors in the *input* order, each sorted ascending.
    /// Deterministic: the same degree vector always yields the same
    /// blocks. Panics if Σ degrees > replicas.
    pub fn allocate(&self, degrees: &[usize]) -> Vec<Vec<RankId>> {
        self.place(degrees, None)
    }

    /// [`DeviceMesh::allocate`] with reuse preference: before falling back
    /// to the locality heuristic, each group first tries the hint's blocks
    /// of its degree (in recorded order, first fully-free block wins).
    /// With `hint = None` this IS the historical `allocate` behavior.
    pub fn place(&self, degrees: &[usize], hint: Option<&WaveHint>) -> Vec<Vec<RankId>> {
        self.place_tracked(degrees, hint).blocks
    }

    /// [`DeviceMesh::place`] with hint-quality telemetry: additionally
    /// reports how many groups were placed by replaying a hinted block
    /// (see [`Placement`]). The blocks are identical to what
    /// [`DeviceMesh::place`] returns for the same inputs.
    pub fn place_tracked(
        &self,
        degrees: &[usize],
        hint: Option<&WaveHint>,
    ) -> Placement {
        let total: usize = degrees.iter().sum();
        let available = self.free_replicas();
        assert!(
            total <= available,
            "allocate: need {total} ranks, have {available} free of {}",
            self.replicas
        );
        let rpn = self.replicas_per_node;
        let n_nodes = self.replicas.div_ceil(rpn);
        // Free slots per node (kept sorted, pre-occupied ranks excluded),
        // plus a flat freeness map so hinted blocks can be
        // membership-tested in O(d).
        let mut free: Vec<Vec<RankId>> = (0..n_nodes)
            .map(|node| {
                (node * rpn..((node + 1) * rpn).min(self.replicas))
                    .filter(|&r| !self.occupied[r])
                    .collect()
            })
            .collect();
        let mut is_free: Vec<bool> =
            (0..self.replicas).map(|r| !self.occupied[r]).collect();
        // Hinted blocks are consumed at most once per wave placement.
        let mut hint_used: HashMap<usize, Vec<bool>> = HashMap::new();
        // Place largest first (stable order for determinism).
        let mut order: Vec<usize> = (0..degrees.len()).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(degrees[i]));
        let mut out = vec![Vec::new(); degrees.len()];
        let mut replayed = 0usize;
        'groups: for &i in &order {
            let d = degrees[i];
            // Reuse preference: the first still-free block this degree
            // used last step. Matching the k-th degree-d group to the
            // k-th recorded block replays the previous placement when the
            // degree vector is unchanged.
            if let Some(cands) = hint.and_then(|h| h.candidates(d)) {
                let used = hint_used
                    .entry(d)
                    .or_insert_with(|| vec![false; cands.len()]);
                // Blocks under key d all have length d (WaveHint keys by
                // block length), so only freeness needs checking.
                for (bi, block) in cands.iter().enumerate() {
                    if used[bi]
                        || !block
                            .iter()
                            .all(|&r| is_free.get(r).copied().unwrap_or(false))
                    {
                        continue;
                    }
                    // Locality guard: never let reuse downgrade a group
                    // that fits inside one node onto a node-spanning
                    // block — pool hits must not cost ring bandwidth.
                    // (Replay stays exact: a fragmented block the
                    // previous step produced via fresh fallback is
                    // re-derived identically by the fallback below.)
                    if d <= rpn && !self.is_intra_node(block) {
                        continue;
                    }
                    used[bi] = true;
                    for &r in block {
                        is_free[r] = false;
                        free[self.node_of(r)].retain(|&x| x != r);
                    }
                    out[i] = block.clone();
                    replayed += 1;
                    continue 'groups;
                }
            }
            if d <= rpn {
                // Best fit: the node whose free count is smallest but
                // sufficient (preserves big holes for later groups).
                let node = free
                    .iter()
                    .enumerate()
                    .filter(|(_, f)| f.len() >= d)
                    .min_by_key(|(_, f)| f.len())
                    .map(|(n, _)| n);
                if let Some(n) = node {
                    let ranks: Vec<RankId> = free[n].drain(..d).collect();
                    for &r in &ranks {
                        is_free[r] = false;
                    }
                    out[i] = ranks;
                    continue;
                }
            }
            // Node-spanning (or fragmented) group: take the emptiest
            // nodes' slots greedily.
            let mut need = d;
            let mut ranks = Vec::with_capacity(d);
            let mut node_order: Vec<usize> = (0..n_nodes).collect();
            node_order.sort_by_key(|&n| std::cmp::Reverse(free[n].len()));
            for n in node_order {
                if need == 0 {
                    break;
                }
                let take = need.min(free[n].len());
                ranks.extend(free[n].drain(..take));
                need -= take;
            }
            assert_eq!(need, 0, "allocator accounting bug");
            for &r in &ranks {
                is_free[r] = false;
            }
            ranks.sort_unstable();
            out[i] = ranks;
        }
        Placement {
            blocks: out,
            replayed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn mesh() -> DeviceMesh {
        DeviceMesh::new(&ClusterConfig::default()) // 8 nodes × 8
    }

    #[test]
    fn node_mapping() {
        let m = mesh();
        assert_eq!(m.node_of(0), 0);
        assert_eq!(m.node_of(7), 0);
        assert_eq!(m.node_of(8), 1);
        assert_eq!(m.node_of(63), 7);
    }

    #[test]
    fn intra_vs_inter_bandwidth() {
        let m = mesh();
        assert_eq!(m.ring_bandwidth(&[0, 1, 2, 3]), m.intra_bw);
        assert_eq!(m.ring_bandwidth(&[6, 7, 8]), m.inter_bw);
        assert_eq!(m.ring_bandwidth(&[]), m.intra_bw);
    }

    #[test]
    fn allocate_is_disjoint_and_complete() {
        let m = mesh();
        let groups = m.allocate(&[8, 6, 6, 4, 2, 2, 1, 1, 1, 1]);
        let mut all: Vec<RankId> = groups.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all.len(), 32);
        all.dedup();
        assert_eq!(all.len(), 32, "ranks must be disjoint");
        // Each group's size matches its degree, in input order.
        assert_eq!(groups[0].len(), 8);
        assert_eq!(groups[3].len(), 4);
    }

    #[test]
    fn large_groups_get_aligned_blocks() {
        let m = mesh();
        let groups = m.allocate(&[2, 8]);
        // The degree-8 group is placed first (largest-first) at offset 0:
        // exactly one node → intra-node bandwidth.
        assert_eq!(groups[1], (0..8).collect::<Vec<_>>());
        assert!(m.is_intra_node(&groups[1]));
    }

    #[test]
    #[should_panic(expected = "allocate")]
    fn over_allocation_panics() {
        mesh().allocate(&[60, 10]);
    }

    #[test]
    fn allocate_is_deterministic() {
        let m = mesh();
        let degrees = [7usize, 5, 5, 3, 2, 1, 1];
        let a = m.allocate(&degrees);
        let b = m.allocate(&degrees);
        assert_eq!(a, b, "same degrees must always place identically");
        // And place() with no hint IS allocate.
        assert_eq!(a, m.place(&degrees, None));
    }

    #[test]
    fn hint_replays_previous_placement() {
        let m = mesh();
        let degrees = [6usize, 4, 2, 1, 1, 1];
        let first = m.allocate(&degrees);
        let mut hint = WaveHint::default();
        for block in &first {
            hint.remember(block);
        }
        let replay = m.place(&degrees, Some(&hint));
        assert_eq!(first, replay, "unchanged degree vector must replay");
    }

    #[test]
    fn tracked_placement_counts_replayed_groups() {
        let m = mesh();
        let degrees = [6usize, 4, 2, 1];
        let first = m.place_tracked(&degrees, None);
        assert_eq!(first.replayed, 0, "no hint, nothing replayed");
        let mut hint = WaveHint::default();
        for block in &first.blocks {
            hint.remember(block);
        }
        let replay = m.place_tracked(&degrees, Some(&hint));
        assert_eq!(replay.blocks, first.blocks);
        assert_eq!(replay.replayed, degrees.len(), "full replay");
        // One degree changes: only the surviving degrees replay.
        let partial = m.place_tracked(&[6usize, 4, 3], Some(&hint));
        assert_eq!(partial.replayed, 2);
    }

    #[test]
    fn hint_survives_partial_degree_change() {
        let m = mesh();
        let first = m.allocate(&[4usize, 4, 4]);
        let mut hint = WaveHint::default();
        for block in &first {
            hint.remember(block);
        }
        // One group changes degree; the two surviving degree-4 groups must
        // still land on previously used blocks (→ pool hits).
        let next = m.place(&[4usize, 4, 3], Some(&hint));
        assert!(first.contains(&next[0]));
        assert!(first.contains(&next[1]));
        assert_ne!(next[0], next[1]);
        // Disjointness holds with the fresh degree-3 group.
        let mut all: Vec<RankId> = next.iter().flatten().copied().collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 11);
    }

    #[test]
    fn stale_hint_blocks_are_skipped() {
        let m = mesh();
        let mut hint = WaveHint::default();
        hint.remember(&[0, 1, 2, 3, 4, 5, 6, 7]); // will be free
        hint.remember(&[200, 201]); // out of range — must be ignored
        let out = m.place(&[8usize, 2], Some(&hint));
        assert_eq!(out[0], (0..8).collect::<Vec<_>>());
        assert_eq!(out[1].len(), 2);
        assert!(out[1].iter().all(|&r| r < 64));
    }

    #[test]
    fn occupancy_excludes_ranks_from_placement() {
        let mut m = mesh();
        m.occupy(&[0, 1, 2, 3, 8, 9]);
        assert_eq!(m.free_replicas(), 58);
        assert_eq!(m.occupied_replicas(), 6);
        assert!(!m.is_rank_free(0));
        assert!(m.is_rank_free(4));
        assert_eq!(m.free_per_node()[0], 4);
        assert_eq!(m.free_per_node()[1], 6);
        let groups = m.allocate(&[8, 6, 4, 1, 1]);
        for g in &groups {
            for &r in g {
                assert!(m.is_rank_free(r), "rank {r} placed while occupied");
            }
        }
        // Release restores the full mesh.
        m.release(&[0, 1, 2, 3, 8, 9]);
        assert_eq!(m.free_replicas(), 64);
    }

    #[test]
    #[should_panic(expected = "allocate")]
    fn occupancy_shrinks_the_rank_budget() {
        // 60 ranks requested, but only 56 are free.
        mesh().with_occupied(&[0, 1, 2, 3, 4, 5, 6, 7]).allocate(&[60]);
    }

    #[test]
    #[should_panic(expected = "already occupied")]
    fn double_occupy_panics() {
        let mut m = mesh();
        m.occupy(&[5]);
        m.occupy(&[5]);
    }

    #[test]
    fn occupied_hint_blocks_are_not_replayed() {
        let mut m = mesh();
        let first = m.allocate(&[4usize, 4]);
        let mut hint = WaveHint::default();
        for block in &first {
            hint.remember(block);
        }
        assert_eq!(hint.free_intra_degrees(&m), vec![(4, 2)]);
        // Occupy one rank of the first block: that block must neither be
        // replayed nor counted replayable; placement stays disjoint from
        // the occupied rank.
        m.occupy(&[first[0][0]]);
        assert_eq!(hint.free_intra_degrees(&m), vec![(4, 1)]);
        let placement = m.place_tracked(&[4usize, 4], Some(&hint));
        assert_eq!(placement.replayed, 1, "only the free block replays");
        for block in &placement.blocks {
            for &r in block {
                assert!(m.is_rank_free(r));
            }
        }
    }

    #[test]
    fn uniform_mesh_is_single_fabric() {
        let m = DeviceMesh::uniform(16, 12.5e9);
        assert_eq!(m.ring_bandwidth(&[0, 15]), 12.5e9);
        assert!(m.is_intra_node(&[0, 15]));
        let groups = m.allocate(&[8, 8]);
        assert_eq!(groups[0].len(), 8);
        assert_eq!(groups[1].len(), 8);
    }
}
