//! MPU-style parallel state (paper §5, implementation detail 4): TP/PP
//! stay in their static grid; DHP dynamically re-derives the CP (and
//! implied DP) groups per micro-batch, acquiring them through the pool.

use anyhow::{bail, Result};

use super::group::{CommGroup, GroupKind, RankId};
use super::mesh::DeviceMesh;
use super::pool::{GroupPool, PoolCapacity, PoolStats};
use crate::scheduler::{PlacedPlan, Schedule};

/// The live parallel state of the training job.
#[derive(Debug)]
pub struct ParallelState {
    /// The physical replica topology groups are placed on.
    pub mesh: DeviceMesh,
    /// Static tensor-parallel degree (validated, never reconfigured).
    pub tp: usize,
    /// Static pipeline-parallel degree (validated, never reconfigured).
    pub pp: usize,
    pool: GroupPool,
    /// CP groups of the current micro-batch, in plan order.
    current_cp: Vec<CommGroup>,
    /// Reconfiguration count (diagnostics).
    pub reconfigurations: u64,
}

impl ParallelState {
    /// Fresh parallel state with an unbounded group pool.
    pub fn new(mesh: DeviceMesh, tp: usize, pp: usize) -> Self {
        ParallelState {
            mesh,
            tp,
            pp,
            pool: GroupPool::new(),
            current_cp: Vec::new(),
            reconfigurations: 0,
        }
    }

    /// Bound the group pool's communicator-buffer budget (LRU eviction on
    /// overflow — see [`PoolCapacity`]).
    pub fn with_pool_capacity(mut self, capacity: PoolCapacity) -> Self {
        self.pool.set_capacity(capacity);
        self
    }

    /// Model the cluster's per-member-rank communicator buffer footprint
    /// (threaded from [`crate::config::ClusterConfig::group_buffer_bytes`];
    /// defaults to the 64 MiB constant).
    pub fn with_group_buffer_bytes(mut self, bytes: u64) -> Self {
        self.pool.set_buffer_bytes_per_rank(bytes);
        self
    }

    /// Reconfigure the CP layout from a PLACED plan: the scheduler
    /// already bound ranks, so this validates the placement invariants
    /// and acquires pooled groups directly — no mesh re-allocation
    /// happens on the execution path. The wave's groups are acquired
    /// atomically ([`GroupPool::acquire_wave_groups`]): they are co-live
    /// on the device, so a capacity-capped pool may evict only groups
    /// OUTSIDE this wave to make room.
    pub fn reconfigure_cp_placed(&mut self, plan: &PlacedPlan) -> Result<&[CommGroup]> {
        plan.validate_placement(self.mesh.replicas)?;
        self.current_cp = self
            .pool
            .acquire_wave_groups(plan.groups.iter().map(|g| g.pool_key()));
        self.reconfigurations += 1;
        Ok(&self.current_cp)
    }

    /// Prepare (prewarm) every wave of a placed schedule ONE STEP AHEAD
    /// of execution — the paper's CPU-side overlap: group creation for
    /// the next batch happens while the accelerator is busy with the
    /// current one. Returns the simulated creation seconds paid for pool
    /// misses during this prepare.
    ///
    /// Prewarm order is eviction-aware: on an unbounded pool waves warm
    /// in execution order (`current_cp` is left on the last wave — the
    /// historical behavior); on a capacity-capped pool they warm in
    /// REVERSE wave order, so the groups the executor needs soonest are
    /// the most recently touched — the warmest under LRU — and a cap
    /// below the schedule's working set evicts the last wave's groups
    /// (needed latest) instead of the first's (`current_cp` then ends on
    /// wave 0, the wave about to execute).
    pub fn prepare_schedule(&mut self, schedule: &Schedule) -> Result<f64> {
        let before = self.pool.stats().create_time_s;
        if matches!(self.pool.capacity(), PoolCapacity::Unbounded) {
            for wave in &schedule.waves {
                self.reconfigure_cp_placed(wave)?;
            }
        } else {
            for wave in schedule.waves.iter().rev() {
                self.reconfigure_cp_placed(wave)?;
            }
        }
        Ok(self.pool.stats().create_time_s - before)
    }

    /// Reconfigure the CP layout for a new micro-batch from degrees only:
    /// allocate ranks through the mesh, then acquire (pooled) groups.
    /// Retained for degree-level callers; the scheduling path goes
    /// through [`ParallelState::reconfigure_cp_placed`].
    ///
    /// Validates the paper's Cond. (6): Σ d_p ≤ N.
    pub fn reconfigure_cp(&mut self, degrees: &[usize]) -> Result<&[CommGroup]> {
        let total: usize = degrees.iter().sum();
        // Validate against the FREE budget: on a fragmented mesh the
        // allocator's own assert would otherwise turn this Result API's
        // error path into a panic.
        let available = self.mesh.free_replicas();
        if total > available {
            bail!(
                "plan requests {total} ranks but only {available} of the \
                 cluster's {} are free",
                self.mesh.replicas
            );
        }
        if degrees.iter().any(|&d| d == 0) {
            bail!("zero CP degree in plan");
        }
        let rank_sets = self.mesh.allocate(degrees);
        // Same co-liveness rule as the placed path: one wave's groups are
        // acquired atomically and never evict each other.
        self.current_cp = self.pool.acquire_wave_groups(
            rank_sets
                .into_iter()
                .map(|ranks| (GroupKind::ContextParallel, ranks)),
        );
        self.reconfigurations += 1;
        Ok(&self.current_cp)
    }

    /// The CP group a replica rank currently belongs to (idle ranks — the
    /// paper's implicit DP-only ranks — return None).
    pub fn cp_group_of(&self, rank: RankId) -> Option<&CommGroup> {
        self.current_cp.iter().find(|g| g.contains(rank))
    }

    /// Ranks not in any CP group this micro-batch (degree-1 DP workers in
    /// the paper's framing are degree-1 CP groups; truly idle ranks only
    /// occur when the plan under-subscribes the cluster).
    pub fn idle_ranks(&self) -> Vec<RankId> {
        (0..self.mesh.replicas)
            .filter(|&r| self.cp_group_of(r).is_none())
            .collect()
    }

    /// The CP groups of the current micro-batch, in plan order.
    pub fn current_cp_groups(&self) -> &[CommGroup] {
        &self.current_cp
    }

    /// Traffic statistics of the underlying group pool.
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// Read-only view of the underlying group pool (capacity, residency,
    /// byte accounting — for telemetry and tests).
    pub fn pool(&self) -> &GroupPool {
        &self.pool
    }

    /// Mutable access to the underlying group pool — the handle
    /// [`crate::session::DhpSession`] passes to the cluster simulator so
    /// the prewarm and the execution path charge ONE pool.
    pub fn pool_mut(&mut self) -> &mut GroupPool {
        &mut self.pool
    }

    /// Number of groups currently established in the pool.
    pub fn pool_size(&self) -> usize {
        self.pool.len()
    }

    /// Modeled communicator-buffer bytes the pool currently pins.
    pub fn pool_buffer_bytes(&self) -> u64 {
        self.pool.buffer_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn state() -> ParallelState {
        let cluster = ClusterConfig::default().with_npus(16); // 16 replicas
        ParallelState::new(DeviceMesh::new(&cluster), 1, 1)
    }

    #[test]
    fn reconfigure_covers_disjoint_ranks() {
        let mut st = state();
        let groups = st.reconfigure_cp(&[8, 4, 2, 1, 1]).unwrap();
        assert_eq!(groups.len(), 5);
        let mut seen = std::collections::HashSet::new();
        for g in groups {
            for &r in &g.ranks {
                assert!(seen.insert(r), "rank {r} in two groups");
            }
        }
        assert_eq!(seen.len(), 16);
        assert!(st.idle_ranks().is_empty());
    }

    #[test]
    fn under_subscription_leaves_idle_ranks() {
        let mut st = state();
        st.reconfigure_cp(&[4, 4]).unwrap();
        assert_eq!(st.idle_ranks().len(), 8);
    }

    #[test]
    fn over_subscription_rejected() {
        let mut st = state();
        assert!(st.reconfigure_cp(&[10, 8]).is_err());
        assert!(st.reconfigure_cp(&[4, 0]).is_err());
    }

    #[test]
    fn fragmented_mesh_over_subscription_errors_not_panics() {
        // 16 replicas, 6 pre-occupied: a 12-rank plan fits the cluster
        // total but not the free budget — the Result API must return Err
        // (not trip the allocator's assert).
        let cluster = ClusterConfig::default().with_npus(16);
        let mesh = DeviceMesh::new(&cluster).with_occupied(&[0, 1, 2, 3, 4, 5]);
        let mut st = ParallelState::new(mesh, 1, 1);
        assert!(st.reconfigure_cp(&[8, 4]).is_err());
        // A plan within the free budget still succeeds and avoids the
        // occupied ranks.
        let groups = st.reconfigure_cp(&[6, 4]).unwrap();
        for g in groups {
            for &r in &g.ranks {
                assert!(r >= 6, "occupied rank {r} acquired");
            }
        }
    }

    #[test]
    fn pool_reuse_across_reconfigurations() {
        let mut st = state();
        st.reconfigure_cp(&[8, 4, 4]).unwrap();
        let misses_first = st.pool_stats().misses;
        // Same shape again: all groups come from the pool.
        st.reconfigure_cp(&[8, 4, 4]).unwrap();
        assert_eq!(st.pool_stats().misses, misses_first);
        assert!(st.pool_stats().hits >= 3);
        assert_eq!(st.reconfigurations, 2);
    }

    #[test]
    fn rank_lookup() {
        let mut st = state();
        st.reconfigure_cp(&[8, 8]).unwrap();
        let g0 = st.cp_group_of(0).unwrap();
        assert_eq!(g0.degree(), 8);
        assert!(st.cp_group_of(15).is_some());
    }

    fn placed(groups: &[(usize, Vec<usize>)]) -> crate::scheduler::PlacedPlan {
        crate::scheduler::PlacedPlan {
            groups: groups
                .iter()
                .map(|(d, ranks)| crate::scheduler::PlacedGroup {
                    degree: *d,
                    seq_idxs: vec![],
                    agg: Default::default(),
                    est_time_s: 0.0,
                    ranks: ranks.clone(),
                    ring_bw: 1.0,
                })
                .collect(),
            est_makespan_s: 0.0,
            search_makespan_s: 0.0,
            replayed_groups: 0,
        }
    }

    #[test]
    fn placed_reconfigure_uses_exact_ranks_and_pools() {
        let mut st = state();
        let plan = placed(&[(2, vec![3, 9]), (1, vec![0])]);
        let groups = st.reconfigure_cp_placed(&plan).unwrap();
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].ranks, vec![3, 9]);
        let misses = st.pool_stats().misses;
        // Same placement again: all pool hits, no new groups.
        st.reconfigure_cp_placed(&plan).unwrap();
        assert_eq!(st.pool_stats().misses, misses);
        assert_eq!(st.reconfigurations, 2);
    }

    #[test]
    fn placed_reconfigure_keeps_whole_wave_under_tight_capacity() {
        // A pool cap below the wave size must not break the wave: all of
        // its groups stay resident (co-live), over-committing the budget.
        let cluster = ClusterConfig::default().with_npus(16);
        let mut st = ParallelState::new(DeviceMesh::new(&cluster), 1, 1)
            .with_pool_capacity(crate::parallel::PoolCapacity::MaxGroups(1));
        let plan = placed(&[(2, vec![0, 1]), (2, vec![2, 3]), (1, vec![4])]);
        let groups = st.reconfigure_cp_placed(&plan).unwrap();
        assert_eq!(groups.len(), 3);
        assert_eq!(st.pool_size(), 3, "wave must stay co-resident");
    }

    #[test]
    fn capped_prepare_warms_first_wave_last() {
        // Eviction-aware prewarm ordering: with a cap below the
        // schedule's working set, the FIRST wave's groups (needed
        // soonest) must be the LRU-warmest survivors; the last wave's
        // groups are the ones sacrificed.
        use crate::scheduler::Schedule;
        let cluster = ClusterConfig::default().with_npus(16);
        let mut st = ParallelState::new(DeviceMesh::new(&cluster), 1, 1)
            .with_pool_capacity(crate::parallel::PoolCapacity::MaxGroups(2));
        let schedule = Schedule {
            waves: vec![
                placed(&[(2, vec![0, 1]), (2, vec![2, 3])]),
                placed(&[(2, vec![4, 5]), (2, vec![6, 7])]),
            ],
            ..Default::default()
        };
        let paid = st.prepare_schedule(&schedule).unwrap();
        assert!(paid > 0.0, "cold pool must create groups");
        assert_eq!(st.pool_size(), 2);
        for ranks in [vec![0usize, 1], vec![2, 3]] {
            assert!(
                st.pool()
                    .get(crate::parallel::GroupKind::ContextParallel, &ranks)
                    .is_some(),
                "first wave's group {ranks:?} was evicted by the prewarm"
            );
        }
        // current_cp ends on the wave about to execute (wave 0).
        assert_eq!(st.current_cp_groups()[0].ranks, vec![0, 1]);
        // An unbounded pool keeps the historical execution-order warm:
        // current_cp ends on the LAST wave.
        let mut unbounded =
            ParallelState::new(DeviceMesh::new(&cluster), 1, 1);
        unbounded.prepare_schedule(&schedule).unwrap();
        assert_eq!(unbounded.pool_size(), 4);
        assert_eq!(unbounded.current_cp_groups()[0].ranks, vec![4, 5]);
    }

    #[test]
    fn placed_reconfigure_rejects_bad_placements() {
        let mut st = state();
        // Overlapping ranks within one wave.
        assert!(st
            .reconfigure_cp_placed(&placed(&[(2, vec![0, 1]), (2, vec![1, 2])]))
            .is_err());
        // Arity mismatch.
        assert!(st
            .reconfigure_cp_placed(&placed(&[(3, vec![0, 1])]))
            .is_err());
        // Out-of-range rank (16 replicas).
        assert!(st
            .reconfigure_cp_placed(&placed(&[(1, vec![16])]))
            .is_err());
    }
}
