//! Communication groups — the HCCL-group analogue the scheduler
//! (re)configures. Creation carries a realistic one-time cost, which is
//! what makes the [`super::pool`] worthwhile (paper §5: "creating new
//! HCCL communication groups on the fly for each batch would significantly
//! increase buffer overhead").

/// A model-replica rank (one complete TP×PP model copy).
pub type RankId = usize;

/// What a group is used for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum GroupKind {
    /// Ring context-parallel group (dynamically sized by DHP).
    ContextParallel,
    /// Data-parallel gradient synchronization group.
    DataParallel,
    /// Static tensor-parallel group (never reconfigured).
    TensorParallel,
    /// Static pipeline-parallel group (never reconfigured).
    PipelineParallel,
}

/// An established communication group over a set of ranks.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CommGroup {
    /// What this group is used for.
    pub kind: GroupKind,
    /// Member ranks, sorted (identity of the group).
    pub ranks: Vec<RankId>,
    /// Creation sequence number (diagnostics).
    pub serial: u64,
}

impl CommGroup {
    /// Canonical identity key: kind + sorted ranks.
    pub fn key(kind: GroupKind, mut ranks: Vec<RankId>) -> (GroupKind, Vec<RankId>) {
        ranks.sort_unstable();
        ranks.dedup();
        (kind, ranks)
    }

    /// Number of member ranks.
    pub fn degree(&self) -> usize {
        self.ranks.len()
    }

    /// Modeled device-buffer bytes this group pins while established,
    /// under the DEFAULT per-rank footprint (see [`group_buffer_bytes`]).
    /// The pool's byte accounting uses its own configured footprint
    /// ([`super::pool::GroupPool::buffer_bytes_per_rank`], threaded from
    /// [`crate::config::ClusterConfig::group_buffer_bytes`]), which may
    /// differ from this default.
    pub fn buffer_bytes(&self) -> u64 {
        group_buffer_bytes(self.degree())
    }

    /// Ring neighbours of `rank` inside this group: (prev, next).
    pub fn ring_neighbours(&self, rank: RankId) -> Option<(RankId, RankId)> {
        let idx = self.ranks.iter().position(|&r| r == rank)?;
        let n = self.ranks.len();
        Some((
            self.ranks[(idx + n - 1) % n],
            self.ranks[(idx + 1) % n],
        ))
    }

    /// Is `rank` a member of this group?
    pub fn contains(&self, rank: RankId) -> bool {
        self.ranks.binary_search(&rank).is_ok()
    }
}

/// Simulated HCCL group-creation cost in seconds (buffer registration +
/// rendezvous). Charged once per unique group; the pool amortizes it.
pub const GROUP_CREATE_COST_S: f64 = 0.030;

/// DEFAULT modeled per-member device-buffer footprint of an established
/// group, in bytes. Real HCCL communicators pin a per-device staging
/// buffer (`HCCL_BUFFSIZE`-style, tens of MB) for as long as the group
/// lives — this is the memory the paper's "buffer overhead" remark refers
/// to, and the unit the [`super::pool::PoolCapacity::BufferBytes`] budget
/// counts. It is a default, not a law of nature: clusters with a
/// different `HCCL_BUFFSIZE` override it per run via
/// [`crate::config::ClusterConfig::group_buffer_bytes`], which is
/// threaded to every budgeted pool
/// ([`super::pool::GroupPool::with_buffer_bytes_per_rank`]).
pub const GROUP_BUFFER_BYTES_PER_RANK: u64 = 64 * 1024 * 1024;

/// Modeled device-buffer bytes a group of `degree` members pins while it
/// stays established, under the default per-rank footprint: every member
/// rank holds one staging buffer.
pub const fn group_buffer_bytes(degree: usize) -> u64 {
    degree as u64 * GROUP_BUFFER_BYTES_PER_RANK
}

#[cfg(test)]
mod tests {
    use super::*;

    fn group(ranks: Vec<RankId>) -> CommGroup {
        let (kind, ranks) = CommGroup::key(GroupKind::ContextParallel, ranks);
        CommGroup {
            kind,
            ranks,
            serial: 0,
        }
    }

    #[test]
    fn key_canonicalizes() {
        let a = CommGroup::key(GroupKind::ContextParallel, vec![3, 1, 2]);
        let b = CommGroup::key(GroupKind::ContextParallel, vec![1, 2, 3, 3]);
        assert_eq!(a, b);
    }

    #[test]
    fn ring_neighbours_wrap() {
        let g = group(vec![2, 5, 9]);
        assert_eq!(g.ring_neighbours(2), Some((9, 5)));
        assert_eq!(g.ring_neighbours(5), Some((2, 9)));
        assert_eq!(g.ring_neighbours(9), Some((5, 2)));
        assert_eq!(g.ring_neighbours(7), None);
    }

    #[test]
    fn degree_and_contains() {
        let g = group(vec![0, 4, 8, 12]);
        assert_eq!(g.degree(), 4);
        assert!(g.contains(8));
        assert!(!g.contains(3));
    }

    #[test]
    fn singleton_ring_is_self_loop() {
        let g = group(vec![7]);
        assert_eq!(g.ring_neighbours(7), Some((7, 7)));
    }
}
