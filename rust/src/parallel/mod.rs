//! Parallel state management: communication groups, the group POOL
//! (paper §5 implementation detail 1, now capacity-bounded with LRU
//! eviction), the MPU-style parallel-state object DHP reconfigures per
//! micro-batch, and the device mesh mapping replica ranks to physical
//! nodes.

pub mod group;
pub mod mesh;
pub mod mpu;
pub mod pool;

pub use group::{CommGroup, GroupKind, RankId};
pub use mesh::DeviceMesh;
pub use mpu::ParallelState;
pub use pool::{GroupPool, PoolCapacity, PoolStats};
