//! The communication-group pool (paper §5, implementation detail 1):
//! groups are created once, cached, and reused across batches. "In
//! practice, the total number of unique groups required is limited, and
//! the creation overhead becomes negligible over long training runs."
//!
//! The seed pool grew without bound, which silently assumed that claim.
//! Real HCCL communicators pin device buffer memory for as long as they
//! live ([`super::group::group_buffer_bytes`]), so a production system must budget the
//! pool: [`GroupPool`] therefore takes a [`PoolCapacity`] — a group-count
//! cap or a modeled buffer-byte budget — and evicts least-recently-used
//! groups when [`GroupPool::acquire`]/[`GroupPool::prewarm`] would exceed
//! it. Re-creating an evicted group is charged the full creation cost
//! again (and counted in [`PoolStats::evicted_recreations`]), which is
//! what makes the "near-free reconfiguration" claim falsifiable: cap the
//! pool below the workload's working set and the cost comes back.
//!
//! # Acquire/evict lifecycle
//!
//! ```
//! use dhp::parallel::group::GroupKind;
//! use dhp::parallel::pool::{GroupPool, PoolCapacity};
//!
//! let mut pool = GroupPool::with_capacity(PoolCapacity::MaxGroups(2));
//! pool.acquire(GroupKind::ContextParallel, vec![0, 1]); // miss: created
//! pool.acquire(GroupKind::ContextParallel, vec![2, 3]); // miss: created
//! pool.acquire(GroupKind::ContextParallel, vec![0, 1]); // hit: refreshes LRU order
//! assert_eq!(pool.stats().hits, 1);
//!
//! // A third group exceeds the cap: the coldest group ([2,3]) is evicted.
//! pool.acquire(GroupKind::ContextParallel, vec![4, 5]);
//! assert_eq!(pool.len(), 2);
//! assert_eq!(pool.stats().evictions, 1);
//!
//! // Re-acquiring the evicted group is an honest re-creation: a fresh
//! // miss that pays the full creation cost again.
//! pool.acquire(GroupKind::ContextParallel, vec![2, 3]);
//! assert_eq!(pool.stats().misses, 4);
//! assert_eq!(pool.stats().evicted_recreations, 1);
//! ```

use std::collections::{HashMap, HashSet};

use super::group::{CommGroup, GroupKind, RankId, GROUP_CREATE_COST_S};

/// Capacity budget of a [`GroupPool`] — how much communicator state the
/// device can afford to keep established at once.
///
/// The group being acquired is always admitted (it is in active use);
/// eviction only removes *other* groups. A budget smaller than a single
/// group therefore degrades the pool to pass-through (every acquire is a
/// miss) rather than failing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolCapacity {
    /// No cap: the seed behavior, kept as the default. Honest only when
    /// the workload's unique-group working set is genuinely small.
    Unbounded,
    /// At most this many groups may stay established.
    MaxGroups(usize),
    /// Modeled device-buffer budget in bytes: the sum of
    /// [`super::group::group_buffer_bytes`]-modeled bytes (at the pool's
    /// configured per-rank footprint) over all established groups must
    /// stay at or under this budget.
    BufferBytes(u64),
}

impl Default for PoolCapacity {
    fn default() -> Self {
        PoolCapacity::Unbounded
    }
}

impl PoolCapacity {
    /// Does a pool holding `groups` groups totalling `bytes` modeled
    /// buffer bytes fit this budget?
    pub fn admits(&self, groups: usize, bytes: u64) -> bool {
        match *self {
            PoolCapacity::Unbounded => true,
            PoolCapacity::MaxGroups(cap) => groups <= cap,
            PoolCapacity::BufferBytes(budget) => bytes <= budget,
        }
    }
}

/// Pool statistics (reported by Table-4-style case studies, the Tables
/// 1–2 overhead columns, and the scalability benches).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PoolStats {
    /// Acquires resolved by an already-established group.
    pub hits: u64,
    /// Acquires that had to create (or re-create) a group.
    pub misses: u64,
    /// Total simulated seconds spent creating groups.
    pub create_time_s: f64,
    /// Groups evicted to stay within the [`PoolCapacity`] budget.
    pub evictions: u64,
    /// Misses that re-created a group the pool had previously evicted —
    /// the capacity-thrash signal: a high count means the budget is below
    /// the workload's working set.
    pub evicted_recreations: u64,
}

impl PoolStats {
    /// Fraction of acquires served from the pool (0 when no traffic).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// One established group plus its LRU bookkeeping.
#[derive(Debug)]
struct Entry {
    group: CommGroup,
    /// Logical acquire-clock timestamp of the last touch. Strictly
    /// increasing across acquires, so LRU victim selection is
    /// deterministic regardless of hash-map iteration order.
    last_used: u64,
}

/// Cache of established communication groups keyed by (kind, ranks),
/// bounded by a [`PoolCapacity`] with least-recently-used eviction.
///
/// See the [module docs](self) for the acquire/evict lifecycle.
#[derive(Debug)]
pub struct GroupPool {
    groups: HashMap<(GroupKind, Vec<RankId>), Entry>,
    capacity: PoolCapacity,
    stats: PoolStats,
    next_serial: u64,
    clock: u64,
    /// Modeled buffer bytes currently pinned by established groups.
    buffer_bytes: u64,
    /// Modeled per-member-rank communicator buffer footprint used by the
    /// byte accounting (defaults to
    /// [`super::group::GROUP_BUFFER_BYTES_PER_RANK`]; clusters override
    /// it via [`crate::config::ClusterConfig::group_buffer_bytes`]).
    bytes_per_rank: u64,
    /// Identity of every group ever evicted, so re-creations can be
    /// counted (stats metadata only — no buffers are modeled for it).
    evicted: HashSet<(GroupKind, Vec<RankId>)>,
    /// Keys protected from eviction for the duration of one
    /// [`GroupPool::acquire_wave`] call (a wave's groups are co-live on
    /// the device and must never evict each other). Empty outside it.
    pinned: HashSet<(GroupKind, Vec<RankId>)>,
    /// While set, an acquire that finds its group resident refreshes the
    /// LRU position WITHOUT counting a hit (see
    /// [`GroupPool::set_passive_hits`]). Misses always count.
    passive_hits: bool,
}

impl Default for GroupPool {
    fn default() -> Self {
        GroupPool {
            groups: HashMap::new(),
            capacity: PoolCapacity::Unbounded,
            stats: PoolStats::default(),
            next_serial: 0,
            clock: 0,
            buffer_bytes: 0,
            bytes_per_rank: super::group::GROUP_BUFFER_BYTES_PER_RANK,
            evicted: HashSet::new(),
            pinned: HashSet::new(),
            passive_hits: false,
        }
    }
}

impl GroupPool {
    /// An unbounded pool (the seed behavior).
    pub fn new() -> Self {
        Self::default()
    }

    /// A pool bounded by `capacity` (LRU eviction on overflow).
    pub fn with_capacity(capacity: PoolCapacity) -> Self {
        GroupPool {
            capacity,
            ..Self::default()
        }
    }

    /// The configured capacity budget.
    pub fn capacity(&self) -> PoolCapacity {
        self.capacity
    }

    /// Override the modeled per-member-rank communicator buffer size the
    /// byte accounting charges (builder form of
    /// [`GroupPool::set_buffer_bytes_per_rank`]).
    pub fn with_buffer_bytes_per_rank(mut self, bytes: u64) -> Self {
        self.set_buffer_bytes_per_rank(bytes);
        self
    }

    /// Re-model the per-member-rank buffer footprint: resident groups are
    /// re-accounted under the new size and the capacity budget is
    /// re-enforced immediately (a larger footprint can push a
    /// [`PoolCapacity::BufferBytes`] pool over budget).
    pub fn set_buffer_bytes_per_rank(&mut self, bytes: u64) {
        self.bytes_per_rank = bytes;
        self.buffer_bytes = self
            .groups
            .values()
            .map(|e| e.group.degree() as u64 * bytes)
            .sum();
        self.enforce_capacity(None);
    }

    /// The modeled per-member-rank buffer footprint in effect.
    pub fn buffer_bytes_per_rank(&self) -> u64 {
        self.bytes_per_rank
    }

    /// Modeled buffer bytes a group of `degree` members pins under this
    /// pool's per-rank footprint.
    fn group_bytes(&self, degree: usize) -> u64 {
        degree as u64 * self.bytes_per_rank
    }

    /// Re-budget the pool, immediately evicting LRU groups until the new
    /// capacity is satisfied (a zero budget empties the pool — nothing is
    /// in active use during a re-budget, so no group is protected).
    pub fn set_capacity(&mut self, capacity: PoolCapacity) {
        self.capacity = capacity;
        self.enforce_capacity(None);
    }

    /// Fetch-or-create a group. A pool hit is free and refreshes the
    /// group's LRU position; a miss pays the (simulated) HCCL creation
    /// cost, registers the group, and evicts least-recently-used groups
    /// as needed to stay within the capacity budget. The acquired group
    /// itself is never evicted by its own admission.
    pub fn acquire(&mut self, kind: GroupKind, ranks: Vec<RankId>) -> &CommGroup {
        let key = CommGroup::key(kind, ranks);
        self.clock += 1;
        if let Some(entry) = self.groups.get_mut(&key) {
            entry.last_used = self.clock;
            if !self.passive_hits {
                self.stats.hits += 1;
            }
        } else {
            self.stats.misses += 1;
            self.stats.create_time_s += GROUP_CREATE_COST_S;
            if self.evicted.contains(&key) {
                self.stats.evicted_recreations += 1;
            }
            let serial = self.next_serial;
            self.next_serial += 1;
            let group = CommGroup {
                kind: key.0,
                ranks: key.1.clone(),
                serial,
            };
            self.buffer_bytes += self.group_bytes(group.degree());
            self.groups.insert(
                key.clone(),
                Entry {
                    group,
                    last_used: self.clock,
                },
            );
            self.enforce_capacity(Some(&key));
        }
        &self.groups.get(&key).unwrap().group
    }

    /// Fetch-or-create every group of ONE wave, guaranteeing the wave's
    /// groups coexist: the groups of a wave are all live on the device at
    /// once, so none of them may evict another (only groups outside the
    /// wave are eviction victims). If the wave alone exceeds the budget
    /// the pool over-commits for the wave's duration — that over-commit
    /// is exactly the signal that the budget cannot actually run this
    /// schedule. Returns the simulated creation seconds paid.
    pub fn acquire_wave<I>(&mut self, keys: I) -> f64
    where
        I: IntoIterator<Item = (GroupKind, Vec<RankId>)>,
    {
        let before = self.stats.create_time_s;
        let canon: Vec<(GroupKind, Vec<RankId>)> = keys
            .into_iter()
            .map(|(kind, ranks)| CommGroup::key(kind, ranks))
            .collect();
        self.pinned = canon.iter().cloned().collect();
        for (kind, ranks) in canon {
            self.acquire(kind, ranks);
        }
        self.pinned.clear();
        self.stats.create_time_s - before
    }

    /// [`GroupPool::acquire_wave`] returning the wave's established
    /// groups (cloned, in key order) in the same pass — the form
    /// executors use to install a wave as their current parallel state
    /// without a second key-derivation round-trip.
    pub fn acquire_wave_groups<I>(&mut self, keys: I) -> Vec<CommGroup>
    where
        I: IntoIterator<Item = (GroupKind, Vec<RankId>)>,
    {
        let canon: Vec<(GroupKind, Vec<RankId>)> = keys
            .into_iter()
            .map(|(kind, ranks)| CommGroup::key(kind, ranks))
            .collect();
        self.pinned = canon.iter().cloned().collect();
        let mut out = Vec::with_capacity(canon.len());
        for (kind, ranks) in canon {
            out.push(self.acquire(kind, ranks).clone());
        }
        self.pinned.clear();
        out
    }

    /// The established group for a key, if resident (wave callers use
    /// this after [`GroupPool::acquire_wave`], whose pinning guarantees
    /// residency for every key of the wave).
    pub fn get(&self, kind: GroupKind, ranks: &[RankId]) -> Option<&CommGroup> {
        let key = CommGroup::key(kind, ranks.to_vec());
        self.groups.get(&key).map(|e| &e.group)
    }

    /// Evict LRU groups until the capacity budget holds. `protect` (the
    /// group just acquired) and the pinned wave keys are never victims;
    /// if they alone exceed the budget the pool transiently over-commits
    /// rather than evicting groups in active use.
    fn enforce_capacity(&mut self, protect: Option<&(GroupKind, Vec<RankId>)>) {
        while !self.capacity.admits(self.groups.len(), self.buffer_bytes) {
            let victim = self
                .groups
                .iter()
                .filter(|(k, _)| Some(*k) != protect && !self.pinned.contains(*k))
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(key) => {
                    let entry = self.groups.remove(&key).unwrap();
                    self.buffer_bytes -= self.group_bytes(entry.group.degree());
                    self.stats.evictions += 1;
                    self.evicted.insert(key);
                }
                None => break, // only in-use (protected/pinned) groups remain
            }
        }
    }

    /// Pre-create groups at training start (the paper's warm pool). The
    /// capacity budget applies here too: prewarming more than the budget
    /// holds establishes only the most recently warmed groups.
    pub fn prewarm<I>(&mut self, entries: I)
    where
        I: IntoIterator<Item = (GroupKind, Vec<RankId>)>,
    {
        for (kind, ranks) in entries {
            self.acquire(kind, ranks);
        }
        // Prewarming should not count as runtime traffic — neither the
        // hit/miss counters nor the creation-time charge (prewarmed pools
        // report zero runtime creation cost).
        self.reset_stats();
    }

    /// Toggle passive-hit mode, for an EXECUTION phase that re-touches
    /// groups its prepare phase already acquired: while set, an acquire
    /// that finds the group resident refreshes its LRU position without
    /// counting a hit, so pool traffic reflects ONE acquisition per
    /// group per step (the prepare) and hit-rates stay comparable with a
    /// prepare-less system. Misses still count fully — a group evicted
    /// between prepare and execution is an honest, charged re-creation.
    /// Used by [`crate::session::DhpSession`] around simulator execution.
    pub fn set_passive_hits(&mut self, passive: bool) {
        self.passive_hits = passive;
    }

    /// Tear down every established group whose rank set intersects
    /// `ranks`. The session calls this when a mesh event surrenders
    /// ranks to a concurrent job: a communicator spanning a rank this
    /// job no longer owns is invalid, so its modeled buffers are
    /// released immediately instead of lingering as phantom footprint.
    /// Deliberately NOT counted as capacity evictions (and not
    /// remembered for `evicted_recreations`): re-establishing such a
    /// group later is a plain miss, not capacity thrash. Returns the
    /// number of groups torn down.
    pub fn invalidate_ranks(&mut self, ranks: &[RankId]) -> usize {
        let doomed: Vec<(GroupKind, Vec<RankId>)> = self
            .groups
            .keys()
            .filter(|(_, members)| members.iter().any(|m| ranks.contains(m)))
            .cloned()
            .collect();
        for key in &doomed {
            let entry = self.groups.remove(key).unwrap();
            self.buffer_bytes -= self.group_bytes(entry.group.degree());
        }
        doomed.len()
    }

    /// Zero the traffic counters while keeping the cached groups (for
    /// windowed hit-rate measurements, e.g. "after a 10-step warmup").
    /// The evicted-identity memory is cleared too, so a window's
    /// `evicted_recreations` only counts re-creations of groups evicted
    /// WITHIN that window — recreations never exceed evictions in any
    /// windowed report.
    pub fn reset_stats(&mut self) {
        self.stats = PoolStats::default();
        self.evicted.clear();
    }

    /// Number of currently established groups (pool occupancy).
    pub fn len(&self) -> usize {
        self.groups.len()
    }

    /// Is the pool empty?
    pub fn is_empty(&self) -> bool {
        self.groups.is_empty()
    }

    /// Modeled device-buffer bytes currently pinned by the established
    /// groups (degree × per-rank footprint, summed over the pool).
    pub fn buffer_bytes(&self) -> u64 {
        self.buffer_bytes
    }

    /// Traffic counters since the last [`GroupPool::reset_stats`].
    pub fn stats(&self) -> PoolStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::group::GROUP_BUFFER_BYTES_PER_RANK;

    #[test]
    fn second_acquire_is_a_hit() {
        let mut pool = GroupPool::new();
        pool.acquire(GroupKind::ContextParallel, vec![0, 1, 2]);
        pool.acquire(GroupKind::ContextParallel, vec![2, 1, 0]); // same set
        assert_eq!(pool.stats().misses, 1);
        assert_eq!(pool.stats().hits, 1);
        assert_eq!(pool.len(), 1);
    }

    #[test]
    fn kind_distinguishes_groups() {
        let mut pool = GroupPool::new();
        pool.acquire(GroupKind::ContextParallel, vec![0, 1]);
        pool.acquire(GroupKind::DataParallel, vec![0, 1]);
        assert_eq!(pool.len(), 2);
        assert_eq!(pool.stats().misses, 2);
    }

    #[test]
    fn create_cost_accounted_once() {
        let mut pool = GroupPool::new();
        for _ in 0..10 {
            pool.acquire(GroupKind::ContextParallel, vec![0, 1, 2, 3]);
        }
        assert!((pool.stats().create_time_s - GROUP_CREATE_COST_S).abs() < 1e-12);
        assert!((pool.stats().hit_rate() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn prewarm_resets_counters() {
        let mut pool = GroupPool::new();
        pool.prewarm([
            (GroupKind::ContextParallel, vec![0, 1]),
            (GroupKind::ContextParallel, vec![2, 3]),
        ]);
        assert_eq!(pool.len(), 2);
        assert_eq!(pool.stats().hits + pool.stats().misses, 0);
        assert_eq!(
            pool.stats().create_time_s,
            0.0,
            "prewarmed pools must report zero runtime creation cost"
        );
        pool.acquire(GroupKind::ContextParallel, vec![0, 1]);
        assert_eq!(pool.stats().hits, 1);
        assert_eq!(pool.stats().create_time_s, 0.0);
    }

    #[test]
    fn reset_stats_keeps_groups() {
        let mut pool = GroupPool::new();
        pool.acquire(GroupKind::ContextParallel, vec![0, 1]);
        pool.reset_stats();
        assert_eq!(pool.len(), 1);
        assert_eq!(pool.stats(), PoolStats::default());
        pool.acquire(GroupKind::ContextParallel, vec![0, 1]);
        assert_eq!(pool.stats().hits, 1);
        assert_eq!(pool.stats().misses, 0);
    }

    #[test]
    fn reset_stats_starts_a_self_consistent_window() {
        // A window never reports recreations of evictions it didn't see:
        // after reset_stats, re-creating a pre-window-evicted group is a
        // plain miss, not an evicted_recreation.
        let mut pool = GroupPool::with_capacity(PoolCapacity::MaxGroups(1));
        pool.acquire(GroupKind::ContextParallel, vec![0, 1]);
        pool.acquire(GroupKind::ContextParallel, vec![2, 3]); // evicts [0,1]
        pool.reset_stats();
        pool.acquire(GroupKind::ContextParallel, vec![0, 1]); // re-creates
        let s = pool.stats();
        assert_eq!(s.evicted_recreations, 0);
        assert_eq!(s.misses, 1);
        assert!(
            s.evicted_recreations <= s.evictions + s.misses,
            "windowed thrash counters must be self-consistent"
        );
    }

    #[test]
    fn serials_are_unique() {
        let mut pool = GroupPool::new();
        let s1 = pool.acquire(GroupKind::ContextParallel, vec![0]).serial;
        let s2 = pool.acquire(GroupKind::ContextParallel, vec![1]).serial;
        assert_ne!(s1, s2);
    }

    #[test]
    fn unbounded_pool_never_evicts() {
        let mut pool = GroupPool::new();
        for i in 0..100usize {
            pool.acquire(GroupKind::ContextParallel, vec![i, i + 100]);
        }
        assert_eq!(pool.len(), 100);
        assert_eq!(pool.stats().evictions, 0);
        assert_eq!(pool.stats().evicted_recreations, 0);
    }

    #[test]
    fn lru_evicts_coldest_group_first() {
        let mut pool = GroupPool::with_capacity(PoolCapacity::MaxGroups(2));
        pool.acquire(GroupKind::ContextParallel, vec![0, 1]);
        pool.acquire(GroupKind::ContextParallel, vec![2, 3]);
        // Touch [0,1]: [2,3] becomes the LRU victim.
        pool.acquire(GroupKind::ContextParallel, vec![0, 1]);
        pool.acquire(GroupKind::ContextParallel, vec![4, 5]);
        assert_eq!(pool.len(), 2);
        assert_eq!(pool.stats().evictions, 1);
        // [0,1] survived, [2,3] did not.
        pool.acquire(GroupKind::ContextParallel, vec![0, 1]);
        assert_eq!(pool.stats().misses, 3);
        pool.acquire(GroupKind::ContextParallel, vec![2, 3]);
        assert_eq!(pool.stats().misses, 4);
        assert_eq!(pool.stats().evicted_recreations, 1);
    }

    #[test]
    fn recreation_of_evicted_group_pays_full_cost() {
        let mut pool = GroupPool::with_capacity(PoolCapacity::MaxGroups(1));
        pool.acquire(GroupKind::ContextParallel, vec![0, 1]);
        pool.acquire(GroupKind::ContextParallel, vec![2, 3]); // evicts [0,1]
        pool.acquire(GroupKind::ContextParallel, vec![0, 1]); // re-creates
        assert_eq!(pool.stats().misses, 3);
        assert_eq!(pool.stats().evictions, 2);
        assert_eq!(pool.stats().evicted_recreations, 1);
        assert!(
            (pool.stats().create_time_s - 3.0 * GROUP_CREATE_COST_S).abs() < 1e-12,
            "every re-creation must be charged honestly"
        );
    }

    #[test]
    fn buffer_budget_counts_modeled_bytes() {
        // Budget fits exactly two degree-2 groups.
        let budget = 4 * GROUP_BUFFER_BYTES_PER_RANK;
        let mut pool = GroupPool::with_capacity(PoolCapacity::BufferBytes(budget));
        pool.acquire(GroupKind::ContextParallel, vec![0, 1]);
        pool.acquire(GroupKind::ContextParallel, vec![2, 3]);
        assert_eq!(pool.buffer_bytes(), budget);
        assert_eq!(pool.stats().evictions, 0);
        // A degree-4 group alone fills the budget: both residents evicted.
        pool.acquire(GroupKind::ContextParallel, vec![4, 5, 6, 7]);
        assert_eq!(pool.len(), 1);
        assert_eq!(pool.buffer_bytes(), budget);
        assert_eq!(pool.stats().evictions, 2);
    }

    #[test]
    fn configurable_buffer_footprint_drives_byte_accounting() {
        // A cluster-configured per-rank footprint replaces the 64 MB
        // constant in every byte computation: occupancy accounting AND
        // BufferBytes budget enforcement.
        let per_rank = 8 * 1024 * 1024u64; // 8 MB ranks
        let mut pool = GroupPool::with_capacity(PoolCapacity::BufferBytes(
            4 * per_rank,
        ))
        .with_buffer_bytes_per_rank(per_rank);
        assert_eq!(pool.buffer_bytes_per_rank(), per_rank);
        pool.acquire(GroupKind::ContextParallel, vec![0, 1]);
        pool.acquire(GroupKind::ContextParallel, vec![2, 3]);
        assert_eq!(pool.buffer_bytes(), 4 * per_rank);
        assert_eq!(pool.stats().evictions, 0, "fits under the 8 MB model");
        // Under the default 64 MB model the same budget holds nothing:
        // re-modeling the footprint re-enforces the budget immediately.
        pool.set_buffer_bytes_per_rank(GROUP_BUFFER_BYTES_PER_RANK);
        assert!(pool.len() < 2, "re-modeled footprint must evict down");
        assert!(pool.stats().evictions >= 1);
    }

    #[test]
    fn acquired_group_is_never_its_own_victim() {
        // A single group larger than the whole budget is still admitted
        // (it is in active use); the pool transiently over-commits.
        let mut pool = GroupPool::with_capacity(PoolCapacity::BufferBytes(
            GROUP_BUFFER_BYTES_PER_RANK,
        ));
        let g = pool.acquire(GroupKind::ContextParallel, vec![0, 1, 2]);
        assert_eq!(g.ranks, vec![0, 1, 2]);
        assert_eq!(pool.len(), 1);
        assert!(pool.buffer_bytes() > GROUP_BUFFER_BYTES_PER_RANK);
    }

    #[test]
    fn set_capacity_evicts_down() {
        let mut pool = GroupPool::new();
        for i in 0..6usize {
            pool.acquire(GroupKind::ContextParallel, vec![2 * i, 2 * i + 1]);
        }
        pool.set_capacity(PoolCapacity::MaxGroups(2));
        assert_eq!(pool.len(), 2);
        assert_eq!(pool.stats().evictions, 4);
        // The two most recently used groups survive.
        pool.acquire(GroupKind::ContextParallel, vec![8, 9]);
        pool.acquire(GroupKind::ContextParallel, vec![10, 11]);
        assert_eq!(pool.stats().hits, 2);
    }

    #[test]
    fn wave_acquire_never_evicts_co_live_groups() {
        // Groups of one wave are simultaneously live on the device: under
        // a cap smaller than the wave, the wave's groups must evict only
        // OUTSIDE groups and over-commit for the rest — never each other.
        let mut pool = GroupPool::with_capacity(PoolCapacity::MaxGroups(2));
        pool.acquire(GroupKind::ContextParallel, vec![0, 1]);
        pool.acquire(GroupKind::ContextParallel, vec![2, 3]);
        let paid = pool.acquire_wave([
            (GroupKind::ContextParallel, vec![4, 5]),
            (GroupKind::ContextParallel, vec![6, 7]),
            (GroupKind::ContextParallel, vec![8, 9]),
        ]);
        assert!((paid - 3.0 * GROUP_CREATE_COST_S).abs() < 1e-12);
        // Both outside residents were evicted; the wave over-commits.
        assert_eq!(pool.stats().evictions, 2);
        assert_eq!(pool.len(), 3, "the whole wave must stay resident");
        for ranks in [vec![4, 5], vec![6, 7], vec![8, 9]] {
            assert!(
                pool.get(GroupKind::ContextParallel, &ranks).is_some(),
                "wave group {ranks:?} was evicted by its own wave"
            );
        }
        // The next non-wave acquire shrinks the pool back under the cap.
        pool.acquire(GroupKind::ContextParallel, vec![10, 11]);
        assert_eq!(pool.len(), 2);
    }

    #[test]
    fn wave_acquire_hits_warm_groups_for_free() {
        let mut pool = GroupPool::new();
        pool.prewarm([
            (GroupKind::ContextParallel, vec![0, 1]),
            (GroupKind::ContextParallel, vec![2, 3]),
        ]);
        let paid = pool.acquire_wave([
            (GroupKind::ContextParallel, vec![1, 0]), // same set, warm
            (GroupKind::ContextParallel, vec![2, 3]),
        ]);
        assert_eq!(paid, 0.0);
        assert_eq!(pool.stats().hits, 2);
    }

    #[test]
    fn passive_hits_refresh_lru_without_counting() {
        let mut pool = GroupPool::with_capacity(PoolCapacity::MaxGroups(2));
        pool.acquire(GroupKind::ContextParallel, vec![0, 1]);
        pool.acquire(GroupKind::ContextParallel, vec![2, 3]);
        pool.set_passive_hits(true);
        pool.acquire(GroupKind::ContextParallel, vec![0, 1]); // silent re-touch
        assert_eq!(pool.stats().hits, 0, "passive re-touch must not count");
        assert_eq!(pool.stats().misses, 2);
        pool.set_passive_hits(false);
        // …but the LRU refresh was real: [2,3] is now the victim.
        pool.acquire(GroupKind::ContextParallel, vec![4, 5]);
        assert!(pool.get(GroupKind::ContextParallel, &[0, 1]).is_some());
        assert!(pool.get(GroupKind::ContextParallel, &[2, 3]).is_none());
        // A passive-mode MISS still counts and still pays creation.
        let mut p2 = GroupPool::new();
        p2.set_passive_hits(true);
        p2.acquire(GroupKind::ContextParallel, vec![7, 8]);
        assert_eq!(p2.stats().misses, 1);
        assert!(p2.stats().create_time_s > 0.0);
    }

    #[test]
    fn invalidate_ranks_tears_down_intersecting_groups_only() {
        let mut pool = GroupPool::new();
        pool.acquire(GroupKind::ContextParallel, vec![0, 1]);
        pool.acquire(GroupKind::ContextParallel, vec![2, 3]);
        pool.acquire(GroupKind::DataParallel, vec![1, 2]);
        let bytes_before = pool.buffer_bytes();
        let torn = pool.invalidate_ranks(&[1]);
        assert_eq!(torn, 2, "[0,1] and [1,2] span the surrendered rank");
        assert_eq!(pool.len(), 1);
        assert!(pool.get(GroupKind::ContextParallel, &[2, 3]).is_some());
        assert!(pool.buffer_bytes() < bytes_before);
        // Invalidation is not capacity thrash: no evictions recorded,
        // and re-establishing the group later is a plain miss.
        assert_eq!(pool.stats().evictions, 0);
        pool.acquire(GroupKind::ContextParallel, vec![0, 1]);
        assert_eq!(pool.stats().evicted_recreations, 0);
    }

    #[test]
    fn prewarm_respects_capacity() {
        let mut pool = GroupPool::with_capacity(PoolCapacity::MaxGroups(2));
        pool.prewarm([
            (GroupKind::ContextParallel, vec![0, 1]),
            (GroupKind::ContextParallel, vec![2, 3]),
            (GroupKind::ContextParallel, vec![4, 5]),
        ]);
        assert_eq!(pool.len(), 2);
        // Stats (including prewarm evictions) are reset: not runtime
        // traffic.
        assert_eq!(pool.stats(), PoolStats::default());
        // The most recently warmed groups are the residents.
        pool.acquire(GroupKind::ContextParallel, vec![2, 3]);
        pool.acquire(GroupKind::ContextParallel, vec![4, 5]);
        assert_eq!(pool.stats().hits, 2);
        assert_eq!(pool.stats().misses, 0);
    }
}
