//! The communication-group pool (paper §5, implementation detail 1):
//! groups are created once, cached, and reused across batches. "In
//! practice, the total number of unique groups required is limited, and
//! the creation overhead becomes negligible over long training runs."

use std::collections::HashMap;

use super::group::{CommGroup, GroupKind, RankId, GROUP_CREATE_COST_S};

/// Pool statistics (reported by Table-4-style case studies and the
/// scalability benches).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PoolStats {
    pub hits: u64,
    pub misses: u64,
    /// Total simulated seconds spent creating groups.
    pub create_time_s: f64,
}

impl PoolStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Cache of established communication groups keyed by (kind, ranks).
#[derive(Debug, Default)]
pub struct GroupPool {
    groups: HashMap<(GroupKind, Vec<RankId>), CommGroup>,
    stats: PoolStats,
    next_serial: u64,
}

impl GroupPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch-or-create a group. A pool hit is free; a miss pays the
    /// (simulated) HCCL creation cost and registers the group.
    pub fn acquire(&mut self, kind: GroupKind, ranks: Vec<RankId>) -> &CommGroup {
        let key = CommGroup::key(kind, ranks);
        if self.groups.contains_key(&key) {
            self.stats.hits += 1;
        } else {
            self.stats.misses += 1;
            self.stats.create_time_s += GROUP_CREATE_COST_S;
            let serial = self.next_serial;
            self.next_serial += 1;
            let group = CommGroup {
                kind: key.0,
                ranks: key.1.clone(),
                serial,
            };
            self.groups.insert(key.clone(), group);
        }
        self.groups.get(&key).unwrap()
    }

    /// Pre-create groups at training start (the paper's warm pool).
    pub fn prewarm<I>(&mut self, entries: I)
    where
        I: IntoIterator<Item = (GroupKind, Vec<RankId>)>,
    {
        for (kind, ranks) in entries {
            self.acquire(kind, ranks);
        }
        // Prewarming should not count as runtime traffic — neither the
        // hit/miss counters nor the creation-time charge (prewarmed pools
        // report zero runtime creation cost).
        self.reset_stats();
    }

    /// Zero the traffic counters while keeping the cached groups (for
    /// windowed hit-rate measurements, e.g. "after a 10-step warmup").
    pub fn reset_stats(&mut self) {
        self.stats = PoolStats::default();
    }

    pub fn len(&self) -> usize {
        self.groups.len()
    }

    pub fn is_empty(&self) -> bool {
        self.groups.is_empty()
    }

    pub fn stats(&self) -> PoolStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn second_acquire_is_a_hit() {
        let mut pool = GroupPool::new();
        pool.acquire(GroupKind::ContextParallel, vec![0, 1, 2]);
        pool.acquire(GroupKind::ContextParallel, vec![2, 1, 0]); // same set
        assert_eq!(pool.stats().misses, 1);
        assert_eq!(pool.stats().hits, 1);
        assert_eq!(pool.len(), 1);
    }

    #[test]
    fn kind_distinguishes_groups() {
        let mut pool = GroupPool::new();
        pool.acquire(GroupKind::ContextParallel, vec![0, 1]);
        pool.acquire(GroupKind::DataParallel, vec![0, 1]);
        assert_eq!(pool.len(), 2);
        assert_eq!(pool.stats().misses, 2);
    }

    #[test]
    fn create_cost_accounted_once() {
        let mut pool = GroupPool::new();
        for _ in 0..10 {
            pool.acquire(GroupKind::ContextParallel, vec![0, 1, 2, 3]);
        }
        assert!((pool.stats().create_time_s - GROUP_CREATE_COST_S).abs() < 1e-12);
        assert!((pool.stats().hit_rate() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn prewarm_resets_counters() {
        let mut pool = GroupPool::new();
        pool.prewarm([
            (GroupKind::ContextParallel, vec![0, 1]),
            (GroupKind::ContextParallel, vec![2, 3]),
        ]);
        assert_eq!(pool.len(), 2);
        assert_eq!(pool.stats().hits + pool.stats().misses, 0);
        assert_eq!(
            pool.stats().create_time_s,
            0.0,
            "prewarmed pools must report zero runtime creation cost"
        );
        pool.acquire(GroupKind::ContextParallel, vec![0, 1]);
        assert_eq!(pool.stats().hits, 1);
        assert_eq!(pool.stats().create_time_s, 0.0);
    }

    #[test]
    fn reset_stats_keeps_groups() {
        let mut pool = GroupPool::new();
        pool.acquire(GroupKind::ContextParallel, vec![0, 1]);
        pool.reset_stats();
        assert_eq!(pool.len(), 1);
        assert_eq!(pool.stats(), PoolStats::default());
        pool.acquire(GroupKind::ContextParallel, vec![0, 1]);
        assert_eq!(pool.stats().hits, 1);
        assert_eq!(pool.stats().misses, 0);
    }

    #[test]
    fn serials_are_unique() {
        let mut pool = GroupPool::new();
        let s1 = pool.acquire(GroupKind::ContextParallel, vec![0]).serial;
        let s2 = pool.acquire(GroupKind::ContextParallel, vec![1]).serial;
        assert_ne!(s1, s2);
    }
}
