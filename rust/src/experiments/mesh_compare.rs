//! Fig. 2 — static mesh vs dynamic mesh: on one skewed micro-batch, show
//! the static grid's idle gaps / synchronization stalls vs DHP's adaptive
//! groups.

use anyhow::Result;

use crate::baselines::{MegatronStaticCp, SchedulePolicy};
use crate::cluster::CommKind;
use crate::config::presets::by_name;
use crate::config::TrainStage;
use crate::data::datasets::DatasetKind;
use crate::report::Table;
use crate::util::cli::Args;

use super::harness::ExpContext;

/// One Fig. 2 row: a policy's makespan/idle profile on one batch.
#[derive(Debug, Clone)]
pub struct MeshRow {
    /// Policy display name.
    pub policy: String,
    /// Simulated batch makespan (seconds).
    pub makespan_s: f64,
    /// Mean idle fraction across waves.
    pub idle_fraction: f64,
    /// Degree multiset the policy used.
    pub degrees: Vec<usize>,
}

/// Execute all policies on one sampled batch and collect Fig. 2 rows.
pub fn compute(npus: usize, batch: usize, seed: u64) -> Vec<MeshRow> {
    let mut ctx = ExpContext::new(
        by_name("InternVL3-8B").unwrap(),
        DatasetKind::OpenVid,
        npus,
        TrainStage::Full,
    );
    ctx.seed = seed;
    let mut sampler = ctx.sampler();
    let seqs = sampler.sample_batch(batch);
    let sim = ctx.sim();
    let cost = ctx.cost_model();

    let static_d =
        MegatronStaticCp::degree_for_longest(&seqs, ctx.replicas(), &cost);
    let static_policy = MegatronStaticCp::new(
        static_d,
        ctx.replicas(),
        cost,
        ctx.cluster.inter_bw,
    );
    let dhp = ctx.dhp();

    let mut rows = Vec::new();
    for (name, schedule, comm) in [
        (
            "Static mesh".to_string(),
            static_policy
                .schedule(&seqs)
                .expect("mesh comparison runs on an unfragmented mesh"),
            CommKind::RingCp,
        ),
        ("Dynamic mesh (DHP)".to_string(), dhp.schedule(&seqs), CommKind::RingCp),
    ] {
        let reports = sim.execute_schedule(&seqs, &schedule, comm);
        rows.push(MeshRow {
            policy: name,
            makespan_s: reports.iter().map(|w| w.makespan_s).sum(),
            idle_fraction: crate::util::stats::mean(
                &reports.iter().map(|w| w.idle_fraction).collect::<Vec<_>>(),
            ),
            degrees: schedule.degree_multiset(),
        });
    }
    rows
}

/// `dhp reproduce fig2` entry point.
pub fn run(args: &Args) -> Result<()> {
    let npus = args.usize_or("npus", 32)?;
    let batch = args.usize_or("batch", 24)?;
    let seed = args.u64_or("seed", 7)?;
    let rows = compute(npus, batch, seed);
    let mut t = Table::new(
        &format!("Fig. 2: static vs dynamic mesh ({npus} replicas, {batch} skewed seqs)"),
        &["Mesh", "total time (s)", "idle fraction", "CP degrees"],
    );
    for r in &rows {
        t.row(vec![
            r.policy.clone(),
            format!("{:.3}", r.makespan_s),
            format!("{:.1}%", r.idle_fraction * 100.0),
            crate::scheduler::format_degree_multiset(&r.degrees),
        ]);
    }
    t.print();
    let speedup = rows[0].makespan_s / rows[1].makespan_s;
    println!("dynamic-mesh speedup over static: {speedup:.2}x");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dynamic_beats_static_on_skewed_batch() {
        let rows = compute(32, 24, 7);
        let static_row = &rows[0];
        let dhp_row = &rows[1];
        assert!(
            dhp_row.makespan_s < static_row.makespan_s,
            "dynamic {} vs static {}",
            dhp_row.makespan_s,
            static_row.makespan_s
        );
        // And reduces idle time — the Fig. 2 mechanism.
        assert!(dhp_row.idle_fraction <= static_row.idle_fraction + 0.05);
        // Static is uniform; DHP is heterogeneous.
        let uniq_static: std::collections::HashSet<_> =
            static_row.degrees.iter().collect();
        assert_eq!(uniq_static.len(), 1);
    }
}
