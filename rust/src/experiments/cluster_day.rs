//! "Cluster day": the multi-tenant service benchmark.
//!
//! Replays one seeded job-arrival trace through every allocator-policy
//! × session-scheduler combination on a shared 8-replica cluster, and
//! additionally runs the pinned *departure scenario* — a hand-written
//! trace where one job's departure opens capacity for a queued job,
//! and WHERE that capacity opens differs by allocator: first-fit hands
//! the queued job a cross-node pair while best-fit hands it a whole
//! node, so the same job's goodput is measurably higher under
//! best-fit. `dhp reproduce cluster_day` prints per-job SLO and
//! cluster utilization/fragmentation tables for every cell;
//! `benches/cluster_day.rs` gates on the same rows and emits
//! `BENCH_cluster_day.json`.

use anyhow::Result;

use crate::cluster_service::{
    run_service, AllocPolicy, ClusterReport, JobSpec, JobTrace,
    ServiceConfig, ServiceScheduler, TraceConfig,
};
use crate::config::presets::by_name;
use crate::config::{ClusterConfig, TrainStage};
use crate::data::datasets::DatasetKind;
use crate::report::Table;
use crate::util::cli::Args;

/// One (allocator, scheduler) cell of the comparison.
#[derive(Debug, Clone)]
pub struct CellResult {
    /// Rank-placement policy the cell ran under.
    pub alloc: AllocPolicy,
    /// Per-job session scheduler the cell ran under.
    pub scheduler: ServiceScheduler,
    /// The full service report for the cell.
    pub report: ClusterReport,
}

/// The benchmark cluster: 4 nodes × 8 NPUs at TP=2 × PP=2 — 8 model
/// replicas, 2 per node, so allocation locality decides which fabric a
/// job's rings and gradient sync ride.
pub fn service_cluster() -> ClusterConfig {
    let mut cluster = ClusterConfig::default().with_npus(32);
    cluster.tp = 2;
    cluster.pp = 2;
    cluster
}

/// Service configuration for one cell.
pub fn service_config(
    alloc: AllocPolicy,
    scheduler: ServiceScheduler,
) -> ServiceConfig {
    ServiceConfig {
        preset: by_name("InternVL3-2B").expect("preset"),
        stage: TrainStage::Full,
        cluster: service_cluster(),
        alloc_policy: alloc,
        scheduler,
        max_ticks: 512,
    }
}

/// The pinned departure scenario (8 replicas, 2 per node). Jobs 0–3
/// fill the cluster to 7/8 ranks; job 4 (2 replicas) must queue. Job 0
/// departs after 3 steps. Under first-fit the freed rank 0 pairs with
/// the stranded rank 7 — a cross-node grant; under best-fit job 4 gets
/// ranks 0–1 — a whole node. Same trace, same scheduler: the grant's
/// fabric (and with it job 4's goodput) is the allocator's doing.
pub fn departure_trace() -> JobTrace {
    let job = |job_id, replicas, steps| JobSpec {
        job_id,
        arrival_step: 0,
        replicas,
        steps,
        dataset: DatasetKind::OpenVid,
        gbs: 8,
        seed: 0xDA1 ^ job_id,
        resizes: Vec::new(),
    };
    JobTrace {
        jobs: vec![
            job(0, 1, 3),
            job(1, 2, 8),
            job(2, 2, 8),
            job(3, 2, 8),
            job(4, 2, 4),
        ],
    }
}

/// The synthetic cluster-day trace for `seed` (smaller under
/// `--quick`).
pub fn day_trace(seed: u64, quick: bool) -> JobTrace {
    JobTrace::synthetic(&TraceConfig {
        seed,
        jobs: if quick { 6 } else { 16 },
        arrival_rate: 0.2,
        mean_replicas: 2,
        max_replicas: 4,
        mean_steps: if quick { 4 } else { 10 },
        resize_prob: 0.3,
    })
}

/// All four cells over the same trace.
pub fn compute(trace: &JobTrace) -> Result<Vec<CellResult>> {
    let mut cells = Vec::new();
    for alloc in [AllocPolicy::FirstFit, AllocPolicy::BestFit] {
        for scheduler in [ServiceScheduler::Dhp, ServiceScheduler::StaticCp] {
            let report = run_service(
                service_config(alloc, scheduler),
                trace.clone(),
            )?;
            cells.push(CellResult {
                alloc,
                scheduler,
                report,
            });
        }
    }
    Ok(cells)
}

/// Cross-cell comparison table.
pub fn summary_table(title: &str, cells: &[CellResult]) -> Table {
    let mut t = Table::new(
        title,
        &[
            "allocator",
            "scheduler",
            "util",
            "frag",
            "mean wait",
            "completed",
            "goodput (steps/s)",
            "digest",
        ],
    );
    for c in cells {
        t.row(vec![
            c.alloc.name().to_string(),
            c.scheduler.name().to_string(),
            format!("{:.4}", c.report.mean_utilization()),
            format!("{:.4}", c.report.mean_fragmentation()),
            format!("{:.3}", c.report.mean_queue_wait_steps()),
            format!("{}/{}", c.report.completed_jobs(), c.report.jobs.len()),
            format!("{:.4}", c.report.total_goodput_steps_per_s()),
            format!("{:016x}", c.report.digest),
        ]);
    }
    t
}

/// Goodput of the queued job (id 4) in the departure scenario under
/// `alloc` + DHP.
pub fn queued_job_goodput(cells: &[CellResult], alloc: AllocPolicy) -> f64 {
    cells
        .iter()
        .find(|c| c.alloc == alloc && c.scheduler == ServiceScheduler::Dhp)
        .and_then(|c| c.report.jobs.iter().find(|j| j.job_id == 4))
        .map(|j| j.goodput_steps_per_s)
        .unwrap_or(0.0)
}

/// `dhp reproduce cluster_day`: the departure scenario plus a synthetic
/// cluster day, all four cells each.
pub fn run(args: &Args) -> Result<()> {
    let quick = args.flag("quick");
    let seed = args.u64_or("seed", 0xC1_D4B)?;

    let dep = compute(&departure_trace())?;
    for c in &dep {
        c.report.job_table().print();
        c.report.cluster_table().print();
    }
    summary_table("Departure scenario — allocator × scheduler", &dep).print();
    let ff = queued_job_goodput(&dep, AllocPolicy::FirstFit);
    let bf = queued_job_goodput(&dep, AllocPolicy::BestFit);
    println!(
        "queued job 4 goodput: first-fit {:.4} vs best-fit {:.4} steps/s ({:+.1}%)",
        ff,
        bf,
        (bf / ff.max(1e-12) - 1.0) * 100.0
    );

    let day = compute(&day_trace(seed, quick))?;
    summary_table(
        &format!(
            "Cluster day (seed {seed:#x}, {} jobs) — allocator × scheduler",
            day[0].report.jobs.len()
        ),
        &day,
    )
    .print();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn departure_raises_queued_goodput_under_best_fit() {
        // THE acceptance scenario: job 4 queues in every cell; after job
        // 0 departs, best-fit re-admits it onto a whole node while
        // first-fit scatters it across nodes. Intra-node gradient sync
        // and rings are strictly faster, so best-fit goodput must be
        // measurably (>5%) higher on this pinned trace.
        let cells = compute(&departure_trace()).unwrap();
        for c in &cells {
            let j4 = c.report.jobs.iter().find(|j| j.job_id == 4).unwrap();
            assert!(
                j4.queue_wait_steps > 0,
                "{}/{}: job 4 never queued",
                c.alloc.name(),
                c.scheduler.name()
            );
            assert!(j4.completed_step.is_some());
        }
        let ff = queued_job_goodput(&cells, AllocPolicy::FirstFit);
        let bf = queued_job_goodput(&cells, AllocPolicy::BestFit);
        assert!(ff > 0.0 && bf > 0.0);
        assert!(
            bf > ff * 1.05,
            "best-fit {bf} must beat first-fit {ff} by >5% for the queued job"
        );
    }

    #[test]
    fn departure_scenario_runs_three_plus_concurrent_sessions() {
        let cells = compute(&departure_trace()).unwrap();
        for c in &cells {
            let peak = c.report.samples.iter().map(|s| s.running).max();
            assert!(
                peak >= Some(4),
                "{}/{}: peak concurrency {peak:?} < 4",
                c.alloc.name(),
                c.scheduler.name()
            );
        }
    }

    #[test]
    fn cells_replay_bit_identically() {
        let trace = day_trace(7, true);
        let a = compute(&trace).unwrap();
        let b = compute(&trace).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.report.digest, y.report.digest);
            assert_eq!(x.report.render(), y.report.render());
        }
    }

    #[test]
    fn synthetic_day_makes_progress_in_every_cell() {
        let cells = compute(&day_trace(7, true)).unwrap();
        for c in &cells {
            assert!(
                c.report.completed_jobs() > 0,
                "{}/{}: no job completed",
                c.alloc.name(),
                c.scheduler.name()
            );
            assert!(c.report.mean_utilization() > 0.0);
        }
    }
}
