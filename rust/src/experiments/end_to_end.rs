//! Figs. 4 & 6 — average iteration time across all 18 configurations
//! (6 models × 3 datasets) for Megatron-LM, DeepSpeed and DHP. Fig. 6 is
//! full end-to-end training; Fig. 4 freezes the vision encoder.

use anyhow::Result;

use crate::config::presets::PRESETS;
use crate::config::TrainStage;
use crate::data::datasets::DatasetKind;
use crate::report::Table;
use crate::util::cli::Args;

use super::harness::{run_policy, ExpContext, PolicySet};

/// One configuration's results.
#[derive(Debug, Clone)]
pub struct E2eRow {
    /// Model preset name.
    pub model: &'static str,
    /// Dataset display name.
    pub dataset: &'static str,
    /// Megatron-LM mean iteration seconds.
    pub megatron_s: f64,
    /// DeepSpeed-Ulysses mean iteration seconds.
    pub deepspeed_s: f64,
    /// DHP mean iteration seconds.
    pub dhp_s: f64,
}

impl E2eRow {
    /// Speedup over the BEST baseline (the paper's headline definition).
    pub fn speedup_vs_best(&self) -> f64 {
        self.megatron_s.min(self.deepspeed_s) / self.dhp_s
    }

    /// Speedup over Megatron-LM (the figures' annotation).
    pub fn speedup_vs_megatron(&self) -> f64 {
        self.megatron_s / self.dhp_s
    }
}

/// Run the full 6-model × 3-dataset sweep at `stage`.
pub fn compute(
    stage: TrainStage,
    npus: usize,
    gbs: usize,
    warmup: usize,
    measure: usize,
    seed: u64,
) -> Vec<E2eRow> {
    let mut rows = Vec::new();
    for preset in PRESETS.iter() {
        for dataset in DatasetKind::all() {
            let mut ctx = ExpContext::new(preset.clone(), dataset, npus, stage)
                .with_gbs(gbs)
                .with_steps(warmup, measure);
            ctx.seed = seed;
            let set = PolicySet::build(&ctx);
            let mega = run_policy(&ctx, &set.megatron);
            let ds = run_policy(&ctx, &set.deepspeed);
            let dhp = run_policy(&ctx, &set.dhp);
            rows.push(E2eRow {
                model: preset.name,
                dataset: dataset.name(),
                megatron_s: mega.mean_iter_s,
                deepspeed_s: ds.mean_iter_s,
                dhp_s: dhp.mean_iter_s,
            });
        }
    }
    rows
}

/// `dhp reproduce fig4|fig6` entry point (stage selects the figure).
pub fn run(args: &Args, stage: TrainStage) -> Result<()> {
    let npus = args.usize_or("npus", 64)?;
    let gbs = args.usize_or("gbs", 512)?;
    let (warmup, measure) = super::protocol_steps(args)?;
    let seed = args.u64_or("seed", 0xF164)?;
    let rows = compute(stage, npus, gbs, warmup, measure, seed);

    let (fig, title) = match stage {
        TrainStage::Full => ("Fig. 6", "end-to-end training"),
        TrainStage::FrozenVision => ("Fig. 4", "frozen vision encoder"),
    };
    let mut t = Table::new(
        &format!("{fig}: avg iteration time, {title} ({npus} NPUs, GBS {gbs})"),
        &[
            "Model",
            "Dataset",
            "Megatron (s)",
            "DeepSpeed (s)",
            "DHP (s)",
            "vs best",
            "vs Megatron",
        ],
    );
    let mut speedups = Vec::new();
    for r in &rows {
        speedups.push(r.speedup_vs_best());
        t.row(vec![
            r.model.to_string(),
            r.dataset.to_string(),
            format!("{:.2}", r.megatron_s),
            format!("{:.2}", r.deepspeed_s),
            format!("{:.2}", r.dhp_s),
            format!("{:.2}x", r.speedup_vs_best()),
            format!("{:.2}x", r.speedup_vs_megatron()),
        ]);
    }
    t.print();
    let wins = speedups.iter().filter(|&&s| s > 1.0).count();
    let max = speedups.iter().cloned().fold(0.0f64, f64::max);
    let over_1_2 = speedups.iter().filter(|&&s| s >= 1.2).count();
    println!(
        "DHP beats best baseline in {wins}/{} configs; max speedup {max:.2}x; \
         >=1.2x in {over_1_2} configs (paper: all 18; up to 1.35-1.36x; 14/18)",
        rows.len()
    );
    if let Some(path) = args.get("out") {
        write_json(path, fig, npus, gbs, &rows)?;
        println!("wrote JSON report to {path}");
    }
    Ok(())
}

/// Emit the rows as a machine-readable JSON report (`--out file.json`).
fn write_json(
    path: &str,
    fig: &str,
    npus: usize,
    gbs: usize,
    rows: &[E2eRow],
) -> Result<()> {
    use crate::util::json::{arr, num, obj, s};
    let items = rows
        .iter()
        .map(|r| {
            obj(vec![
                ("model", s(r.model)),
                ("dataset", s(r.dataset)),
                ("megatron_s", num(r.megatron_s)),
                ("deepspeed_s", num(r.deepspeed_s)),
                ("dhp_s", num(r.dhp_s)),
                ("speedup_vs_best", num(r.speedup_vs_best())),
            ])
        })
        .collect();
    let doc = obj(vec![
        ("experiment", s(fig)),
        ("npus", num(npus as f64)),
        ("gbs", num(gbs as f64)),
        ("rows", arr(items)),
    ]);
    std::fs::write(path, doc.to_string_pretty())
        .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::by_name;
    use crate::data::datasets::DatasetKind;
    use crate::experiments::harness::{run_policy, ExpContext, PolicySet};

    /// Reduced-scale version of the headline claim so the test stays fast:
    /// one model on the most/least skewed datasets.
    #[test]
    fn dhp_beats_baselines_on_openvid() {
        let mut ctx = ExpContext::new(
            by_name("InternVL3-8B").unwrap(),
            DatasetKind::OpenVid,
            32,
            TrainStage::Full,
        )
        .with_gbs(64)
        .with_steps(1, 3);
        ctx.seed = 99;
        let set = PolicySet::build(&ctx);
        let mega = run_policy(&ctx, &set.megatron);
        let ds = run_policy(&ctx, &set.deepspeed);
        let dhp = run_policy(&ctx, &set.dhp);
        let best = mega.mean_iter_s.min(ds.mean_iter_s);
        assert!(
            dhp.mean_iter_s < best,
            "DHP {} vs best baseline {}",
            dhp.mean_iter_s,
            best
        );
    }

    #[test]
    fn speedup_larger_on_skewed_dataset() {
        // Paper: "the improvement is particularly pronounced on the
        // diverse and complex OpenVid dataset" vs MSRVTT.
        let run_one = |dataset| {
            let mut ctx = ExpContext::new(
                by_name("InternVL3-8B").unwrap(),
                dataset,
                16,
                TrainStage::Full,
            )
            .with_gbs(64)
            .with_steps(1, 3);
            ctx.seed = 7;
            let set = PolicySet::build(&ctx);
            let mega = run_policy(&ctx, &set.megatron);
            let ds = run_policy(&ctx, &set.deepspeed);
            let dhp = run_policy(&ctx, &set.dhp);
            mega.mean_iter_s.min(ds.mean_iter_s) / dhp.mean_iter_s
        };
        let s_openvid = run_one(DatasetKind::OpenVid);
        let s_msrvtt = run_one(DatasetKind::Msrvtt);
        assert!(
            s_openvid > s_msrvtt,
            "openvid speedup {s_openvid} <= msrvtt {s_msrvtt}"
        );
    }
}
