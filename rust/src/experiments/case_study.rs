//! Table 4 — case study: the actual CP-group configurations DHP vs the
//! static baselines employ within one global batch, on OpenVid (case 1,
//! long-tailed) and MSRVTT (case 2, more uniform), plus the resulting
//! speedups.

use anyhow::Result;

use crate::baselines::SchedulePolicy;
use crate::config::presets::by_name;
use crate::config::TrainStage;
use crate::data::batch::GlobalBatch;
use crate::data::datasets::DatasetKind;
use crate::data::sequence::Sequence;
use crate::report::Table;
use crate::scheduler::{format_degree_multiset, Schedule};
use crate::util::cli::Args;

use super::harness::{ExpContext, PolicySet};

/// Table 4 result for one dataset case.
#[derive(Debug, Clone)]
pub struct CaseResult {
    /// Dataset display name.
    pub dataset: &'static str,
    /// Degree multiset per micro-batch, per policy.
    pub megatron: Vec<Vec<usize>>,
    /// DeepSpeed-Ulysses degree multisets per micro-batch.
    pub deepspeed: Vec<Vec<usize>>,
    /// DHP degree multisets per micro-batch.
    pub dhp: Vec<Vec<usize>>,
    /// DHP speedup over the best baseline on this batch.
    pub speedup: f64,
    /// Distinct CP degrees DHP used.
    pub dhp_distinct_degrees: usize,
}

/// Run all three policies on one global batch of `dataset` and collect
/// the Table 4 row.
pub fn compute_case(dataset: DatasetKind, npus: usize, gbs: usize, seed: u64) -> CaseResult {
    let mut ctx = ExpContext::new(
        by_name("InternVL3-8B").unwrap(),
        dataset,
        npus,
        TrainStage::Full,
    )
    .with_gbs(gbs);
    ctx.seed = seed;
    let set = PolicySet::build(&ctx);
    let planner = ctx.micro_batch_planner();
    let sim = ctx.sim();
    let mut sampler = ctx.sampler();
    let batch = GlobalBatch {
        step: 0,
        sequences: sampler.sample_batch(gbs),
    };
    let mbs = planner.plan(&batch);

    let run = |policy: &dyn SchedulePolicy| -> (Vec<Vec<usize>>, f64) {
        let mut degrees = Vec::new();
        let scheduled: Vec<(Vec<Sequence>, Schedule)> = mbs
            .iter()
            .map(|mb| {
                let s = policy
                    .schedule(&mb.sequences)
                    .expect("case study runs on an unfragmented mesh");
                degrees.push(s.degree_multiset());
                (mb.sequences.clone(), s)
            })
            .collect();
        // One-batch case study: compare steady-state iteration time, so
        // execute against a warm pool (startup creation is not the
        // phenomenon Table 4 isolates).
        let mut pool = crate::parallel::GroupPool::new();
        pool.prewarm(scheduled.iter().flat_map(|(_, s)| s.pool_keys()));
        let t = sim
            .execute_iteration(&scheduled, policy.comm_kind(), &mut pool)
            .iter_time_s;
        (degrees, t)
    };

    let (mega_d, mega_t) = run(&set.megatron);
    let (ds_d, ds_t) = run(&set.deepspeed);
    let (dhp_d, dhp_t) = run(&set.dhp);
    let distinct: std::collections::HashSet<usize> =
        dhp_d.iter().flatten().copied().collect();
    CaseResult {
        dataset: dataset.name(),
        megatron: mega_d,
        deepspeed: ds_d,
        dhp: dhp_d,
        speedup: mega_t.min(ds_t) / dhp_t,
        dhp_distinct_degrees: distinct.len(),
    }
}

fn fmt_multisets(ms: &[Vec<usize>]) -> String {
    // Collapse identical micro-batch multisets: "<8>x1 ... (x4 micro-batches)".
    let mut parts: Vec<String> = Vec::new();
    let mut i = 0;
    while i < ms.len() {
        let mut count = 1;
        while i + count < ms.len() && ms[i + count] == ms[i] {
            count += 1;
        }
        let inner = format_degree_multiset(&ms[i]);
        if count > 1 {
            parts.push(format!("[{inner}] x{count}"));
        } else {
            parts.push(format!("[{inner}]"));
        }
        i += count;
    }
    parts.join("  ")
}

/// `dhp reproduce tab4` entry point.
pub fn run(args: &Args) -> Result<()> {
    let npus = args.usize_or("npus", 32)?;
    let gbs = args.usize_or("gbs", 128)?;
    let seed = args.u64_or("seed", 0x7AB4)?;
    let case1 = compute_case(DatasetKind::OpenVid, npus, gbs, seed);
    let case2 = compute_case(DatasetKind::Msrvtt, npus, gbs, seed);

    let mut t = Table::new(
        &format!("Table 4: CP groups per micro-batch ({npus} replicas, GBS {gbs})"),
        &["Policy", "Case 1 (OpenVid)", "Case 2 (MSRVTT)"],
    );
    t.row(vec![
        "Megatron-LM".into(),
        fmt_multisets(&case1.megatron),
        fmt_multisets(&case2.megatron),
    ]);
    t.row(vec![
        "DeepSpeed".into(),
        fmt_multisets(&case1.deepspeed),
        fmt_multisets(&case2.deepspeed),
    ]);
    t.row(vec![
        "DHP".into(),
        fmt_multisets(&case1.dhp),
        fmt_multisets(&case2.dhp),
    ]);
    t.print();
    println!(
        "speedups: case 1 {:.2}x, case 2 {:.2}x (paper: 1.17x / 1.14x); \
         DHP distinct degrees: case 1 = {}, case 2 = {} (richer mix on the \
         more diverse dataset)",
        case1.speedup, case2.speedup, case1.dhp_distinct_degrees,
        case2.dhp_distinct_degrees
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_shape_holds() {
        let case1 = compute_case(DatasetKind::OpenVid, 32, 32, 21);
        let case2 = compute_case(DatasetKind::Msrvtt, 32, 32, 21);
        // Baselines are uniform within each micro-batch.
        for ms in case1.megatron.iter().chain(&case2.megatron) {
            let uniq: std::collections::HashSet<_> = ms.iter().collect();
            assert!(uniq.len() <= 1, "static mesh must be uniform: {ms:?}");
        }
        // DHP adapts: at least as rich a mix on the diverse dataset.
        assert!(case1.dhp_distinct_degrees >= 2, "{case1:?}");
        assert!(
            case1.dhp_distinct_degrees >= case2.dhp_distinct_degrees,
            "OpenVid should need at least as many distinct degrees: {} vs {}",
            case1.dhp_distinct_degrees,
            case2.dhp_distinct_degrees
        );
        // And DHP wins on both cases.
        assert!(case1.speedup > 1.0, "case1 speedup {}", case1.speedup);
        assert!(case2.speedup > 1.0, "case2 speedup {}", case2.speedup);
    }
}
