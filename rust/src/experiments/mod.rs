//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§6). See DESIGN.md §4 for the experiment index.
//!
//! Each experiment is callable from the CLI (`dhp reproduce <id>`) and
//! from `benches/` (which time the same code paths), and returns its rows
//! so tests can assert the paper's qualitative shape (who wins, by
//! roughly what factor, where crossovers fall).

pub mod case_study;
pub mod cluster_day;
pub mod distributions;
pub mod end_to_end;
pub mod estimator;
pub mod harness;
pub mod mesh_compare;
pub mod overhead;
pub mod resilience;
pub mod scalability;

use anyhow::{bail, Result};

use crate::util::cli::Args;

pub use harness::{dispatch, run_policy, ExpContext, PolicySet, PolicyResult};

/// `dhp reproduce <exp>` dispatcher.
pub fn reproduce(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let run = |name: &str, args: &Args| -> Result<()> {
        match name {
            "fig1" => distributions::run(args),
            "fig2" => mesh_compare::run(args),
            "fig4" => end_to_end::run(args, crate::config::TrainStage::FrozenVision),
            "fig5" => scalability::run(args),
            "fig6" => end_to_end::run(args, crate::config::TrainStage::Full),
            "tab1" => overhead::run_gbs(args),
            "tab2" => overhead::run_npus(args),
            // Tables 1-2 plus the ISSUE-9 cold-vs-steady-state solver
            // comparison: what the solver costs on a correlated batch
            // stream with the cross-step reuse layers on vs forced off.
            "overhead" => {
                overhead::run_gbs(args)?;
                overhead::run_npus(args)?;
                overhead::run_reuse_comparison(args)
            }
            "tab3" => estimator::run(args),
            "tab4" => case_study::run(args),
            "resilience" => resilience::run(args),
            "cluster_day" => cluster_day::run(args),
            other => bail!(
                "unknown experiment {other:?}: expected fig1|fig2|fig4|fig5|fig6|tab1|tab2|tab3|tab4|overhead|resilience|cluster_day|all"
            ),
        }
    };
    if which == "all" {
        for name in [
            "fig1", "fig2", "tab3", "tab4", "tab1", "tab2", "fig5", "fig4",
            "fig6", "resilience", "cluster_day",
        ] {
            println!("\n#### reproduce {name} ####");
            run(name, args)?;
        }
        Ok(())
    } else {
        run(which, args)
    }
}

/// `dhp schedule` — run the scheduler once and print the plan.
pub fn schedule_cmd(args: &Args) -> Result<()> {
    use crate::config::presets;
    use crate::data::datasets::DatasetKind;

    let preset = presets::by_name(args.str_or("model", "InternVL3-8B"))
        .ok_or_else(|| anyhow::anyhow!("unknown --model"))?;
    let dataset = DatasetKind::by_name(args.str_or("dataset", "openvid"))?;
    let npus = args.usize_or("npus", 32)?;
    let gbs = args.usize_or("gbs", 32)?;
    let seed = args.u64_or("seed", 0xD4B)?;

    let mut ctx = ExpContext::new(preset, dataset, npus, crate::config::TrainStage::Full);
    ctx.seed = seed;
    let mut sampler = ctx.sampler();
    let seqs = sampler.sample_batch(gbs);
    let scheduler = ctx.dhp();
    let schedule = scheduler.schedule(&seqs);
    schedule.validate(&seqs, ctx.replicas())?;

    let mut t = crate::report::Table::new(
        &format!(
            "DHP plan: {} on {} ({} replicas, {} seqs, solver {:.2} ms)",
            ctx.preset.name,
            dataset.name(),
            ctx.replicas(),
            gbs,
            schedule.solve_time_s * 1e3
        ),
        &["wave", "group", "degree", "ranks", "#seqs", "tokens", "est time (s)"],
    );
    for (wi, wave) in schedule.waves.iter().enumerate() {
        for (gi, g) in wave.groups.iter().enumerate() {
            let ranks = if g.ranks.len() <= 8 {
                format!("{:?}", g.ranks)
            } else {
                format!(
                    "[{}..{}] ({})",
                    g.ranks.first().unwrap(),
                    g.ranks.last().unwrap(),
                    g.ranks.len()
                )
            };
            t.row(vec![
                wi.to_string(),
                gi.to_string(),
                g.degree.to_string(),
                ranks,
                g.seq_idxs.len().to_string(),
                format!("{:.0}", g.agg.tokens),
                format!("{:.4}", g.est_time_s),
            ]);
        }
    }
    t.print();
    println!(
        "degrees: {}",
        crate::scheduler::format_degree_multiset(&schedule.degree_multiset())
    );
    Ok(())
}

/// Common step-count knobs for experiments (paper protocol by default,
/// reducible for benches via --warmup/--measure).
pub fn protocol_steps(args: &Args) -> Result<(usize, usize)> {
    Ok((
        args.usize_or("warmup", 2)?,
        args.usize_or("measure", 5)?,
    ))
}
