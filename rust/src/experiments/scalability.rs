//! Fig. 5 — scalability: token throughput for DHP / DeepSpeed /
//! Megatron-LM over 8, 16, 32, 64 NPUs (GBS fixed at 512).

use anyhow::Result;

use crate::config::presets::by_name;
use crate::config::TrainStage;
use crate::data::datasets::DatasetKind;
use crate::report::Table;
use crate::util::cli::Args;

use super::harness::{run_policy, ExpContext, PolicySet};

/// One Fig. 5 row: throughput at one cluster size.
#[derive(Debug, Clone)]
pub struct ScaleRow {
    /// NPU count of the row.
    pub npus: usize,
    /// k tokens/s, cluster-wide (Fig. 5's y-axis).
    pub megatron_ktps: f64,
    /// DeepSpeed-Ulysses throughput (k tokens/s).
    pub deepspeed_ktps: f64,
    /// DHP throughput (k tokens/s).
    pub dhp_ktps: f64,
}

impl ScaleRow {
    /// DHP's throughput ratio over DeepSpeed (the Fig. 5 annotation).
    pub fn dhp_vs_deepspeed(&self) -> f64 {
        self.dhp_ktps / self.deepspeed_ktps
    }
}

/// Sweep cluster sizes and measure all three policies' throughput.
pub fn compute(
    npus_list: &[usize],
    gbs: usize,
    warmup: usize,
    measure: usize,
    seed: u64,
) -> Vec<ScaleRow> {
    let preset = by_name("InternVL3-8B").unwrap();
    npus_list
        .iter()
        .map(|&npus| {
            let mut ctx = ExpContext::new(
                preset.clone(),
                DatasetKind::OpenVid,
                npus,
                TrainStage::Full,
            )
            .with_gbs(gbs)
            .with_steps(warmup, measure);
            ctx.seed = seed;
            let set = PolicySet::build(&ctx);
            let mega = run_policy(&ctx, &set.megatron);
            let ds = run_policy(&ctx, &set.deepspeed);
            let dhp = run_policy(&ctx, &set.dhp);
            ScaleRow {
                npus,
                megatron_ktps: mega.tokens_per_s / 1e3,
                deepspeed_ktps: ds.tokens_per_s / 1e3,
                dhp_ktps: dhp.tokens_per_s / 1e3,
            }
        })
        .collect()
}

/// `dhp reproduce fig5` entry point.
pub fn run(args: &Args) -> Result<()> {
    let npus_list = args.usize_list_or("npus", &[8, 16, 32, 64])?;
    let gbs = args.usize_or("gbs", 512)?;
    let (warmup, measure) = super::protocol_steps(args)?;
    let seed = args.u64_or("seed", 0xF165)?;
    let rows = compute(&npus_list, gbs, warmup, measure, seed);
    let mut t = Table::new(
        &format!("Fig. 5: token throughput scaling (InternVL3-8B, OpenVid, GBS {gbs})"),
        &[
            "NPUs",
            "Megatron (k tok/s)",
            "DeepSpeed (k tok/s)",
            "DHP (k tok/s)",
            "DHP/DeepSpeed",
        ],
    );
    for r in &rows {
        t.row(vec![
            r.npus.to_string(),
            format!("{:.1}", r.megatron_ktps),
            format!("{:.1}", r.deepspeed_ktps),
            format!("{:.1}", r.dhp_ktps),
            format!("{:.2}x", r.dhp_vs_deepspeed()),
        ]);
    }
    t.print();
    if rows.len() >= 2 {
        let first = rows.first().unwrap().dhp_vs_deepspeed();
        let last = rows.last().unwrap().dhp_vs_deepspeed();
        println!(
            "DHP advantage vs DeepSpeed grows with scale: {first:.2}x @ {} NPUs \
             -> {last:.2}x @ {} NPUs (paper: 1.02x -> 1.16x)",
            rows.first().unwrap().npus,
            rows.last().unwrap().npus
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_shape_holds() {
        // Reduced protocol for test speed.
        let rows = compute(&[8, 32], 128, 1, 2, 3);
        assert_eq!(rows.len(), 2);
        for r in &rows {
            // DHP is the highest-throughput policy at every scale.
            assert!(
                r.dhp_ktps >= r.megatron_ktps && r.dhp_ktps >= r.deepspeed_ktps,
                "{r:?}"
            );
        }
        // The relative advantage does not shrink with scale.
        assert!(
            rows[1].dhp_vs_deepspeed() >= rows[0].dhp_vs_deepspeed() * 0.95,
            "{rows:?}"
        );
        // Cluster throughput grows with more NPUs.
        assert!(rows[1].dhp_ktps > rows[0].dhp_ktps);
    }
}
