//! Tables 1 & 2 — time consumption of computing vs scheduling vs solver,
//! sweeping the global batch size (Table 1) and the NPU count (Table 2).
//! Schedule/solver times are REAL wall-clock of our solver; computing
//! time is the simulated cluster execution.

use anyhow::Result;

use crate::config::presets::by_name;
use crate::config::TrainStage;
use crate::data::datasets::DatasetKind;
use crate::report::Table;
use crate::util::cli::Args;

use super::harness::{run_policy, ExpContext};

/// One Table 1/2 row: where each iteration's time goes.
#[derive(Debug, Clone)]
pub struct OverheadRow {
    /// Global batch size of the row.
    pub gbs: usize,
    /// NPU count of the row.
    pub npus: usize,
    /// Mean simulated execution + grad-sync seconds per iteration.
    pub computing_s: f64,
    /// Mean measured scheduling-phase wall-clock (ms).
    pub schedule_ms: f64,
    /// Mean measured pure solver wall-clock (ms).
    pub solver_ms: f64,
    /// Mean group-reconfiguration time CHARGED per measured iteration:
    /// the pool-miss creation cost left after the prewarm overlap hid up
    /// to the previous step's compute — the paper claims this is
    /// negligible once the pool is warm, and now we measure it.
    pub reconfig_ms: f64,
    /// Mean fully-serial reconfiguration time (ms) — what the same run
    /// would pay without the CPU-side prewarm overlap (ablation column).
    pub reconfig_serial_ms: f64,
    /// Communication-group pool hit-rate over the measured window.
    pub pool_hit_rate: f64,
    /// Fraction of placed groups that replayed the previous step's rank
    /// block (hint quality: separates placement churn from data drift).
    pub replay_rate: f64,
    /// Pool evictions over the measured window (0 unless capacity-capped).
    pub evictions: u64,
}

/// One Table 1/2 row: run the DHP policy at (`gbs`, `npus`) through the
/// protocol and extract the overhead columns.
pub fn compute_row(
    gbs: usize,
    npus: usize,
    warmup: usize,
    measure: usize,
    seed: u64,
) -> OverheadRow {
    let mut ctx = ExpContext::new(
        by_name("InternVL3-8B").unwrap(),
        DatasetKind::OpenVid,
        npus,
        TrainStage::Full,
    )
    .with_gbs(gbs)
    .with_steps(warmup, measure);
    ctx.seed = seed;
    let dhp = ctx.dhp();
    let r = run_policy(&ctx, &dhp);
    OverheadRow {
        gbs,
        npus,
        // Pure execution + grad-sync: reconfiguration is reported in its
        // own column, so the Computing column stays comparable across
        // runs and the columns are additive.
        computing_s: r.mean_iter_s - r.mean_reconfig_s,
        schedule_ms: r.mean_schedule_s * 1e3,
        solver_ms: r.mean_solver_s * 1e3,
        reconfig_ms: r.mean_reconfig_s * 1e3,
        reconfig_serial_ms: r.mean_reconfig_serial_s * 1e3,
        pool_hit_rate: r.pool.hit_rate(),
        replay_rate: r.replay_rate,
        evictions: r.pool.evictions,
    }
}

fn print_table(title: &str, label: &str, rows: &[OverheadRow], key: impl Fn(&OverheadRow) -> usize) {
    let mut t = Table::new(
        title,
        &[
            label,
            "Computing Time (s)",
            "Schedule Time (ms)",
            "Solver Time (ms)",
            "Reconfig (ms)",
            "Serial (ms)",
            "Pool hit-rate",
            "Replay",
            "Evict",
        ],
    );
    for r in rows {
        t.row(vec![
            key(r).to_string(),
            format!("{:.2}", r.computing_s),
            format!("{:.0}", r.schedule_ms),
            format!("{:.1}", r.solver_ms),
            format!("{:.1}", r.reconfig_ms),
            format!("{:.1}", r.reconfig_serial_ms),
            format!("{:.2}", r.pool_hit_rate),
            format!("{:.2}", r.replay_rate),
            r.evictions.to_string(),
        ]);
    }
    t.print();
}

/// Table 1: GBS ∈ {128, 256, 512} at 64 NPUs.
pub fn run_gbs(args: &Args) -> Result<()> {
    let gbs_list = args.usize_list_or("gbs-list", &[128, 256, 512])?;
    let npus = args.usize_or("npus", 64)?;
    let (warmup, measure) = super::protocol_steps(args)?;
    let seed = args.u64_or("seed", 0x7AB1)?;
    let rows: Vec<OverheadRow> = gbs_list
        .iter()
        .map(|&g| compute_row(g, npus, warmup, measure, seed))
        .collect();
    print_table(
        &format!("Table 1: time consumption vs global batch size ({npus} NPUs)"),
        "GBS",
        &rows,
        |r| r.gbs,
    );
    for r in &rows {
        println!(
            "GBS {}: schedule/compute = {:.1}% (paper: scheduling always \
             hidden behind compute)",
            r.gbs,
            r.schedule_ms / 10.0 / r.computing_s
        );
    }
    Ok(())
}

/// Per-mode result of the cold-vs-steady-state solver comparison: one
/// correlated batch stream replayed through one scheduler configuration.
#[derive(Debug, Clone)]
pub struct ReuseModeStats {
    /// Median per-step pure solver wall-clock (ms).
    pub solver_p50_ms: f64,
    /// 90th-percentile per-step solver wall-clock (ms).
    pub solver_p90_ms: f64,
    /// Steps served from the exact-hit schedule cache.
    pub cache_hits: usize,
    /// Steps whose search ran warm-started.
    pub warm_starts: usize,
    /// Steps that took the ε fast path (0 under the default config).
    pub fast_paths: usize,
    /// Mean pruned-candidate fraction over the steps that searched.
    pub pruned_frac: f64,
}

/// Replay a correlated stream (three of four steps repeat a base batch,
/// every fourth draws fresh from the same distribution) through one
/// scheduler and collect per-step solver telemetry. The stream is
/// passed in so cold and steady-state modes see identical batches.
pub fn reuse_stream_stats(
    sch: &crate::scheduler::Scheduler,
    stream: &[Vec<crate::data::sequence::Sequence>],
) -> ReuseModeStats {
    use crate::util::stats;
    let mut samples = Vec::with_capacity(stream.len());
    let (mut cache_hits, mut warm_starts, mut fast_paths) = (0usize, 0usize, 0usize);
    let mut pruned = Vec::new();
    for batch in stream {
        let out = sch.schedule(batch);
        samples.push(out.solve_time_s);
        cache_hits += out.stats.cache_hit as usize;
        warm_starts += out.stats.warm_started as usize;
        fast_paths += out.stats.fast_path as usize;
        if out.stats.candidates > 0 {
            pruned.push(out.stats.pruned_frac());
        }
    }
    ReuseModeStats {
        solver_p50_ms: stats::percentile(&samples, 50.0) * 1e3,
        solver_p90_ms: stats::percentile(&samples, 90.0) * 1e3,
        cache_hits,
        warm_starts,
        fast_paths,
        pruned_frac: if pruned.is_empty() {
            0.0
        } else {
            pruned.iter().sum::<f64>() / pruned.len() as f64
        },
    }
}

/// Build the correlated stream both comparison modes replay.
pub fn correlated_stream(
    ctx: &ExpContext,
    gbs: usize,
    steps: usize,
) -> Vec<Vec<crate::data::sequence::Sequence>> {
    let mut sampler = ctx.sampler();
    let base = sampler.sample_batch(gbs);
    (0..steps)
        .map(|step| {
            if step > 0 && step % 4 == 0 {
                sampler.sample_batch(gbs)
            } else {
                base.clone()
            }
        })
        .collect()
}

/// The ISSUE-9 companion row to Tables 1–2: cold vs steady-state solver
/// overhead on one correlated stream — the training-time regime the
/// per-row protocol (fresh batches every step, short measure window)
/// under-represents. "Cold" forces every step down the full search
/// (`with_solver_reuse(false)`); "steady-state" is the production
/// default (exact-hit cache + warm-start seeding, both exact).
pub fn run_reuse_comparison(args: &Args) -> Result<()> {
    let npus = args.usize_or("npus", 64)?;
    let gbs = args.usize_or("gbs", 512)?;
    let steps = args.usize_or("steps", 16)?;
    let seed = args.u64_or("seed", 0x7AB3)?;
    let mut ctx = ExpContext::new(
        by_name("InternVL3-8B").unwrap(),
        DatasetKind::OpenVid,
        npus,
        TrainStage::Full,
    );
    ctx.seed = seed;
    let stream = correlated_stream(&ctx, gbs, steps);
    let cold = reuse_stream_stats(&ctx.dhp().with_solver_reuse(false), &stream);
    let steady = reuse_stream_stats(&ctx.dhp(), &stream);
    let mut t = Table::new(
        &format!(
            "Solver overhead, cold vs steady-state ({steps}-step correlated \
             stream, GBS {gbs}, {npus} NPUs)"
        ),
        &[
            "Mode",
            "Solver p50 (ms)",
            "Solver p90 (ms)",
            "Cache hits",
            "Warm starts",
            "Fast paths",
            "Pruned frac",
        ],
    );
    for (name, m) in [("cold (reuse off)", &cold), ("steady-state", &steady)] {
        t.row(vec![
            name.to_string(),
            format!("{:.3}", m.solver_p50_ms),
            format!("{:.3}", m.solver_p90_ms),
            m.cache_hits.to_string(),
            m.warm_starts.to_string(),
            m.fast_paths.to_string(),
            format!("{:.2}", m.pruned_frac),
        ]);
    }
    t.print();
    Ok(())
}

/// Table 2: NPUs ∈ {16, 32, 64} with GBS fixed at 512.
pub fn run_npus(args: &Args) -> Result<()> {
    let npus_list = args.usize_list_or("npus", &[16, 32, 64])?;
    let gbs = args.usize_or("gbs", 512)?;
    let (warmup, measure) = super::protocol_steps(args)?;
    let seed = args.u64_or("seed", 0x7AB2)?;
    let rows: Vec<OverheadRow> = npus_list
        .iter()
        .map(|&n| compute_row(gbs, n, warmup, measure, seed))
        .collect();
    print_table(
        &format!("Table 2: time consumption vs NPU count (GBS {gbs})"),
        "NPUs",
        &rows,
        |r| r.npus,
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solver_is_millisecond_scale_and_hidden() {
        // The paper's efficiency claims (Tables 1-2): solver <= ~100 ms,
        // scheduling time < computing time. Reduced GBS for test speed.
        let r = compute_row(128, 16, 0, 2, 5);
        assert!(
            r.solver_ms < 100.0,
            "solver took {} ms (paper: <= 86 ms)",
            r.solver_ms
        );
        assert!(r.schedule_ms >= r.solver_ms);
        assert!(
            r.schedule_ms / 1e3 < r.computing_s,
            "schedule {} ms vs compute {} s — not hideable",
            r.schedule_ms,
            r.computing_s
        );
        // The reuse claim: warm-pool reconfiguration must be a vanishing
        // fraction of the iteration.
        assert!(
            r.reconfig_ms / 1e3 < r.computing_s * 0.1,
            "reconfig {} ms vs compute {} s",
            r.reconfig_ms,
            r.computing_s
        );
        // Overlap-aware charging never exceeds the serial cost.
        assert!(
            r.reconfig_ms <= r.reconfig_serial_ms + 1e-9,
            "charged {} > serial {}",
            r.reconfig_ms,
            r.reconfig_serial_ms
        );
    }

    #[test]
    fn steady_state_stream_hits_the_cache_and_the_cold_twin_never_does() {
        // Tiny instance of the `reproduce overhead` comparison: a 6-step
        // correlated stream (steps 1-3 and 5 replay the base batch, step
        // 4 draws fresh) through a reuse-enabled scheduler vs a twin
        // with reuse forced off.
        let mut ctx = ExpContext::new(
            by_name("InternVL3-8B").unwrap(),
            DatasetKind::OpenVid,
            8,
            TrainStage::Full,
        );
        ctx.seed = 0x7AB3;
        let stream = correlated_stream(&ctx, 16, 6);
        let cold = reuse_stream_stats(&ctx.dhp().with_solver_reuse(false), &stream);
        let steady = reuse_stream_stats(&ctx.dhp(), &stream);
        assert_eq!(cold.cache_hits, 0, "reuse off must never probe: {cold:?}");
        assert_eq!(cold.warm_starts, 0);
        assert_eq!(
            steady.cache_hits, 4,
            "base-batch replays must be exact hits: {steady:?}"
        );
        // Step 4 is the only miss with a previous solve available; it
        // warm-starts iff the previous plan re-costs cleanly under the
        // fresh batch (positive warm-start coverage lives in the
        // schedule_cache property tests).
        assert!(
            steady.warm_starts <= 1,
            "only the one fresh batch may warm-start: {steady:?}"
        );
        assert_eq!(steady.fast_paths, 0, "ε fast path is opt-in");
        assert!((0.0..=1.0).contains(&steady.pruned_frac));
    }

    #[test]
    fn solver_time_grows_with_gbs() {
        let small = compute_row(32, 16, 0, 2, 6);
        let large = compute_row(256, 16, 0, 2, 6);
        assert!(
            large.solver_ms > small.solver_ms * 0.8,
            "solver should scale with GBS: {small:?} vs {large:?}"
        );
        assert!(large.computing_s > small.computing_s);
    }
}
