//! Tables 1 & 2 — time consumption of computing vs scheduling vs solver,
//! sweeping the global batch size (Table 1) and the NPU count (Table 2).
//! Schedule/solver times are REAL wall-clock of our solver; computing
//! time is the simulated cluster execution.

use anyhow::Result;

use crate::config::presets::by_name;
use crate::config::TrainStage;
use crate::data::datasets::DatasetKind;
use crate::report::Table;
use crate::util::cli::Args;

use super::harness::{run_policy, ExpContext};

/// One Table 1/2 row: where each iteration's time goes.
#[derive(Debug, Clone)]
pub struct OverheadRow {
    /// Global batch size of the row.
    pub gbs: usize,
    /// NPU count of the row.
    pub npus: usize,
    /// Mean simulated execution + grad-sync seconds per iteration.
    pub computing_s: f64,
    /// Mean measured scheduling-phase wall-clock (ms).
    pub schedule_ms: f64,
    /// Mean measured pure solver wall-clock (ms).
    pub solver_ms: f64,
    /// Mean group-reconfiguration time CHARGED per measured iteration:
    /// the pool-miss creation cost left after the prewarm overlap hid up
    /// to the previous step's compute — the paper claims this is
    /// negligible once the pool is warm, and now we measure it.
    pub reconfig_ms: f64,
    /// Mean fully-serial reconfiguration time (ms) — what the same run
    /// would pay without the CPU-side prewarm overlap (ablation column).
    pub reconfig_serial_ms: f64,
    /// Communication-group pool hit-rate over the measured window.
    pub pool_hit_rate: f64,
    /// Fraction of placed groups that replayed the previous step's rank
    /// block (hint quality: separates placement churn from data drift).
    pub replay_rate: f64,
    /// Pool evictions over the measured window (0 unless capacity-capped).
    pub evictions: u64,
}

/// One Table 1/2 row: run the DHP policy at (`gbs`, `npus`) through the
/// protocol and extract the overhead columns.
pub fn compute_row(
    gbs: usize,
    npus: usize,
    warmup: usize,
    measure: usize,
    seed: u64,
) -> OverheadRow {
    let mut ctx = ExpContext::new(
        by_name("InternVL3-8B").unwrap(),
        DatasetKind::OpenVid,
        npus,
        TrainStage::Full,
    )
    .with_gbs(gbs)
    .with_steps(warmup, measure);
    ctx.seed = seed;
    let dhp = ctx.dhp();
    let r = run_policy(&ctx, &dhp);
    OverheadRow {
        gbs,
        npus,
        // Pure execution + grad-sync: reconfiguration is reported in its
        // own column, so the Computing column stays comparable across
        // runs and the columns are additive.
        computing_s: r.mean_iter_s - r.mean_reconfig_s,
        schedule_ms: r.mean_schedule_s * 1e3,
        solver_ms: r.mean_solver_s * 1e3,
        reconfig_ms: r.mean_reconfig_s * 1e3,
        reconfig_serial_ms: r.mean_reconfig_serial_s * 1e3,
        pool_hit_rate: r.pool.hit_rate(),
        replay_rate: r.replay_rate,
        evictions: r.pool.evictions,
    }
}

fn print_table(title: &str, label: &str, rows: &[OverheadRow], key: impl Fn(&OverheadRow) -> usize) {
    let mut t = Table::new(
        title,
        &[
            label,
            "Computing Time (s)",
            "Schedule Time (ms)",
            "Solver Time (ms)",
            "Reconfig (ms)",
            "Serial (ms)",
            "Pool hit-rate",
            "Replay",
            "Evict",
        ],
    );
    for r in rows {
        t.row(vec![
            key(r).to_string(),
            format!("{:.2}", r.computing_s),
            format!("{:.0}", r.schedule_ms),
            format!("{:.1}", r.solver_ms),
            format!("{:.1}", r.reconfig_ms),
            format!("{:.1}", r.reconfig_serial_ms),
            format!("{:.2}", r.pool_hit_rate),
            format!("{:.2}", r.replay_rate),
            r.evictions.to_string(),
        ]);
    }
    t.print();
}

/// Table 1: GBS ∈ {128, 256, 512} at 64 NPUs.
pub fn run_gbs(args: &Args) -> Result<()> {
    let gbs_list = args.usize_list_or("gbs-list", &[128, 256, 512])?;
    let npus = args.usize_or("npus", 64)?;
    let (warmup, measure) = super::protocol_steps(args)?;
    let seed = args.u64_or("seed", 0x7AB1)?;
    let rows: Vec<OverheadRow> = gbs_list
        .iter()
        .map(|&g| compute_row(g, npus, warmup, measure, seed))
        .collect();
    print_table(
        &format!("Table 1: time consumption vs global batch size ({npus} NPUs)"),
        "GBS",
        &rows,
        |r| r.gbs,
    );
    for r in &rows {
        println!(
            "GBS {}: schedule/compute = {:.1}% (paper: scheduling always \
             hidden behind compute)",
            r.gbs,
            r.schedule_ms / 10.0 / r.computing_s
        );
    }
    Ok(())
}

/// Table 2: NPUs ∈ {16, 32, 64} with GBS fixed at 512.
pub fn run_npus(args: &Args) -> Result<()> {
    let npus_list = args.usize_list_or("npus", &[16, 32, 64])?;
    let gbs = args.usize_or("gbs", 512)?;
    let (warmup, measure) = super::protocol_steps(args)?;
    let seed = args.u64_or("seed", 0x7AB2)?;
    let rows: Vec<OverheadRow> = npus_list
        .iter()
        .map(|&n| compute_row(gbs, n, warmup, measure, seed))
        .collect();
    print_table(
        &format!("Table 2: time consumption vs NPU count (GBS {gbs})"),
        "NPUs",
        &rows,
        |r| r.npus,
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solver_is_millisecond_scale_and_hidden() {
        // The paper's efficiency claims (Tables 1-2): solver <= ~100 ms,
        // scheduling time < computing time. Reduced GBS for test speed.
        let r = compute_row(128, 16, 0, 2, 5);
        assert!(
            r.solver_ms < 100.0,
            "solver took {} ms (paper: <= 86 ms)",
            r.solver_ms
        );
        assert!(r.schedule_ms >= r.solver_ms);
        assert!(
            r.schedule_ms / 1e3 < r.computing_s,
            "schedule {} ms vs compute {} s — not hideable",
            r.schedule_ms,
            r.computing_s
        );
        // The reuse claim: warm-pool reconfiguration must be a vanishing
        // fraction of the iteration.
        assert!(
            r.reconfig_ms / 1e3 < r.computing_s * 0.1,
            "reconfig {} ms vs compute {} s",
            r.reconfig_ms,
            r.computing_s
        );
        // Overlap-aware charging never exceeds the serial cost.
        assert!(
            r.reconfig_ms <= r.reconfig_serial_ms + 1e-9,
            "charged {} > serial {}",
            r.reconfig_ms,
            r.reconfig_serial_ms
        );
    }

    #[test]
    fn solver_time_grows_with_gbs() {
        let small = compute_row(32, 16, 0, 2, 6);
        let large = compute_row(256, 16, 0, 2, 6);
        assert!(
            large.solver_ms > small.solver_ms * 0.8,
            "solver should scale with GBS: {small:?} vs {large:?}"
        );
        assert!(large.computing_s > small.computing_s);
    }
}
