//! Resilience under injected faults: goodput vs failure rate (MTBF
//! sweep) for DHP and the static baselines, all through the same
//! [`crate::session::DhpSession`] machinery.
//!
//! The question this experiment answers is the MegaScale-Omni one
//! (PAPERS.md): production MLLM training is gated by *workload
//! resilience*, not steady-state throughput. DHP's per-batch re-solve
//! means a rank failure shrinks the mesh and the very next schedule
//! runs on the survivors; a static grid sized for the full mesh can
//! only report a typed failed step ([`crate::baselines::ScheduleError`])
//! and retry at full strength once the repair lands. Goodput — useful
//! steps per simulated second, net of recovery, checkpoint, and
//! failed-step penalties — is the honest summary of that difference.

use anyhow::Result;

use crate::baselines::SchedulePolicy;
use crate::cluster::{FaultConfig, FaultInjector};
use crate::config::presets::by_name;
use crate::config::TrainStage;
use crate::data::datasets::DatasetKind;
use crate::report::Table;
use crate::util::cli::Args;

use super::harness::{flexsp, ExpContext};
use super::PolicySet;

/// One (policy, MTBF) cell of the resilience sweep.
#[derive(Debug, Clone)]
pub struct ResilienceRow {
    /// Policy display name.
    pub policy: String,
    /// Mean steps between rank failures (0 = fault-free reference).
    pub mtbf_steps: f64,
    /// Steps that executed and made training progress.
    pub useful_steps: usize,
    /// Steps that ended in a typed schedule failure (no progress).
    pub failed_steps: usize,
    /// Total simulated seconds the run consumed (iterations + recovery
    /// + checkpoints + failed-step stalls).
    pub total_time_s: f64,
    /// Total recovery seconds charged (restores, re-warms, lost work).
    pub recovery_s: f64,
    /// Total straggle inflation attributed across the run's waves.
    pub straggle_s: f64,
    /// Useful steps per total simulated second — the headline metric.
    pub goodput_steps_per_s: f64,
    /// Order-sensitive fold of the per-step report digests: two runs of
    /// the same (ctx, policy, fault seed) must match bit-for-bit, and a
    /// quiet config must match an injector-free session exactly.
    pub digest: u64,
    /// True when the cell executed on the discrete-event kernel
    /// (faults land mid-step at their arrival fraction); false for the
    /// step-granular reference path (faults land at step boundaries).
    pub within_step: bool,
    /// Total lost work charged (re-executed wave fractions on the event
    /// path; whole `work_since_ckpt` replays on the boundary path).
    pub lost_work_s: f64,
}

/// Run `policy` for `steps` steps under `cfg`'s fault trace, entirely
/// through the session façade. A failed step (static baseline on a
/// shrunken mesh) makes no progress but still burns wall-clock: the
/// cluster stalls for roughly one iteration (the last successful step's
/// span) plus whatever the fault boundary charged.
pub fn run_policy_under_faults(
    ctx: &ExpContext,
    policy: &dyn SchedulePolicy,
    cfg: FaultConfig,
    steps: usize,
) -> ResilienceRow {
    run_policy_mode(ctx, policy, cfg, steps, false)
}

/// [`run_policy_under_faults`] on the discrete-event kernel: the same
/// protocol with `within_step_faults(true)`, so each fault lands at its
/// within-step arrival fraction and only the interrupted partial wave
/// is re-executed (vs the boundary path's whole-step replay).
pub fn run_policy_under_faults_within_step(
    ctx: &ExpContext,
    policy: &dyn SchedulePolicy,
    cfg: FaultConfig,
    steps: usize,
) -> ResilienceRow {
    run_policy_mode(ctx, policy, cfg, steps, true)
}

fn run_policy_mode(
    ctx: &ExpContext,
    policy: &dyn SchedulePolicy,
    cfg: FaultConfig,
    steps: usize,
    within_step: bool,
) -> ResilienceRow {
    let mut session = ctx
        .session_builder_for(policy.clone_policy())
        .fault_injector(FaultInjector::new(ctx.replicas(), cfg))
        .within_step_faults(within_step)
        .build();
    let mut sampler = ctx.sampler();
    let mut useful = 0usize;
    let mut failed = 0usize;
    let mut total_time_s = 0.0;
    let mut recovery_s = 0.0;
    let mut straggle_s = 0.0;
    let mut lost_work_s = 0.0;
    let mut digest: u64 = 0;
    let mut last_iter_s = 0.0;
    for _ in 0..steps {
        let report = session.step(&sampler.sample_batch(ctx.gbs));
        digest = digest.rotate_left(1) ^ report.digest();
        recovery_s += report.recovery_time_s;
        straggle_s += report.iteration.straggle_s;
        lost_work_s += report.lost_work_s;
        if report.failed.is_some() {
            failed += 1;
            total_time_s +=
                last_iter_s + report.recovery_time_s + report.checkpoint_time_s;
        } else {
            useful += 1;
            last_iter_s = report.iteration.iter_time_s;
            total_time_s += report.total_time_s();
        }
    }
    ResilienceRow {
        policy: session.policy_name().to_string(),
        mtbf_steps: cfg.mtbf_steps,
        useful_steps: useful,
        failed_steps: failed,
        total_time_s,
        recovery_s,
        straggle_s,
        goodput_steps_per_s: if total_time_s > 0.0 {
            useful as f64 / total_time_s
        } else {
            0.0
        },
        digest,
        within_step,
        lost_work_s,
    }
}

/// Sweep goodput over `mtbfs` (0 = fault-free) for DHP and all three
/// baselines (tuned per the paper's protocol), plus a DHP cell on the
/// discrete-event kernel at each MTBF. Every policy sees the SAME fault
/// trace at each MTBF (same seed), so cells differ only in how the
/// policy absorbs the faults — and, for the two DHP cells, in whether
/// faults land mid-wave or at the step boundary.
pub fn compute(
    ctx: &ExpContext,
    mtbfs: &[f64],
    steps: usize,
    seed: u64,
) -> Vec<ResilienceRow> {
    let set = PolicySet::build(ctx);
    let flex = flexsp(ctx);
    let policies: [&dyn SchedulePolicy; 4] =
        [&set.dhp, &set.megatron, &set.deepspeed, &flex];
    let mut rows = Vec::new();
    for &mtbf in mtbfs {
        let cfg = if mtbf <= 0.0 {
            FaultConfig::quiet(seed)
        } else {
            FaultConfig::mtbf(mtbf, seed)
        };
        for policy in policies {
            rows.push(run_policy_under_faults(ctx, policy, cfg, steps));
        }
        rows.push(run_policy_under_faults_within_step(
            ctx, &set.dhp, cfg, steps,
        ));
    }
    rows
}

/// `dhp reproduce resilience` entry point.
pub fn run(args: &Args) -> Result<()> {
    let npus = args.usize_or("npus", 32)?;
    let gbs = args.usize_or("gbs", 64)?;
    let steps = args.usize_or("steps", 30)?;
    let seed = args.u64_or("seed", 0xFA17)?;
    let mut ctx = ExpContext::new(
        by_name("InternVL3-8B").unwrap(),
        DatasetKind::OpenVid,
        npus,
        TrainStage::Full,
    )
    .with_gbs(gbs);
    ctx.seed = seed;
    let mtbfs = [0.0, 50.0, 20.0, 8.0];
    let rows = compute(&ctx, &mtbfs, steps, seed);

    let mut t = Table::new(
        &format!(
            "Resilience: goodput vs MTBF ({npus} NPUs, {steps} steps, gbs {gbs})"
        ),
        &[
            "MTBF (steps)",
            "policy",
            "faults",
            "useful",
            "failed",
            "recovery (s)",
            "lost work (s)",
            "goodput (steps/s)",
        ],
    );
    for r in &rows {
        t.row(vec![
            if r.mtbf_steps <= 0.0 {
                "none".to_string()
            } else {
                format!("{:.0}", r.mtbf_steps)
            },
            r.policy.clone(),
            if r.within_step { "mid-wave" } else { "boundary" }.to_string(),
            r.useful_steps.to_string(),
            r.failed_steps.to_string(),
            format!("{:.1}", r.recovery_s),
            format!("{:.1}", r.lost_work_s),
            format!("{:.4}", r.goodput_steps_per_s),
        ]);
    }
    t.print();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::FaultEvent;

    fn test_ctx() -> ExpContext {
        let mut ctx = ExpContext::new(
            by_name("InternVL3-2B").unwrap(),
            DatasetKind::OpenVid,
            16,
            TrainStage::Full,
        )
        .with_gbs(24);
        ctx.seed = 0x5EED;
        ctx
    }

    #[test]
    fn same_seed_is_bit_identical() {
        let ctx = test_ctx();
        let a = compute(&ctx, &[0.0, 6.0], 5, 11);
        let b = compute(&ctx, &[0.0, 6.0], 5, 11);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(
                x.digest, y.digest,
                "{} at MTBF {} must replay bit-identically",
                x.policy, x.mtbf_steps
            );
            assert_eq!(
                x.goodput_steps_per_s.to_bits(),
                y.goodput_steps_per_s.to_bits()
            );
        }
    }

    #[test]
    fn quiet_sweep_matches_injector_free_sessions() {
        let ctx = test_ctx();
        let dhp = ctx.dhp();
        let faulted =
            run_policy_under_faults(&ctx, &dhp, FaultConfig::quiet(3), 4);
        assert_eq!(faulted.failed_steps, 0);
        assert_eq!(faulted.recovery_s, 0.0);
        // The same protocol with no injector installed at all.
        let mut session = ctx.session_for(dhp.clone_policy());
        let mut sampler = ctx.sampler();
        let mut digest: u64 = 0;
        for _ in 0..4 {
            let report = session.step(&sampler.sample_batch(ctx.gbs));
            digest = digest.rotate_left(1) ^ report.digest();
        }
        assert_eq!(
            faulted.digest, digest,
            "a quiet injector must be zero-drift vs no injector"
        );
    }

    #[test]
    fn within_step_quiet_matches_the_step_granular_cell() {
        // The event-kernel cell under a quiet injector must be
        // digest-identical to the boundary cell: the discrete-event
        // execution is a pure re-ordering of the same arithmetic.
        let ctx = test_ctx();
        let dhp = ctx.dhp();
        let ev = run_policy_under_faults_within_step(
            &ctx,
            &dhp,
            FaultConfig::quiet(3),
            4,
        );
        let st = run_policy_under_faults(&ctx, &dhp, FaultConfig::quiet(3), 4);
        assert!(ev.within_step && !st.within_step);
        assert_eq!(
            ev.digest, st.digest,
            "quiet event-kernel cell drifted from the step-granular cell"
        );
        assert_eq!(ev.lost_work_s, 0.0, "quiet run charged lost work");
        assert_eq!(
            ev.goodput_steps_per_s.to_bits(),
            st.goodput_steps_per_s.to_bits()
        );
    }

    #[test]
    fn dhp_survives_where_the_static_grid_fails_typed() {
        let ctx = test_ctx();
        let steps = 12usize;
        // Deterministically pick a seed whose trace actually fails a
        // rank inside the window (seeded draws, so this scan is stable).
        let seed = (0..64u64)
            .find(|&s| {
                let mut inj = FaultInjector::new(
                    ctx.replicas(),
                    FaultConfig::mtbf(4.0, s),
                );
                (0..steps as u64).flat_map(|step| inj.advance(step)).any(
                    |ev| matches!(ev, FaultEvent::RankFailure { .. }),
                )
            })
            .expect("some seed under MTBF 4 must fail within the window");
        let cfg = FaultConfig::mtbf(4.0, seed);
        let set = PolicySet::build(&ctx);

        let dhp = run_policy_under_faults(&ctx, &set.dhp, cfg, steps);
        assert_eq!(dhp.failed_steps, 0, "DHP must re-solve on survivors");
        assert_eq!(dhp.useful_steps, steps);
        assert!(dhp.recovery_s > 0.0, "failures must charge recovery");

        let mega = run_policy_under_faults(&ctx, &set.megatron, cfg, steps);
        assert!(
            mega.failed_steps > 0,
            "the static grid must report typed failed steps"
        );
        assert_eq!(mega.useful_steps + mega.failed_steps, steps);
        assert!(
            dhp.goodput_steps_per_s > mega.goodput_steps_per_s,
            "DHP goodput {} must beat the failing static grid's {}",
            dhp.goodput_steps_per_s,
            mega.goodput_steps_per_s
        );
    }
}
