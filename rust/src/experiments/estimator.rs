//! Table 3 — estimation error of the cost estimator across model families
//! and scales: the Profiler fits Eq. 8's coefficients from degree-1
//! calibration measurements (as the paper's Profiler does before
//! training), then predictions at swept (workload, degree) points are
//! compared against the simulator's first-principles ground truth.

use anyhow::Result;

use crate::config::presets::{by_name, ModelPreset};
use crate::config::TrainStage;
use crate::cost::profiler::{fit_compute_with, Sample};
use crate::cost::{exact, CostCoeffs, CostModel, HardwareSpec, WorkloadAgg};
use crate::data::datasets::{DatasetKind, DatasetSampler};
use crate::data::sequence::Sequence;
use crate::report::Table;
use crate::util::cli::Args;

use super::harness::experiment_tokenizer;

/// One Table 3 row: estimator error for one model preset.
#[derive(Debug, Clone)]
pub struct EstimatorRow {
    /// Model family ("InternVL3" / "Qwen3VL").
    pub family: &'static str,
    /// Parameter-count label ("2B"…"8B").
    pub size: &'static str,
    /// Full preset name.
    pub model: &'static str,
    /// Mean absolute percentage error (%) — Table 3's metric.
    pub error_pct: f64,
}

/// Calibrate then evaluate one model preset.
pub fn evaluate_preset(preset: &ModelPreset, seed: u64) -> f64 {
    let hw = HardwareSpec::default();
    let stage = TrainStage::Full;
    let bw = 12.5e9;

    // --- Calibration phase (the paper's pre-training profile run):
    // degree-1 executions swept over BOTH sequence length and attention
    // mask mix (vision fraction → η), exactly what the paper's Profiler
    // does by "constructing data of different lengths" — covering the η
    // range keeps the single-α₁ folding honest on vision-heavy batches.
    let mut cal_samples = Vec::new();
    for &l in &[512u64, 1024, 2048, 4096, 8192, 16384, 32768] {
        for &fv in &[0.8f64, 0.9, 0.95] {
            let lv = ((l as f64) * fv) as u64;
            let s = Sequence::new(0, lv, l - lv);
            let t = exact::group_time(preset, stage, &hw, &[s.clone()], 1, bw);
            cal_samples.push(Sample {
                seq_len: l,
                quad: (1.0 + s.eta()) * (l as f64) * (l as f64),
                degree: 1,
                time_s: t,
            });
        }
    }
    let analytic = CostCoeffs::analytic(preset, stage, &hw);
    let fitted = fit_compute_with(&cal_samples, analytic).expect("fit");
    let cost = CostModel {
        coeffs: fitted,
        memory: crate::cost::MemoryModel::new(preset, 64e9, 64),
    };

    // --- Evaluation phase: realistic grouped workloads at varied degrees.
    let mut sampler =
        DatasetSampler::new(DatasetKind::OpenVid, seed).with_spec(experiment_tokenizer());
    let mut errs = Vec::new();
    for trial in 0..40 {
        let k = 1 + (trial % 4);
        let seqs = sampler.sample_batch(k);
        let agg = WorkloadAgg::of(&seqs);
        for d in [1usize, 2, 3, 4, 6, 8] {
            let truth = exact::group_time(preset, stage, &hw, &seqs, d, bw);
            let est = cost.t_total(&agg, d, bw);
            errs.push(((est - truth) / truth).abs() * 100.0);
        }
    }
    crate::util::stats::mean(&errs)
}

/// Evaluate estimator error for all six presets.
pub fn compute(seed: u64) -> Vec<EstimatorRow> {
    let specs = [
        ("Qwen3VL", "2B", "Qwen3VL-2B"),
        ("Qwen3VL", "4B", "Qwen3VL-4B"),
        ("Qwen3VL", "8B", "Qwen3VL-8B"),
        ("InternVL3", "2B", "InternVL3-2B"),
        ("InternVL3", "4B", "InternVL2.5-4B"),
        ("InternVL3", "8B", "InternVL3-8B"),
    ];
    specs
        .iter()
        .map(|&(family, size, model)| EstimatorRow {
            family,
            size,
            model,
            error_pct: evaluate_preset(&by_name(model).unwrap(), seed),
        })
        .collect()
}

/// `dhp reproduce tab3` entry point.
pub fn run(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 0x7AB3)?;
    let rows = compute(seed);
    let mut t = Table::new(
        "Table 3: time-cost estimation error (%)",
        &["Model", "2B", "4B", "8B"],
    );
    for family in ["Qwen3VL", "InternVL3"] {
        let get = |size: &str| {
            rows.iter()
                .find(|r| r.family == family && r.size == size)
                .map(|r| format!("{:.2}", r.error_pct))
                .unwrap_or_default()
        };
        t.row(vec![
            family.to_string(),
            get("2B"),
            get("4B"),
            get("8B"),
        ]);
    }
    t.print();
    println!(
        "paper: 4.1-7.9%, decreasing with model size; discrepancies below 8%"
    );
    Ok(())
}

/// Profile-based variant over the REAL PJRT runtime (used by the
/// `profile_real` example and tab3 bench when artifacts exist): fits the
/// coefficients from actual CPU executions of the AOT model.
pub fn fit_from_runtime(
    artifacts_dir: &std::path::Path,
    reps: usize,
) -> Result<(crate::cost::CostCoeffs, crate::cost::profiler::FitReport)> {
    use crate::runtime::Runtime;
    let rt = Runtime::cpu()?;
    let manifest = crate::runtime::Manifest::load(artifacts_dir)?;
    let params = crate::runtime::load_params(&artifacts_dir.join("prof_params.f32"))?;
    let mut samples = Vec::new();
    for (file, meta) in manifest.sweep("prof_fwd_") {
        let model = rt.load_with_meta(artifacts_dir, &file, meta.clone())?;
        // Warmup once, then take the median of `reps`.
        model.time_execution(&params)?;
        let times: Vec<f64> = (0..reps.max(1))
            .map(|_| model.time_execution(&params))
            .collect::<Result<_>>()?;
        let l = meta.seq_total as u64;
        let eta = {
            let s = Sequence::new(0, meta.seq_vision as u64, meta.seq_text as u64);
            s.eta()
        };
        samples.push(Sample {
            seq_len: l,
            quad: (1.0 + eta) * (l as f64) * (l as f64),
            degree: 1,
            time_s: crate::util::stats::median(&times),
        });
    }
    let base = CostCoeffs::analytic(
        &by_name("InternVL3-2B").unwrap(),
        TrainStage::Full,
        &HardwareSpec::default(),
    );
    let fitted = fit_compute_with(&samples, base)?;
    let report = crate::cost::profiler::fit_error(&fitted, &samples);
    Ok((fitted, report))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_errors_within_paper_band() {
        let rows = compute(11);
        assert_eq!(rows.len(), 6);
        for r in &rows {
            assert!(
                r.error_pct < 10.0,
                "{}: {:.2}% (paper keeps all below 8%)",
                r.model,
                r.error_pct
            );
            assert!(r.error_pct > 0.0);
        }
    }

    #[test]
    fn errors_stable_across_families_and_sizes() {
        // Paper Table 3 reports 4.1-7.9%; the exact per-size ordering is
        // hardware-dependent (our calibrated estimator lands at 2-5%).
        // Assert every family/size stays within the paper's <8% band and
        // families do not diverge wildly from each other.
        let rows = compute(13);
        let max = rows.iter().map(|r| r.error_pct).fold(0.0f64, f64::max);
        let min = rows.iter().map(|r| r.error_pct).fold(f64::MAX, f64::min);
        assert!(max < 8.0, "max error {max:.2}% breaches the paper band");
        assert!(min > 0.0);
        assert!(max / min < 5.0, "family errors diverge: {rows:?}");
    }
}
