//! Fig. 1 — data distributions of MSRVTT, InternVid, OpenVid: duration
//! histograms over the paper's buckets, plus skew diagnostics.

use anyhow::Result;

use crate::data::datasets::{DatasetKind, DatasetSampler};
use crate::data::distribution::{tail_ratio, Histogram};
use crate::report::Table;
use crate::util::cli::Args;

/// One dataset's distribution summary.
#[derive(Debug, Clone)]
pub struct DistRow {
    /// Dataset display name.
    pub dataset: &'static str,
    /// Mass fraction per Fig. 1 duration bucket.
    pub fractions: Vec<f64>,
    /// Mean/median duration ratio (≫ 1 ⇒ long tail).
    pub tail_ratio: f64,
    /// Mean duration (seconds).
    pub mean_s: f64,
    /// 95th-percentile duration (seconds).
    pub p95_s: f64,
}

/// Sample each corpus and summarize its duration distribution.
pub fn compute(samples: usize, seed: u64) -> Vec<DistRow> {
    DatasetKind::all()
        .iter()
        .map(|&kind| {
            let mut sampler = DatasetSampler::new(kind, seed);
            let durations: Vec<f64> = sampler
                .sample_batch(samples)
                .iter()
                .map(|s| s.duration_s)
                .collect();
            let mut h = Histogram::fig1_buckets();
            h.add_all(&durations);
            DistRow {
                dataset: kind.name(),
                fractions: h.fractions(),
                tail_ratio: tail_ratio(&durations),
                mean_s: crate::util::stats::mean(&durations),
                p95_s: crate::util::stats::percentile(&durations, 95.0),
            }
        })
        .collect()
}

/// `dhp reproduce fig1` entry point.
pub fn run(args: &Args) -> Result<()> {
    let samples = args.usize_or("samples", 10_000)?;
    let seed = args.u64_or("seed", 0xF161)?;
    let rows = compute(samples, seed);
    let labels = Histogram::fig1_buckets().labels();
    let mut headers: Vec<&str> = vec!["Dataset"];
    let label_refs: Vec<String> = labels;
    for l in &label_refs {
        headers.push(l);
    }
    headers.extend_from_slice(&["mean(s)", "p95(s)", "mean/med"]);
    let mut t = Table::new(
        &format!("Fig. 1: duration distributions ({samples} samples/dataset)"),
        &headers,
    );
    for r in &rows {
        let mut cells = vec![r.dataset.to_string()];
        cells.extend(r.fractions.iter().map(|f| format!("{:.1}%", f * 100.0)));
        cells.push(format!("{:.1}", r.mean_s));
        cells.push(format!("{:.1}", r.p95_s));
        cells.push(format!("{:.2}", r.tail_ratio));
        t.row(cells);
    }
    t.print();
    println!(
        "shape check: OpenVid most skewed (paper: 'long-tailed and highly \
         diverse'), MSRVTT most uniform"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_shape_holds() {
        let rows = compute(8000, 1);
        assert_eq!(rows.len(), 3);
        let by_name = |n: &str| rows.iter().find(|r| r.dataset == n).unwrap();
        let msrvtt = by_name("MSRVTT");
        let openvid = by_name("OpenVid");
        // Paper Fig. 1: OpenVid mass concentrated under 8 s with a tail
        // past 64 s; MSRVTT has NO mass under 8 s and none past 64 s.
        let under8 = |r: &DistRow| r.fractions[0] + r.fractions[1] + r.fractions[2];
        assert!(under8(openvid) > 0.5);
        assert!(under8(msrvtt) < 0.01);
        assert!(openvid.fractions[6] > 0.0);
        assert!(msrvtt.fractions[6] < 1e-9);
        // Skew ordering.
        assert!(openvid.tail_ratio > msrvtt.tail_ratio);
    }
}
