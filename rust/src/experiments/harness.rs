//! Shared experiment harness: constructs the cost model / scheduler /
//! baselines for a (model, dataset, cluster, stage) context and runs
//! measured training iterations through the [`crate::session::DhpSession`]
//! façade, following the paper's protocol (tune baselines, warm up 5
//! steps, average 10). Every policy — DHP and the static baselines —
//! executes through the SAME session machinery, so results differ only
//! in scheduling decisions.

use crate::baselines::{
    DeepSpeedUlysses, FlexSp, MegatronStaticCp, SchedulePolicy,
};
use crate::cluster::ClusterSim;
use crate::config::presets::ModelPreset;
use crate::config::{ClusterConfig, TrainStage};
use crate::cost::{CostCoeffs, CostModel, HardwareSpec, MemoryModel};
use crate::data::batch::{GlobalBatch, MicroBatchPlanner};
use crate::data::datasets::{DatasetKind, DatasetSampler, TokenizerSpec};
use crate::data::sequence::Sequence;
use crate::parallel::mesh::DeviceMesh;
use crate::scheduler::{Schedule, Scheduler};
use crate::session::{DhpSession, SessionBuilder};
use crate::util::stats;

pub use crate::session::{dispatch, DispatchEntry};

/// High-resolution video tokenization used by the cluster experiments
/// (the paper targets high-res long-context MLLM training): 2 fps ×
/// 256 tokens/frame — an 8 s clip ⇒ 4096 vision tokens.
pub fn experiment_tokenizer() -> TokenizerSpec {
    TokenizerSpec {
        fps: 2.0,
        tokens_per_frame: 256.0,
        text_min: 32,
        text_max: 512,
    }
}

/// One experimental configuration.
#[derive(Debug, Clone)]
pub struct ExpContext {
    /// Model under test (paper Table 5 preset).
    pub preset: ModelPreset,
    /// Workload distribution sequences are drawn from.
    pub dataset: DatasetKind,
    /// Cluster topology (nodes × NPUs, TP/PP grid, fabrics).
    pub cluster: ClusterConfig,
    /// Which parameters train (full vs frozen-vision).
    pub stage: TrainStage,
    /// Global batch size.
    pub gbs: usize,
    /// Sampler seed (fixed per experiment for reproducibility).
    pub seed: u64,
    /// Steps excluded from measurement (paper protocol: 5).
    pub warmup_steps: usize,
    /// Steps averaged into the reported numbers (paper protocol: 10).
    pub measure_steps: usize,
    /// Communication-group pool budget for the run (default unbounded —
    /// the seed behavior; cap it to measure where the paper's
    /// near-free-reconfiguration claim breaks down).
    pub pool_capacity: crate::parallel::PoolCapacity,
}

impl ExpContext {
    /// Paper-protocol context: TP=2 × PP=2 static grid, GBS 512, 5 warmup
    /// + 10 measured steps, unbounded group pool.
    pub fn new(
        preset: ModelPreset,
        dataset: DatasetKind,
        npus: usize,
        stage: TrainStage,
    ) -> Self {
        // The paper treats TP and PP as predefined static configurations
        // (§4.1); TP=2 × PP=2 is the standard Megatron grid for 2B–8B
        // models on 64 GB devices with long contexts. One replica = 4
        // NPUs, so CP rings above degree 2 cross node boundaries — the
        // regime where the static/dynamic mesh difference matters.
        let mut cluster = ClusterConfig::default().with_npus(npus);
        cluster.tp = 2;
        cluster.pp = 2;
        ExpContext {
            preset,
            dataset,
            cluster,
            stage,
            gbs: 512,
            seed: 0xD4B,
            warmup_steps: 5,
            measure_steps: 10,
            pool_capacity: crate::parallel::PoolCapacity::Unbounded,
        }
    }

    /// Context from a parsed run configuration (the TOML
    /// `[train]`/`[cluster]` file format): model, dataset, cluster
    /// topology, stage, batch size, protocol steps, and the session's
    /// pool budget (`pool_cap_groups` / `pool_cap_buffer_mb`) all flow
    /// through to the sessions this context builds.
    pub fn from_train_config(cfg: &crate::config::TrainConfig) -> Self {
        ExpContext {
            preset: cfg.model.clone(),
            dataset: cfg.dataset,
            cluster: cfg.cluster.clone(),
            stage: cfg.stage,
            gbs: cfg.gbs,
            seed: cfg.seed,
            warmup_steps: cfg.warmup_steps,
            measure_steps: cfg.measure_steps,
            pool_capacity: cfg.pool_capacity,
        }
    }

    /// Override the global batch size.
    pub fn with_gbs(mut self, gbs: usize) -> Self {
        self.gbs = gbs;
        self
    }

    /// Override the warmup/measured step counts.
    pub fn with_steps(mut self, warmup: usize, measure: usize) -> Self {
        self.warmup_steps = warmup;
        self.measure_steps = measure;
        self
    }

    /// Bound the run's communication-group pool (LRU eviction on
    /// overflow; see [`crate::parallel::PoolCapacity`]).
    pub fn with_pool_capacity(
        mut self,
        capacity: crate::parallel::PoolCapacity,
    ) -> Self {
        self.pool_capacity = capacity;
        self
    }

    /// Model replicas in the cluster (one replica = one TP×PP grid).
    pub fn replicas(&self) -> usize {
        self.cluster.replicas()
    }

    /// Eq. 7 memory model for this context (ZeRO-3 across all replicas).
    /// One "rank" is a full TP×PP replica. TP shards activations, so the
    /// activation budget aggregates across TP members; PP does NOT help —
    /// each pipeline stage must hold activations for its in-flight
    /// micro-batches, so the per-token budget stays per-stage.
    pub fn memory(&self) -> MemoryModel {
        MemoryModel::new(
            &self.preset,
            self.cluster.mem_bytes as f64 * self.cluster.tp as f64,
            self.replicas(),
        )
    }

    /// Per-replica hardware spec: a replica aggregates TP×PP NPUs' FLOPs.
    pub fn hw(&self) -> HardwareSpec {
        let tpp = (self.cluster.tp * self.cluster.pp) as f64;
        HardwareSpec {
            peak_flops: 376e12 * tpp,
            ..HardwareSpec::default()
        }
    }

    /// The scheduler's parametric cost model. As in the paper (§5,
    /// implementation detail 3), the Profiler CALIBRATES the Eq. 8
    /// coefficients against measured degree-1 executions before training
    /// — here the measurement substrate is the cluster simulator's
    /// first-principles model (the stand-in for real NPU runs; see
    /// `estimator::fit_from_runtime` for the real-PJRT variant).
    pub fn cost_model(&self) -> CostModel {
        let hw = self.hw();
        let analytic = CostCoeffs::analytic(&self.preset, self.stage, &hw);
        let mut samples = Vec::new();
        for &l in &[512u64, 1024, 2048, 4096, 8192, 16384, 32768] {
            for &fv in &[0.8f64, 0.9, 0.95] {
                let lv = ((l as f64) * fv) as u64;
                let s = crate::data::sequence::Sequence::new(0, lv, l - lv);
                let t = crate::cost::exact::group_time(
                    &self.preset,
                    self.stage,
                    &hw,
                    std::slice::from_ref(&s),
                    1,
                    self.cluster.inter_bw,
                );
                samples.push(crate::cost::profiler::Sample {
                    seq_len: l,
                    quad: (1.0 + s.eta()) * (l as f64) * (l as f64),
                    degree: 1,
                    time_s: t,
                });
            }
        }
        let coeffs = crate::cost::profiler::fit_compute_with(&samples, analytic)
            .expect("profiler calibration");
        CostModel {
            coeffs,
            memory: self.memory(),
        }
    }

    /// Physical replica topology of the context's cluster.
    ///
    /// NOTE: builds a FRESH mesh each call — occupancy marked on one
    /// returned copy is invisible to the next. Cross-step state (mesh
    /// occupancy, placement hints, the group pool) has exactly one owner:
    /// the session returned by [`ExpContext::session`].
    pub fn mesh(&self) -> DeviceMesh {
        DeviceMesh::new(&self.cluster)
    }

    /// A fresh cluster simulator for this context (stateless; see the
    /// [`ExpContext::mesh`] note — training runs go through
    /// [`ExpContext::session`]).
    pub fn sim(&self) -> ClusterSim {
        ClusterSim::new(self.preset.clone(), self.stage, self.cluster.clone())
    }

    /// The context's dataset sampler (high-res video tokenization).
    pub fn sampler(&self) -> DatasetSampler {
        DatasetSampler::new(self.dataset, self.seed)
            .with_spec(experiment_tokenizer())
    }

    /// A fresh DHP scheduler with a calibrated cost model. One-shot
    /// diagnostics only: each call starts with empty placement memory,
    /// so cross-step `PlacementHint` continuity needs the ONE scheduler
    /// a [`ExpContext::session`] owns.
    pub fn dhp(&self) -> Scheduler {
        Scheduler::new(self.cost_model(), self.mesh())
    }

    /// Micro-batch planner bound to this context's memory budget.
    pub fn micro_batch_planner(&self) -> MicroBatchPlanner {
        let mem = self.memory();
        MicroBatchPlanner::new(self.replicas(), mem.rank_budget(), mem.m_token)
    }

    /// The one-owner façade for this context: a [`DhpSession`] wrapping
    /// `policy` with the context's mesh, simulator, micro-batch planner,
    /// and pool budget. All cross-step state — mesh occupancy, placement
    /// hints, the communication-group pool — lives inside the returned
    /// session (the accessors above hand out fresh, stateless builders).
    pub fn session_for(&self, policy: Box<dyn SchedulePolicy>) -> DhpSession {
        self.session_builder_for(policy).build()
    }

    /// The builder behind [`ExpContext::session_for`], for callers that
    /// need extra session knobs before `build()` — the resilience bench
    /// installs its [`crate::cluster::FaultInjector`] here.
    pub fn session_builder_for(
        &self,
        policy: Box<dyn SchedulePolicy>,
    ) -> SessionBuilder {
        DhpSession::builder(policy, self.sim())
            .pool_capacity(self.pool_capacity)
            .group_buffer_bytes(self.cluster.group_buffer_bytes)
            .micro_batch_planner(self.micro_batch_planner())
    }

    /// [`ExpContext::session_for`] with the context's DHP scheduler.
    pub fn session(&self) -> DhpSession {
        self.session_for(Box::new(self.dhp()))
    }
}

/// Per-policy measurement over the protocol's step window.
#[derive(Debug, Clone)]
pub struct PolicyResult {
    /// Policy display name ("DHP", "Megatron-CP", …).
    pub name: String,
    /// Mean end-to-end iteration seconds (primary Figs. 4/6 metric) —
    /// includes any non-hidden reconfiguration time actually charged.
    pub mean_iter_s: f64,
    /// Cluster token throughput in tokens/s (Fig. 5 metric).
    pub tokens_per_s: f64,
    /// Per-NPU token throughput.
    pub tokens_per_s_per_device: f64,
    /// Mean measured full scheduling-phase seconds (Tables 1–2).
    pub mean_schedule_s: f64,
    /// Mean measured pure solver seconds.
    pub mean_solver_s: f64,
    /// Mean CHARGED group-reconfiguration seconds per measured iteration:
    /// the pool-miss creation cost left over after the prewarm overlap
    /// hid up to the previous step's compute
    /// (`max(0, serial − prev_compute)`; ~0 once the pool is warm).
    pub mean_reconfig_s: f64,
    /// Mean fully-serial reconfiguration seconds per measured iteration
    /// (what a system without the CPU-side prewarm overlap would pay) —
    /// the overlap-ablation reference. `mean_reconfig_s ≤` this always.
    pub mean_reconfig_serial_s: f64,
    /// Per-measured-iteration `(charged, serial)` reconfiguration seconds
    /// — the `charged ≤ serial` invariant is testable per iteration, and
    /// the capacity-sweep ablation plots the full series.
    pub reconfig_per_iter_s: Vec<(f64, f64)>,
    /// Hint-quality telemetry: fraction of placed groups over the
    /// measured window that replayed their previous step's rank block.
    /// Low replay + low hit-rate ⇒ placement churn; high replay + low
    /// hit-rate ⇒ genuine workload drift.
    pub replay_rate: f64,
    /// Degrees used across the run (Table 4).
    pub degree_multisets: Vec<Vec<usize>>,
    /// Mean idle fraction over waves (Fig. 2 diagnostics).
    pub mean_idle_fraction: f64,
    /// Final communication-group pool statistics over the measured steps
    /// (hit-rate is the paper's §5 reuse claim; evictions and
    /// evicted-recreations expose capacity thrash).
    pub pool: crate::parallel::pool::PoolStats,
    /// Groups established in the pool at run end (the working set when
    /// the pool is unbounded; ≤ the cap otherwise).
    pub pool_groups: usize,
    /// Modeled communicator-buffer bytes those groups pin at run end.
    pub pool_buffer_bytes: u64,
    /// Measured steps that ended in a typed schedule failure (a static
    /// baseline refusing a fault-shrunken mesh). Failed steps make no
    /// progress and are excluded from the throughput means above; 0
    /// without a fault injector.
    pub failed_steps: usize,
    /// Total recovery seconds charged over the measured window
    /// (checkpoint restores, torn-group re-warms, lost work); 0 without
    /// a fault injector.
    pub recovery_s: f64,
}

/// Run `policy` through the full protocol in `ctx`, entirely through the
/// [`DhpSession`] façade: the session owns the run's single
/// communication-group pool (bounded by `ctx.pool_capacity`), warm-starts
/// it from the first step's schedules (the warm pool a real launch
/// establishes before training), and prepares each step's groups with
/// the previous step's compute as the prewarm-overlap budget, so each
/// iteration is charged only `max(0, serial − prev_compute)` (the serial
/// cost is retained in [`PolicyResult::mean_reconfig_serial_s`] for the
/// ablation).
pub fn run_policy(ctx: &ExpContext, policy: &dyn SchedulePolicy) -> PolicyResult {
    let mut session = ctx.session_for(policy.clone_policy());
    let mut sampler = ctx.sampler();
    let total_steps = ctx.warmup_steps + ctx.measure_steps;

    let mut iter_times = Vec::new();
    let mut tokens_list = Vec::new();
    let mut sched_times = Vec::new();
    let mut solver_times = Vec::new();
    let mut reconfig_per_iter: Vec<(f64, f64)> = Vec::new();
    let mut idle_fracs = Vec::new();
    let mut degree_multisets = Vec::new();
    let mut groups_replayed = 0usize;
    let mut groups_placed = 0usize;
    let mut failed_steps = 0usize;
    let mut recovery_s = 0.0;

    for step in 0..total_steps {
        let seqs = sampler.sample_batch(ctx.gbs);
        if step == ctx.warmup_steps {
            // Measured window starts here: report hit-rates for the
            // steady state, not the warmup churn.
            session.reset_pool_stats();
        }
        let report = session.step(&seqs);
        if step >= ctx.warmup_steps {
            recovery_s += report.recovery_time_s;
            if report.failed.is_some() {
                // No iteration ran: nothing to average into the
                // throughput metrics, but the failure is on the record.
                failed_steps += 1;
                continue;
            }
            iter_times.push(report.iteration.iter_time_s);
            tokens_list.push(report.iteration.tokens as f64);
            sched_times.push(report.schedule_time_s);
            solver_times.push(report.solver_time_s);
            reconfig_per_iter.push((
                report.iteration.reconfig_time_s,
                report.iteration.reconfig_serial_s,
            ));
            idle_fracs.push(report.idle_fraction);
            for s in &report.schedules {
                degree_multisets.push(s.degree_multiset());
            }
            groups_replayed += report.groups_replayed;
            groups_placed += report.groups_placed;
        }
    }

    let total_time: f64 = iter_times.iter().sum();
    let total_tokens: f64 = tokens_list.iter().sum();
    let npus = ctx.cluster.total_npus();
    let charged: Vec<f64> = reconfig_per_iter.iter().map(|p| p.0).collect();
    let serial: Vec<f64> = reconfig_per_iter.iter().map(|p| p.1).collect();
    PolicyResult {
        name: session.policy_name().to_string(),
        mean_iter_s: stats::mean(&iter_times),
        tokens_per_s: total_tokens / total_time,
        tokens_per_s_per_device: total_tokens / total_time / npus as f64,
        mean_schedule_s: stats::mean(&sched_times),
        mean_solver_s: stats::mean(&solver_times),
        mean_reconfig_s: stats::mean(&charged),
        mean_reconfig_serial_s: stats::mean(&serial),
        reconfig_per_iter_s: reconfig_per_iter,
        replay_rate: if groups_placed == 0 {
            0.0
        } else {
            groups_replayed as f64 / groups_placed as f64
        },
        degree_multisets,
        mean_idle_fraction: stats::mean(&idle_fracs),
        pool: session.pool_stats(),
        pool_groups: session.pool_groups(),
        pool_buffer_bytes: session.pool_buffer_bytes(),
        failed_steps,
        recovery_s,
    }
}

/// Build the three paper policies for a context, with static degrees
/// TUNED per the evaluation protocol ("for each baseline method, we tune
/// the hybrid parallelism hyperparameters and select the best-performing
/// configuration"): each candidate degree is trialled on a sample batch
/// and the best simulated iteration time wins.
pub struct PolicySet {
    /// Megatron-LM-style static CP at the tuned degree.
    pub megatron: MegatronStaticCp,
    /// DeepSpeed-Ulysses-style static SP at the tuned degree.
    pub deepspeed: DeepSpeedUlysses,
    /// The DHP dynamic scheduler.
    pub dhp: Scheduler,
}

impl PolicySet {
    /// Tune the static baselines per the paper's protocol and build all
    /// three policies for `ctx`.
    pub fn build(ctx: &ExpContext) -> PolicySet {
        let n = ctx.replicas();
        let cost = ctx.cost_model();
        let sim = ctx.sim();
        let planner = ctx.micro_batch_planner();
        let mut sampler = ctx.sampler();
        let trial_batch = GlobalBatch {
            step: u64::MAX, // tuning batch, outside the measured stream
            sequences: sampler.sample_batch(ctx.gbs.min(128)),
        };
        let bw = ctx.cluster.inter_bw;

        let tune = |mk: &dyn Fn(usize) -> Box<dyn SchedulePolicy>,
                    cands: &[usize]|
         -> usize {
            let mut best = (f64::INFINITY, cands[0]);
            for &d in cands {
                let policy = mk(d);
                let mbs = planner.plan(&trial_batch);
                let scheduled: Vec<(Vec<Sequence>, Schedule)> = mbs
                    .iter()
                    .map(|mb| {
                        let s = policy
                            .schedule(&mb.sequences)
                            .expect("tuning runs on an unfragmented mesh");
                        (mb.sequences.clone(), s)
                    })
                    .collect();
                // Tuning compares steady-state iteration time: a warm
                // pool (one-time creation is amortized over a long run,
                // not attributable to a single trial iteration).
                let mut pool = crate::parallel::GroupPool::new();
                pool.prewarm(scheduled.iter().flat_map(|(_, s)| s.pool_keys()));
                let t = sim
                    .execute_iteration(&scheduled, policy.comm_kind(), &mut pool)
                    .iter_time_s;
                if t < best.0 {
                    best = (t, d);
                }
            }
            best.1
        };

        // Megatron: any pow2 degree that satisfies memory for the longest
        // sequence is admissible; tune among those.
        let mega_floor =
            MegatronStaticCp::degree_for_longest(&trial_batch.sequences, n, &cost);
        let mega_cands: Vec<usize> = crate::baselines::static_degree_candidates(n)
            .into_iter()
            .filter(|&d| d >= mega_floor)
            .collect();
        let cost2 = cost.clone();
        let mesh2 = ctx.mesh();
        let mega_d = tune(
            &|d| {
                Box::new(
                    MegatronStaticCp::new(d, n, cost2.clone(), bw)
                        .with_mesh(mesh2.clone()),
                )
            },
            &mega_cands,
        );

        // DeepSpeed: additionally constrained by head divisibility.
        let ds_cands: Vec<usize> =
            DeepSpeedUlysses::degree_candidates(n, &ctx.preset)
                .into_iter()
                .filter(|&d| d >= mega_floor)
                .collect();
        let ds_cands = if ds_cands.is_empty() {
            // No Ulysses degree can fit the longest sequence: DeepSpeed
            // must run at its largest valid degree and eat the OOM risk —
            // we charge it the largest candidate.
            vec![*DeepSpeedUlysses::degree_candidates(n, &ctx.preset)
                .last()
                .unwrap()]
        } else {
            ds_cands
        };
        let preset = ctx.preset.clone();
        let cost3 = cost.clone();
        let mesh3 = ctx.mesh();
        let ds_d = tune(
            &|d| {
                Box::new(
                    DeepSpeedUlysses::new(d, n, &preset, cost3.clone(), bw)
                        .with_mesh(mesh3.clone()),
                )
            },
            &ds_cands,
        );

        PolicySet {
            megatron: MegatronStaticCp::new(mega_d, n, cost.clone(), bw)
                .with_mesh(ctx.mesh()),
            deepspeed: DeepSpeedUlysses::new(ds_d, n, &ctx.preset, cost.clone(), bw)
                .with_mesh(ctx.mesh()),
            dhp: ctx.dhp(),
        }
    }
}

/// FlexSP ablation policy for a context.
pub fn flexsp(ctx: &ExpContext) -> FlexSp {
    FlexSp::new(ctx.dhp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::by_name;

    fn ctx() -> ExpContext {
        ExpContext::new(
            by_name("InternVL3-2B").unwrap(),
            DatasetKind::OpenVid,
            8,
            TrainStage::Full,
        )
        .with_gbs(32)
        .with_steps(1, 2)
    }

    #[test]
    fn policy_set_builds_and_runs() {
        let ctx = ctx();
        let set = PolicySet::build(&ctx);
        let r_mega = run_policy(&ctx, &set.megatron);
        let r_ds = run_policy(&ctx, &set.deepspeed);
        let r_dhp = run_policy(&ctx, &set.dhp);
        for r in [&r_mega, &r_ds, &r_dhp] {
            assert!(r.mean_iter_s > 0.0, "{r:?}");
            assert!(r.tokens_per_s > 0.0);
            assert!(r.mean_schedule_s >= r.mean_solver_s * 0.5);
        }
        // The headline claim at small scale: DHP ≥ both static baselines.
        assert!(
            r_dhp.mean_iter_s <= r_mega.mean_iter_s * 1.02,
            "DHP {} vs Megatron {}",
            r_dhp.mean_iter_s,
            r_mega.mean_iter_s
        );
    }

    #[test]
    fn pool_stays_hot_after_warmup_in_e2e_path() {
        // The §5 reuse claim, measured on the e2e protocol path: after a
        // 10-step warmup on a stationary workload, the measured window's
        // pool hit-rate must exceed 0.8 and reconfiguration time must be
        // a vanishing fraction of iteration time.
        let ctx = ExpContext::new(
            by_name("InternVL3-8B").unwrap(),
            DatasetKind::OpenVid,
            16,
            crate::config::TrainStage::Full,
        )
        .with_gbs(48)
        .with_steps(10, 5);
        let r = run_policy(&ctx, &ctx.dhp());
        let total = r.pool.hits + r.pool.misses;
        assert!(total > 0, "measured window saw no group traffic");
        assert!(
            r.pool.hit_rate() > 0.8,
            "steady-state hit-rate {:.3} (hits {}, misses {})",
            r.pool.hit_rate(),
            r.pool.hits,
            r.pool.misses
        );
        assert!(
            r.mean_reconfig_s < r.mean_iter_s * 0.05,
            "reconfig {} not negligible vs iter {}",
            r.mean_reconfig_s,
            r.mean_iter_s
        );
    }

    #[test]
    fn capped_pool_stays_hot_and_charging_is_overlap_bounded() {
        // The ISSUE-3 acceptance criterion: with the pool capped at the
        // workload's working set, a stationary run must still sustain a
        // >0.8 hit-rate, the overlap-aware charge must never exceed the
        // serial cost on ANY iteration, and the replay telemetry must
        // attribute the hits to hint replay rather than luck.
        use crate::parallel::PoolCapacity;
        let ctx = ExpContext::new(
            by_name("InternVL3-8B").unwrap(),
            DatasetKind::OpenVid,
            16,
            crate::config::TrainStage::Full,
        )
        .with_gbs(48)
        .with_steps(10, 5);
        // Probe with an unbounded pool to size the working set.
        let probe = run_policy(&ctx, &ctx.dhp());
        let working_set = probe.pool_groups;
        assert!(working_set > 0);
        assert_eq!(probe.pool.evictions, 0, "unbounded pools never evict");
        assert!(
            probe.mean_reconfig_s <= probe.mean_reconfig_serial_s + 1e-15,
            "charged {} > serial {}",
            probe.mean_reconfig_s,
            probe.mean_reconfig_serial_s
        );

        // Capacity ≈ working set: reuse must survive the cap.
        let capped = ctx
            .clone()
            .with_pool_capacity(PoolCapacity::MaxGroups(working_set));
        let r = run_policy(&capped, &capped.dhp());
        assert!(
            r.pool.hit_rate() > 0.8,
            "capped hit-rate {:.3} (hits {}, misses {}, evictions {})",
            r.pool.hit_rate(),
            r.pool.hits,
            r.pool.misses,
            r.pool.evictions
        );
        assert!(r.pool_groups <= working_set, "cap exceeded");
        for (i, &(charged, serial)) in r.reconfig_per_iter_s.iter().enumerate() {
            assert!(
                charged <= serial + 1e-15,
                "iteration {i}: charged {charged} > serial {serial}"
            );
        }
        assert!(
            r.replay_rate > 0.5,
            "stationary workload should replay blocks: {:.3}",
            r.replay_rate
        );
    }

    #[test]
    fn dispatch_covers_every_token_once() {
        let ctx = ctx();
        let mut sampler = ctx.sampler();
        let seqs = sampler.sample_batch(16);
        let schedule = ctx.dhp().schedule(&seqs);
        for plan in &schedule.waves {
            let entries = dispatch(&seqs, plan);
            // Per sequence: chunks tile [0, len) without gaps/overlap.
            for g in &plan.groups {
                for &si in &g.seq_idxs {
                    let mut chunks: Vec<(u64, u64)> = entries
                        .iter()
                        .filter(|e| e.seq_idx == si)
                        .map(|e| (e.token_start, e.token_end))
                        .collect();
                    chunks.sort_unstable();
                    assert_eq!(chunks.first().unwrap().0, 0);
                    assert_eq!(chunks.last().unwrap().1, seqs[si].len());
                    for w in chunks.windows(2) {
                        assert_eq!(w[0].1, w[1].0, "gap/overlap in {chunks:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn tokenizer_spec_is_high_res() {
        let spec = experiment_tokenizer();
        assert_eq!(spec.tokens_per_frame, 256.0);
    }
}
