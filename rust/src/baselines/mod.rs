//! Baseline parallelism policies the paper evaluates against (§6.1):
//! Megatron-LM-style static context parallelism, DeepSpeed-Ulysses-style
//! static sequence parallelism, and a FlexSP-like dynamic-but-power-of-two
//! policy (ablating DHP's arbitrary-integer-degree relaxation).
//!
//! All policies emit the same [`Schedule`] type, so the cluster simulator
//! executes them identically — the comparison isolates the *scheduling*
//! contribution exactly as the paper's evaluation does.

pub mod deepspeed;
pub mod flexsp;
pub mod megatron;

use std::fmt;

use crate::cluster::CommKind;
use crate::data::sequence::Sequence;
use crate::parallel::mesh::DeviceMesh;
use crate::scheduler::{FabricKind, Schedule, Scheduler};

/// Why a policy could not produce a schedule for the current mesh.
///
/// Static-grid baselines (Megatron, DeepSpeed-Ulysses) require their full
/// replica complement; when the session shrinks the mesh under them —
/// occupancy events or rank failures — they return this typed error and
/// the session surfaces a *failed step* instead of aborting the process.
/// The same policy retries at full strength once capacity recovers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScheduleError {
    /// The policy's static grid needs more free replicas than the mesh
    /// currently offers.
    MeshShrunk {
        /// Policy display name (for reports).
        policy: &'static str,
        /// Replicas the static grid was tuned for.
        need: usize,
        /// Free replicas actually available.
        free: usize,
    },
}

impl ScheduleError {
    /// Re-attribute the error to a wrapping policy (e.g. DeepSpeed-Ulysses
    /// delegating its packing to the inner Megatron grid).
    pub fn attributed_to(self, policy: &'static str) -> Self {
        match self {
            ScheduleError::MeshShrunk { need, free, .. } => {
                ScheduleError::MeshShrunk { policy, need, free }
            }
        }
    }

    /// Hash the semantic content into a step digest (wall-clock free).
    pub fn digest_into(&self, h: &mut impl std::hash::Hasher) {
        use std::hash::Hash;
        match self {
            ScheduleError::MeshShrunk { policy, need, free } => {
                0u8.hash(h);
                policy.hash(h);
                need.hash(h);
                free.hash(h);
            }
        }
    }
}

impl fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ScheduleError::MeshShrunk { policy, need, free } => write!(
                f,
                "{policy}: static grid needs {need} free replicas, mesh has {free}"
            ),
        }
    }
}

impl std::error::Error for ScheduleError {}

/// A parallelism scheduling policy: micro-batch sequences → schedule.
///
/// Every policy — DHP and the static baselines alike — drives the
/// training loop through [`crate::session::DhpSession`], which owns the
/// authoritative [`DeviceMesh`] and pushes occupancy changes into the
/// policy via [`SchedulePolicy::sync_mesh`] so the next
/// [`SchedulePolicy::schedule`] call solves against current
/// fragmentation.
pub trait SchedulePolicy: Send {
    /// Display name used in tables and reports.
    fn name(&self) -> &'static str;
    /// Communication pattern the policy's groups use at execution time.
    fn comm_kind(&self) -> CommKind;
    /// Plan one micro-batch into a placed schedule, or a typed error when
    /// the policy cannot operate on the current mesh (static grids under a
    /// shrunk mesh). Dynamic policies (DHP, FlexSP) re-solve on whatever
    /// capacity is free and never fail here.
    fn schedule(&self, seqs: &[Sequence]) -> Result<Schedule, ScheduleError>;
    /// Install an updated physical mesh. The session calls this once at
    /// build time (so policy and executor share one topology) and again
    /// after every applied [`crate::session::MeshEvent`] batch, making
    /// mid-run fragmentation flow into the next solve.
    fn sync_mesh(&mut self, mesh: &DeviceMesh);
    /// Clone this policy into an owned trait object (sessions own their
    /// policy; the experiment harness clones the caller's borrow).
    fn clone_policy(&self) -> Box<dyn SchedulePolicy>;
    /// Which bandwidth oracle this policy costs its candidates against.
    /// The session derives its reported fabric fingerprint from this, so
    /// the policy is the single source of truth. Static baselines
    /// estimate at uniform pre-placement bandwidth, so they report
    /// [`FabricKind::Uniform`].
    fn fabric_kind(&self) -> FabricKind {
        FabricKind::Uniform
    }
    /// Attach a persistent outer-search worker pool. The scheduling
    /// pipeline calls this once per scheduling thread so steady-state
    /// solves never spawn threads ([`crate::scheduler::SearchPool`]).
    /// Policies without a parallel search (the static baselines) ignore
    /// it — the default is a no-op.
    fn attach_search_pool(&mut self, _pool: std::sync::Arc<crate::scheduler::SearchPool>) {}
}

impl SchedulePolicy for Scheduler {
    fn name(&self) -> &'static str {
        "DHP"
    }

    fn comm_kind(&self) -> CommKind {
        CommKind::RingCp
    }

    fn schedule(&self, seqs: &[Sequence]) -> Result<Schedule, ScheduleError> {
        // DHP re-solves on whatever the mesh offers; it only needs one
        // free replica, which the session's occupancy validation and the
        // fault injector's last-rank guard both preserve.
        Ok(Scheduler::schedule(self, seqs))
    }

    fn sync_mesh(&mut self, mesh: &DeviceMesh) {
        // The cross-step placement hint survives: stale blocks (now
        // occupied or out of range) are skipped by the placer, while
        // still-free blocks keep replaying into pooled groups.
        self.mesh = mesh.clone();
        // The exact-hit schedule cache does NOT survive: the pipeline
        // delivers this call as an ordered `SyncMesh` control message,
        // so invalidating here guarantees no solve after a mesh event
        // can be served a placement drafted for the old occupancy. The
        // warm-start seed is kept — it is re-validated against the
        // fresh fabric snapshot on every use
        // ([`crate::scheduler::schedule_cache`]).
        self.invalidate_schedule_cache();
    }

    fn clone_policy(&self) -> Box<dyn SchedulePolicy> {
        Box::new(self.clone())
    }

    fn fabric_kind(&self) -> FabricKind {
        self.fabric
    }

    fn attach_search_pool(&mut self, pool: std::sync::Arc<crate::scheduler::SearchPool>) {
        self.set_search_pool(pool);
    }
}

pub use deepspeed::DeepSpeedUlysses;
pub use flexsp::FlexSp;
pub use megatron::MegatronStaticCp;

/// Valid static degrees for a cluster of `replicas` ranks: powers of two
/// dividing the replica count (what Megatron/DeepSpeed grids allow).
pub fn static_degree_candidates(replicas: usize) -> Vec<usize> {
    (0..=usize::BITS)
        .map(|b| 1usize << b)
        .take_while(|&d| d <= replicas)
        .filter(|&d| replicas % d == 0)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degree_candidates() {
        assert_eq!(static_degree_candidates(8), vec![1, 2, 4, 8]);
        assert_eq!(static_degree_candidates(64), vec![1, 2, 4, 8, 16, 32, 64]);
        assert_eq!(static_degree_candidates(1), vec![1]);
        // 12 replicas: pow2 divisors only.
        assert_eq!(static_degree_candidates(12), vec![1, 2, 4]);
    }
}
