//! Baseline parallelism policies the paper evaluates against (§6.1):
//! Megatron-LM-style static context parallelism, DeepSpeed-Ulysses-style
//! static sequence parallelism, and a FlexSP-like dynamic-but-power-of-two
//! policy (ablating DHP's arbitrary-integer-degree relaxation).
//!
//! All policies emit the same [`Schedule`] type, so the cluster simulator
//! executes them identically — the comparison isolates the *scheduling*
//! contribution exactly as the paper's evaluation does.

pub mod deepspeed;
pub mod flexsp;
pub mod megatron;

use crate::cluster::CommKind;
use crate::data::sequence::Sequence;
use crate::scheduler::Schedule;

/// A parallelism scheduling policy: micro-batch sequences → schedule.
pub trait SchedulePolicy: Send {
    /// Display name used in tables and reports.
    fn name(&self) -> &'static str;
    /// Communication pattern the policy's groups use at execution time.
    fn comm_kind(&self) -> CommKind;
    /// Plan one micro-batch into a placed schedule.
    fn schedule(&self, seqs: &[Sequence]) -> Schedule;
}

pub use deepspeed::DeepSpeedUlysses;
pub use flexsp::FlexSp;
pub use megatron::MegatronStaticCp;

/// Valid static degrees for a cluster of `replicas` ranks: powers of two
/// dividing the replica count (what Megatron/DeepSpeed grids allow).
pub fn static_degree_candidates(replicas: usize) -> Vec<usize> {
    (0..=usize::BITS)
        .map(|b| 1usize << b)
        .take_while(|&d| d <= replicas)
        .filter(|&d| replicas % d == 0)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degree_candidates() {
        assert_eq!(static_degree_candidates(8), vec![1, 2, 4, 8]);
        assert_eq!(static_degree_candidates(64), vec![1, 2, 4, 8, 16, 32, 64]);
        assert_eq!(static_degree_candidates(1), vec![1]);
        // 12 replicas: pow2 divisors only.
        assert_eq!(static_degree_candidates(12), vec![1, 2, 4]);
    }
}
