//! Megatron-LM-style static context parallelism: a fixed CP degree `d`
//! partitions the cluster into N/d uniform groups ("static mesh",
//! paper Fig. 2 / Table 4: "statically partitions parallel groups based on
//! the longest sequence length"). Sequences are balanced across groups
//! with LPT (longest-processing-time first) — a *generous* baseline, as
//! the paper tunes each baseline's hyperparameters — subject to the
//! per-group memory cap; overflow opens a new wave, since all DP groups
//! advance in lock-step toward the gradient all-reduce.

use crate::cluster::CommKind;
use crate::cost::{CostModel, WorkloadAgg};
use crate::data::sequence::Sequence;
use crate::parallel::mesh::DeviceMesh;
use crate::scheduler::{place_plan, Plan, PlannedGroup, Schedule};

use super::{ScheduleError, SchedulePolicy};

/// Static-CP policy with a fixed degree.
#[derive(Debug, Clone)]
pub struct MegatronStaticCp {
    /// The fixed CP degree every group runs at.
    pub degree: usize,
    /// Total model replicas in the cluster.
    pub replicas: usize,
    /// Cost model used for the draft-level estimates.
    pub cost: CostModel,
    /// Ring bandwidth the groups are assumed to see pre-placement (the
    /// draft-level est_time bookkeeping).
    pub bandwidth: f64,
    /// Physical topology the static grid is placed on. Defaults to a
    /// uniform single-fabric mesh at `bandwidth`; the experiment harness
    /// installs the real cluster mesh via [`MegatronStaticCp::with_mesh`].
    pub mesh: DeviceMesh,
}

impl MegatronStaticCp {
    /// Static grid of N/`degree` groups (`degree` must divide
    /// `replicas`), estimated at uniform `bandwidth` pre-placement.
    pub fn new(degree: usize, replicas: usize, cost: CostModel, bandwidth: f64) -> Self {
        assert!(degree >= 1 && degree <= replicas);
        assert_eq!(replicas % degree, 0, "static degree must divide N");
        MegatronStaticCp {
            degree,
            replicas,
            cost,
            bandwidth,
            mesh: DeviceMesh::uniform(replicas, bandwidth),
        }
    }

    /// Place the static grid on a real cluster topology (groups that fit
    /// inside a node then ride the fast fabric, like a real Megatron
    /// launch would). A mesh smaller than the static grid is accepted —
    /// the next [`SchedulePolicy::schedule`] call reports
    /// [`ScheduleError::MeshShrunk`] instead of placing.
    pub fn with_mesh(mut self, mesh: DeviceMesh) -> Self {
        self.mesh = mesh;
        self
    }

    /// The paper's framing: the static degree is forced by the longest
    /// sequence in the workload sample ("partitions parallel groups based
    /// on the longest sequence length") — the smallest valid power of two
    /// whose memory capacity fits it.
    pub fn degree_for_longest(
        seqs: &[Sequence],
        replicas: usize,
        cost: &CostModel,
    ) -> usize {
        let longest = seqs.iter().map(|s| s.len()).max().unwrap_or(1);
        let need = cost.memory.min_degree(longest);
        super::static_degree_candidates(replicas)
            .into_iter()
            .find(|&d| d >= need)
            .unwrap_or(replicas)
    }
}

impl SchedulePolicy for MegatronStaticCp {
    fn name(&self) -> &'static str {
        "Megatron-LM"
    }

    fn comm_kind(&self) -> CommKind {
        CommKind::RingCp
    }

    fn sync_mesh(&mut self, mesh: &DeviceMesh) {
        // A static grid cannot adapt to lost capacity: it keeps planning
        // all N replicas. The shrunk mesh is still recorded so the next
        // schedule() call can report MeshShrunk against the actual free
        // budget (and resume placing once the capacity returns) — exactly
        // the rigidity DHP removes.
        self.mesh = mesh.clone();
    }

    fn clone_policy(&self) -> Box<dyn SchedulePolicy> {
        Box::new(self.clone())
    }

    fn schedule(&self, seqs: &[Sequence]) -> Result<Schedule, ScheduleError> {
        // The static grid plans all `replicas` ranks; anything less free
        // and the placement below would overrun the mesh's free budget.
        // The mesh itself may be LARGER than the grid (a multi-tenant
        // cluster where this job's grant is a slice of the shared mesh):
        // placement runs on free ranks only, so all the grid needs is
        // `replicas` free slots.
        let free = self.mesh.free_replicas();
        if free < self.replicas {
            return Err(ScheduleError::MeshShrunk {
                policy: self.name(),
                need: self.replicas,
                free: free.min(self.mesh.replicas),
            });
        }
        let t0 = std::time::Instant::now();
        let n_groups = self.replicas / self.degree;
        let cap_tokens = {
            // Eq. 3 at the fixed degree.
            let budget = self.cost.memory.rank_budget() * self.degree as f64;
            (budget / self.cost.memory.m_token).floor() as u64
        };
        // LPT over sequences, descending.
        let mut order: Vec<usize> = (0..seqs.len()).collect();
        order.sort_by(|&a, &b| seqs[b].len().cmp(&seqs[a].len()).then(a.cmp(&b)));

        struct Bin {
            idxs: Vec<usize>,
            tokens: u64,
            load: f64,
        }
        let mut waves: Vec<Vec<Bin>> = Vec::new();
        let new_wave = |waves: &mut Vec<Vec<Bin>>| {
            waves.push(
                (0..n_groups)
                    .map(|_| Bin {
                        idxs: vec![],
                        tokens: 0,
                        load: 0.0,
                    })
                    .collect(),
            );
        };
        new_wave(&mut waves);
        for &i in &order {
            let s = &seqs[i];
            let l = s.len();
            let work = (1.0 + s.eta()) * (l as f64) * (l as f64);
            // Least-loaded bin with room, searching the last wave first.
            let mut placed = false;
            let wave = waves.last_mut().unwrap();
            let mut best: Option<usize> = None;
            for (bi, b) in wave.iter().enumerate() {
                if b.tokens + l <= cap_tokens || b.idxs.is_empty() {
                    match best {
                        Some(prev) if wave[prev].load <= b.load => {}
                        _ => best = Some(bi),
                    }
                }
            }
            if let Some(bi) = best {
                let b = &mut wave[bi];
                b.idxs.push(i);
                b.tokens += l;
                b.load += work;
                placed = true;
            }
            if !placed {
                new_wave(&mut waves);
                let b = &mut waves.last_mut().unwrap()[0];
                b.idxs.push(i);
                b.tokens += l;
                b.load += work;
            }
        }

        let mut schedule = Schedule::default();
        for wave in waves {
            let mut plan = Plan::default();
            for b in wave {
                if b.idxs.is_empty() {
                    // A static mesh keeps the group allocated even when
                    // empty — that IS the idle-gap pathology, surfaced by
                    // keeping the degree reserved with zero work.
                    plan.groups.push(PlannedGroup {
                        degree: self.degree,
                        seq_idxs: vec![],
                        agg: WorkloadAgg::default(),
                        est_time_s: 0.0,
                    });
                    continue;
                }
                let group_seqs: Vec<Sequence> =
                    b.idxs.iter().map(|&i| seqs[i].clone()).collect();
                let agg = WorkloadAgg::of(&group_seqs);
                let est = self.cost.t_total(&agg, self.degree, self.bandwidth);
                plan.groups.push(PlannedGroup {
                    degree: self.degree,
                    seq_idxs: b.idxs,
                    agg,
                    est_time_s: est,
                });
            }
            plan.est_makespan_s = plan
                .groups
                .iter()
                .map(|g| g.est_time_s)
                .fold(0.0f64, f64::max);
            // Static grids need no reuse hint: the same degree vector
            // places identically every step, so the pool stays hot by
            // construction.
            let placed = place_plan(&plan, &self.mesh, None, &self.cost);
            schedule.search_est_time_s += plan.est_makespan_s;
            schedule.est_time_s += placed.est_makespan_s;
            schedule.waves.push(placed);
        }
        schedule.solve_time_s = t0.elapsed().as_secs_f64();
        Ok(schedule)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::by_name;
    use crate::config::TrainStage;
    use crate::cost::{CostCoeffs, HardwareSpec, MemoryModel};
    use crate::data::datasets::{DatasetKind, DatasetSampler};

    fn cost() -> CostModel {
        let preset = by_name("InternVL3-8B").unwrap();
        let hw = HardwareSpec::default();
        CostModel {
            coeffs: CostCoeffs::analytic(&preset, TrainStage::Full, &hw),
            memory: MemoryModel {
                e_bytes: 8192.0 * preset.act_bytes_per_token() + 2e9,
                m_states: 2e9,
                m_token: preset.act_bytes_per_token(),
            },
        }
    }

    #[test]
    fn uniform_degrees_only() {
        let policy = MegatronStaticCp::new(4, 16, cost(), 12.5e9);
        let mut sampler = DatasetSampler::new(DatasetKind::Msrvtt, 81);
        let seqs = sampler.sample_batch(32);
        let schedule = policy.schedule(&seqs).unwrap();
        schedule.validate(&seqs, 16).unwrap();
        for d in schedule.degree_multiset() {
            assert_eq!(d, 4);
        }
        // Every wave fields exactly N/d groups (the static grid).
        for p in &schedule.waves {
            assert_eq!(p.groups.len(), 4);
        }
    }

    #[test]
    fn degree_for_longest_fits_memory() {
        let c = cost();
        let mut sampler = DatasetSampler::new(DatasetKind::OpenVid, 83);
        let seqs = sampler.sample_batch(64);
        let d = MegatronStaticCp::degree_for_longest(&seqs, 64, &c);
        assert!(d.is_power_of_two());
        let longest = seqs.iter().map(|s| s.len()).max().unwrap();
        assert!(c.memory.fits(longest, d), "longest seq must fit degree {d}");
    }

    #[test]
    fn memory_overflow_opens_waves() {
        let c = cost();
        // Degree 1 groups hold ~8192 tokens; force multi-wave.
        let policy = MegatronStaticCp::new(1, 2, c, 12.5e9);
        let seqs: Vec<Sequence> = (0..6)
            .map(|i| Sequence::new(i, 3000, 3000)) // 6000 tokens each
            .collect();
        let schedule = policy.schedule(&seqs).unwrap();
        schedule.validate(&seqs, 2).unwrap();
        assert!(schedule.waves.len() >= 3, "{}", schedule.waves.len());
    }

    #[test]
    fn lpt_balances_loads() {
        let policy = MegatronStaticCp::new(2, 8, cost(), 12.5e9);
        let seqs: Vec<Sequence> = vec![
            Sequence::new(0, 2000, 2000),
            Sequence::new(1, 1000, 1000),
            Sequence::new(2, 1000, 1000),
            Sequence::new(3, 500, 500),
            Sequence::new(4, 500, 500),
            Sequence::new(5, 500, 500),
            Sequence::new(6, 250, 250),
            Sequence::new(7, 250, 250),
        ];
        let schedule = policy.schedule(&seqs).unwrap();
        assert_eq!(schedule.waves.len(), 1);
        let times: Vec<f64> = schedule.waves[0]
            .groups
            .iter()
            .map(|g| g.est_time_s)
            .collect();
        let max = times.iter().fold(0.0f64, |a, &b| a.max(b));
        let min = times.iter().fold(f64::MAX, |a, &b| a.min(b));
        assert!(max / min.max(1e-9) < 4.0, "LPT imbalance too high: {times:?}");
    }

    #[test]
    #[should_panic(expected = "divide")]
    fn bad_degree_panics() {
        MegatronStaticCp::new(3, 16, cost(), 12.5e9);
    }

    #[test]
    fn shrunk_mesh_is_a_typed_error_and_recovers() {
        let mut policy = MegatronStaticCp::new(2, 8, cost(), 12.5e9);
        let mut sampler = DatasetSampler::new(DatasetKind::Msrvtt, 7);
        let seqs = sampler.sample_batch(8);
        assert!(policy.schedule(&seqs).is_ok());
        // Two ranks lost: the static grid refuses with a typed error.
        let mut mesh = DeviceMesh::uniform(8, 12.5e9);
        mesh.occupy(&[3, 5]);
        policy.sync_mesh(&mesh);
        match policy.schedule(&seqs) {
            Err(ScheduleError::MeshShrunk { policy, need, free }) => {
                assert_eq!(policy, "Megatron-LM");
                assert_eq!((need, free), (8, 6));
            }
            other => panic!("expected MeshShrunk, got {other:?}"),
        }
        // Capacity back: the same policy schedules at full strength again.
        mesh.release(&[3, 5]);
        policy.sync_mesh(&mesh);
        let schedule = policy.schedule(&seqs).unwrap();
        schedule.validate(&seqs, 8).unwrap();
    }
}
