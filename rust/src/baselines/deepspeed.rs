//! DeepSpeed-Ulysses-style static sequence parallelism: like the static
//! grid, but the SP degree must also divide the attention-head count
//! (Ulysses shards heads across ranks, §3.2 / Appendix A.2), and the
//! communication pattern is all-to-all activation redistribution — not
//! overlappable with attention compute.

use crate::cluster::CommKind;
use crate::config::presets::ModelPreset;
use crate::cost::CostModel;
use crate::data::sequence::Sequence;
use crate::scheduler::Schedule;

use super::megatron::MegatronStaticCp;
use super::{ScheduleError, SchedulePolicy};

/// Static Ulysses-SP policy (delegates grid construction to the static-CP
/// machinery; what differs is degree admissibility and the comm pattern).
#[derive(Debug, Clone)]
pub struct DeepSpeedUlysses {
    inner: MegatronStaticCp,
    /// Attention-head count the SP degree must divide.
    pub heads: usize,
}

impl DeepSpeedUlysses {
    /// Static Ulysses grid at `degree` (must divide the preset's head
    /// count), estimated at uniform `bandwidth` pre-placement.
    pub fn new(
        degree: usize,
        replicas: usize,
        preset: &ModelPreset,
        cost: CostModel,
        bandwidth: f64,
    ) -> Self {
        assert!(
            preset.heads % degree == 0,
            "Ulysses degree {degree} must divide heads {}",
            preset.heads
        );
        DeepSpeedUlysses {
            inner: MegatronStaticCp::new(degree, replicas, cost, bandwidth),
            heads: preset.heads,
        }
    }

    /// Valid Ulysses degrees: powers of two dividing both N and #heads.
    pub fn degree_candidates(replicas: usize, preset: &ModelPreset) -> Vec<usize> {
        super::static_degree_candidates(replicas)
            .into_iter()
            .filter(|&d| preset.heads % d == 0)
            .collect()
    }

    /// The fixed SP degree.
    pub fn degree(&self) -> usize {
        self.inner.degree
    }

    /// Place the static grid on a real cluster topology (see
    /// [`MegatronStaticCp::with_mesh`]).
    pub fn with_mesh(mut self, mesh: crate::parallel::mesh::DeviceMesh) -> Self {
        self.inner = self.inner.with_mesh(mesh);
        self
    }
}

impl SchedulePolicy for DeepSpeedUlysses {
    fn name(&self) -> &'static str {
        "DeepSpeed"
    }

    fn comm_kind(&self) -> CommKind {
        CommKind::UlyssesA2A
    }

    fn schedule(&self, seqs: &[Sequence]) -> Result<Schedule, ScheduleError> {
        // Re-attribute mesh-shrunk errors from the inner static grid so
        // failed-step reports name the policy the session actually runs.
        self.inner
            .schedule(seqs)
            .map_err(|e| e.attributed_to(self.name()))
    }

    fn sync_mesh(&mut self, mesh: &crate::parallel::mesh::DeviceMesh) {
        self.inner.sync_mesh(mesh);
    }

    fn clone_policy(&self) -> Box<dyn SchedulePolicy> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::by_name;
    use crate::config::TrainStage;
    use crate::cost::{CostCoeffs, HardwareSpec, MemoryModel};

    fn cost(name: &str) -> (ModelPreset, CostModel) {
        let preset = by_name(name).unwrap();
        let hw = HardwareSpec::default();
        let cm = CostModel {
            coeffs: CostCoeffs::analytic(&preset, TrainStage::Full, &hw),
            memory: MemoryModel {
                e_bytes: 8192.0 * preset.act_bytes_per_token() + 2e9,
                m_states: 2e9,
                m_token: preset.act_bytes_per_token(),
            },
        };
        (preset, cm)
    }

    #[test]
    fn head_divisibility_enforced() {
        // InternVL3-8B has 28 heads: degree 8 does not divide them.
        let (preset, cm) = cost("InternVL3-8B");
        let cands = DeepSpeedUlysses::degree_candidates(64, &preset);
        assert_eq!(cands, vec![1, 2, 4]);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            DeepSpeedUlysses::new(8, 64, &preset, cm, 12.5e9)
        }));
        assert!(result.is_err());
    }

    #[test]
    fn qwen_allows_more_degrees() {
        let (preset, _) = cost("Qwen3VL-8B"); // 32 heads
        let cands = DeepSpeedUlysses::degree_candidates(64, &preset);
        assert_eq!(cands, vec![1, 2, 4, 8, 16, 32]);
    }

    #[test]
    fn schedules_validate_and_use_a2a() {
        let (preset, cm) = cost("Qwen3VL-2B");
        let policy = DeepSpeedUlysses::new(4, 8, &preset, cm, 12.5e9);
        assert_eq!(policy.comm_kind(), CommKind::UlyssesA2A);
        let seqs: Vec<Sequence> =
            (0..12).map(|i| Sequence::new(i, 400, 400)).collect();
        let schedule = policy.schedule(&seqs).unwrap();
        schedule.validate(&seqs, 8).unwrap();
        for d in schedule.degree_multiset() {
            assert_eq!(d, 4);
        }
    }

    #[test]
    fn shrunk_mesh_error_names_deepspeed() {
        let (preset, cm) = cost("Qwen3VL-2B");
        let mut policy = DeepSpeedUlysses::new(4, 8, &preset, cm, 12.5e9);
        let mut mesh = crate::parallel::mesh::DeviceMesh::uniform(8, 12.5e9);
        mesh.occupy(&[0]);
        policy.sync_mesh(&mesh);
        let seqs: Vec<Sequence> =
            (0..4).map(|i| Sequence::new(i, 400, 400)).collect();
        match policy.schedule(&seqs) {
            Err(ScheduleError::MeshShrunk { policy, need, free }) => {
                assert_eq!(policy, "DeepSpeed");
                assert_eq!((need, free), (8, 7));
            }
            other => panic!("expected MeshShrunk, got {other:?}"),
        }
    }
}
