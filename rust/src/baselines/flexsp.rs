//! FlexSP-style baseline: *dynamic* sequence-parallel planning like DHP,
//! but with communication-group sizes restricted to powers of two (the
//! restriction the paper calls out in §1/§4.1: "FlexSP ... restricts the
//! communication group size to powers of two"). Ablates exactly one thing
//! against DHP: the arbitrary-integer-degree relaxation.

use crate::cluster::CommKind;
use crate::data::sequence::Sequence;
use crate::scheduler::{DegreePolicy, Schedule, Scheduler};

use super::{ScheduleError, SchedulePolicy};

/// Power-of-two-restricted dynamic scheduler.
#[derive(Clone)]
pub struct FlexSp {
    inner: Scheduler,
}

impl FlexSp {
    /// Wrap a DHP scheduler, restricting its degree search to powers of
    /// two.
    pub fn new(scheduler: Scheduler) -> Self {
        FlexSp {
            inner: scheduler.with_policy(DegreePolicy::PowerOfTwo),
        }
    }
}

impl SchedulePolicy for FlexSp {
    fn name(&self) -> &'static str {
        "FlexSP"
    }

    fn comm_kind(&self) -> CommKind {
        CommKind::RingCp
    }

    fn schedule(&self, seqs: &[Sequence]) -> Result<Schedule, ScheduleError> {
        // Dynamic like DHP: re-solves on whatever capacity is free, so a
        // shrunk mesh degrades throughput rather than failing the step.
        Ok(self.inner.schedule(seqs))
    }

    fn sync_mesh(&mut self, mesh: &crate::parallel::mesh::DeviceMesh) {
        self.inner.sync_mesh(mesh);
    }

    fn clone_policy(&self) -> Box<dyn SchedulePolicy> {
        Box::new(self.clone())
    }

    fn fabric_kind(&self) -> crate::scheduler::FabricKind {
        self.inner.fabric
    }

    fn attach_search_pool(
        &mut self,
        pool: std::sync::Arc<crate::scheduler::SearchPool>,
    ) {
        // FlexSP runs the same parallel outer search as DHP (only the
        // degree filter differs), so it benefits identically.
        self.inner.set_search_pool(pool);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::by_name;
    use crate::config::{ClusterConfig, TrainStage};
    use crate::cost::{CostCoeffs, CostModel, HardwareSpec, MemoryModel};
    use crate::parallel::mesh::DeviceMesh;

    fn scheduler(replicas: usize) -> Scheduler {
        // Multi-node regime: 2 replicas/node (TP×PP = 4 NPUs each).
        let mut cluster = ClusterConfig::default().with_npus(replicas * 4);
        cluster.tp = 2;
        cluster.pp = 2;
        let preset = by_name("InternVL3-8B").unwrap();
        // Per-replica FLOPs aggregate the TP*PP member NPUs.
        let hw = HardwareSpec {
            peak_flops: 376e12 * 4.0,
            ..HardwareSpec::default()
        };
        let cost = CostModel {
            coeffs: CostCoeffs::analytic(&preset, TrainStage::Full, &hw),
            memory: MemoryModel {
                e_bytes: 8192.0 * preset.act_bytes_per_token() + 2e9,
                m_states: 2e9,
                m_token: preset.act_bytes_per_token(),
            },
        };
        Scheduler::new(cost, DeviceMesh::new(&cluster))
    }

    #[test]
    fn degrees_are_powers_of_two() {
        use crate::data::datasets::{DatasetKind, DatasetSampler, TokenizerSpec};
        let policy = FlexSp::new(scheduler(16));
        let mut sampler = DatasetSampler::new(DatasetKind::OpenVid, 91)
            .with_spec(TokenizerSpec { fps: 2.0, tokens_per_frame: 256.0, text_min: 32, text_max: 512 });
        let seqs = sampler.sample_batch(40);
        let schedule = policy.schedule(&seqs).unwrap();
        schedule.validate(&seqs, 16).unwrap();
        for d in schedule.degree_multiset() {
            assert!(d.is_power_of_two(), "degree {d}");
        }
    }

    #[test]
    fn flexsp_does_not_beat_dhp_on_average() {
        // Per-instance dominance is NOT guaranteed (pow2-rounded minimum
        // degrees change the wave partitioning), but over a memory-full
        // micro-batch workload DHP's larger feasible set must win.
        use crate::config::presets::by_name;
        use crate::config::TrainStage;
        use crate::data::datasets::DatasetKind;
        use crate::experiments::harness::ExpContext;
        let ctx = ExpContext::new(
            by_name("InternVL3-8B").unwrap(),
            DatasetKind::OpenVid,
            32,
            TrainStage::Full,
        );
        let dhp = ctx.dhp();
        let flex = FlexSp::new(ctx.dhp());
        let (mut t_dhp, mut t_flex) = (0.0, 0.0);
        for seed in 0..6u64 {
            let mut ctx2 = ctx.clone();
            ctx2.seed = 200 + seed;
            let mut sampler = ctx2.sampler();
            let batch = crate::data::batch::GlobalBatch {
                step: 0,
                sequences: sampler.sample_batch(96),
            };
            for mb in ctx2.micro_batch_planner().plan(&batch) {
                // Search objective: the ablation is about the degree
                // search space, not placement fragmentation noise.
                t_dhp += dhp.schedule(&mb.sequences).search_est_time_s;
                t_flex += flex.schedule(&mb.sequences).unwrap().search_est_time_s;
            }
        }
        assert!(
            t_dhp < t_flex,
            "dhp {t_dhp} should beat flexsp {t_flex} on average"
        );
    }
}
