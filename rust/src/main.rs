//! `dhp` CLI — leader entrypoint: experiments, training, reports.

fn main() -> anyhow::Result<()> {
    dhp::util::logger::init();
    let args = dhp::util::cli::Args::from_env()?;
    dhp::report::run_cli(args)
}
