//! Training metrics: iteration timing, token throughput, rolling
//! aggregation with warmup exclusion (the paper's protocol: warm up 5
//! steps, average the next 10).

use crate::util::stats;

/// Accumulates per-step measurements with a warmup cutoff.
#[derive(Debug, Clone)]
pub struct StepMetrics {
    /// Steps excluded from aggregation at the start of the run.
    pub warmup_steps: usize,
    steps_seen: usize,
    iter_times_s: Vec<f64>,
    tokens: Vec<u64>,
    losses: Vec<f64>,
}

impl StepMetrics {
    /// Fresh accumulator excluding the first `warmup_steps` steps.
    pub fn new(warmup_steps: usize) -> Self {
        StepMetrics {
            warmup_steps,
            steps_seen: 0,
            iter_times_s: Vec::new(),
            tokens: Vec::new(),
            losses: Vec::new(),
        }
    }

    /// Record one step; warmup steps are counted but not aggregated.
    pub fn record(&mut self, iter_time_s: f64, tokens: u64, loss: Option<f64>) {
        self.steps_seen += 1;
        if self.steps_seen <= self.warmup_steps {
            return;
        }
        self.iter_times_s.push(iter_time_s);
        self.tokens.push(tokens);
        if let Some(l) = loss {
            self.losses.push(l);
        }
    }

    /// Steps recorded past the warmup cutoff.
    pub fn measured_steps(&self) -> usize {
        self.iter_times_s.len()
    }

    /// Mean iteration time over measured steps (paper's primary metric).
    pub fn mean_iter_time_s(&self) -> f64 {
        stats::mean(&self.iter_times_s)
    }

    /// Median iteration time over measured steps.
    pub fn p50_iter_time_s(&self) -> f64 {
        stats::median(&self.iter_times_s)
    }

    /// Tokens/s over the measured window.
    pub fn throughput_tokens_per_s(&self) -> f64 {
        let t: f64 = self.iter_times_s.iter().sum();
        if t == 0.0 {
            return 0.0;
        }
        self.tokens.iter().sum::<u64>() as f64 / t
    }

    /// Per-device throughput (the paper's token/s/device).
    pub fn throughput_per_device(&self, devices: usize) -> f64 {
        self.throughput_tokens_per_s() / devices.max(1) as f64
    }

    /// Losses recorded past the warmup cutoff.
    pub fn losses(&self) -> &[f64] {
        &self.losses
    }

    /// Most recent measured loss, if any.
    pub fn last_loss(&self) -> Option<f64> {
        self.losses.last().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_excluded() {
        let mut m = StepMetrics::new(2);
        m.record(100.0, 1, None); // warmup
        m.record(100.0, 1, None); // warmup
        m.record(2.0, 10, Some(1.0));
        m.record(4.0, 20, Some(0.5));
        assert_eq!(m.measured_steps(), 2);
        assert_eq!(m.mean_iter_time_s(), 3.0);
        assert_eq!(m.throughput_tokens_per_s(), 5.0);
        assert_eq!(m.throughput_per_device(5), 1.0);
        assert_eq!(m.last_loss(), Some(0.5));
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = StepMetrics::new(5);
        assert_eq!(m.mean_iter_time_s(), 0.0);
        assert_eq!(m.throughput_tokens_per_s(), 0.0);
        assert!(m.last_loss().is_none());
    }
}
