//! Simulated NPU cluster — the substrate substituting for the paper's
//! 8-node Ascend 910B testbed (DESIGN.md §2).
//!
//! The simulator executes a [`Schedule`] (from DHP or any baseline) with:
//! * real rank placement through the [`DeviceMesh`] (intra-node HCCS vs
//!   inter-node IB bandwidth per group),
//! * ground-truth per-group times from the first-principles
//!   [`crate::cost::exact`] model (ring CP) or the Ulysses all-to-all
//!   model (DeepSpeed baseline),
//! * per-iteration data-parallel gradient synchronization,
//! * per-wave makespan/idle accounting (Fig. 2's "idle gaps").

pub mod sim;

pub use sim::{ClusterSim, CommKind, IterationReport, WaveReport};
