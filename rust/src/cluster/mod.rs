//! Simulated NPU cluster — the substrate substituting for the paper's
//! 8-node Ascend 910B testbed (DESIGN.md §2).
//!
//! The simulator executes a PLACED [`crate::scheduler::Schedule`] (from
//! DHP or any baseline) with:
//! * the rank placement the scheduler committed to (intra-node HCCS vs
//!   inter-node IB bandwidth read off each group's actual rank set via
//!   the [`crate::parallel::DeviceMesh`] — the simulator never
//!   re-places),
//! * ground-truth per-group times from the first-principles
//!   [`crate::cost::exact`] model (ring CP) or the Ulysses all-to-all
//!   model (DeepSpeed baseline),
//! * communication-group resolution through a caller-owned pool, with
//!   pool-miss creation cost charged as reconfiguration time,
//! * per-iteration data-parallel gradient synchronization,
//! * per-wave makespan/idle accounting (Fig. 2's "idle gaps").

pub mod event;
pub mod faults;
pub mod sim;

pub use event::{EventKind, EventQueue, EventRecord, EventTimeline};
pub use faults::{arrival_frac, FaultConfig, FaultEvent, FaultInjector, TimedFault};
pub use sim::{ClusterSim, CommKind, IterationReport, WaveReport};
