//! Discrete-event execution of parallelism plans over the simulated
//! cluster.
//!
//! The simulator consumes scheduler-produced PLACED plans: every group
//! arrives with its concrete rank set, so ground-truth bandwidths come
//! from the placement the scheduler committed to — the simulator never
//! re-derives placement (no internal `mesh.allocate`). Communication
//! groups are resolved through the caller's [`GroupPool`]; pool misses
//! charge the (simulated) HCCL group-creation cost into the iteration
//! time, which is what makes the paper's reuse claim measurable.

use crate::config::presets::ModelPreset;
use crate::config::{ClusterConfig, TrainStage};
use crate::cost::exact;
use crate::cost::HardwareSpec;
use crate::data::sequence::Sequence;
use crate::parallel::mesh::DeviceMesh;
use crate::parallel::pool::GroupPool;
use crate::parallel::RankId;
use crate::scheduler::{PlacedPlan, Schedule};

/// Communication pattern of the sequence-dimension parallelism.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommKind {
    /// Ring context parallelism (DHP, Megatron CP): P2P KV rotation,
    /// overlappable with attention compute.
    RingCp,
    /// DeepSpeed-Ulysses sequence parallelism: all-to-all activation
    /// redistribution around attention, not overlapped.
    UlyssesA2A,
}

/// Execution report for one wave (one [`PlacedPlan`]).
#[derive(Debug, Clone)]
pub struct WaveReport {
    /// Per-group execution seconds (plan order).
    pub group_times_s: Vec<f64>,
    /// Wave makespan = max group time.
    pub makespan_s: f64,
    /// Fraction of rank·seconds spent idle waiting for the slowest group
    /// (Fig. 2's synchronization stalls). Idle ranks not in any group
    /// count as fully idle.
    pub idle_fraction: f64,
    /// Straggle inflation: how much longer this wave's critical path ran
    /// versus the same placement with no straggling ranks
    /// (`makespan_s − counterfactual makespan`). Exactly 0.0 when no
    /// slowdowns are installed.
    pub straggle_s: f64,
}

/// Execution report for one full training iteration.
#[derive(Debug, Clone)]
pub struct IterationReport {
    /// Per-wave execution reports, in execution order.
    pub waves: Vec<WaveReport>,
    /// Σ wave makespans.
    pub exec_time_s: f64,
    /// Gradient-synchronization time (ZeRO-style all-reduce).
    pub grad_sync_s: f64,
    /// Communication-group reconfiguration time actually CHARGED this
    /// iteration: the pool-miss creation cost minus whatever the caller's
    /// prewarm overlap hid behind the previous step's compute
    /// (`max(0, reconfig_serial_s − slack)`; see
    /// [`ClusterSim::execute_iteration_overlapped`]). With no overlap
    /// slack this equals [`IterationReport::reconfig_serial_s`].
    pub reconfig_time_s: f64,
    /// The fully-serial pool-miss creation cost of this iteration (what a
    /// system without the pipeline's CPU-side prewarm overlap would pay)
    /// — retained for the overlap-ablation comparison. Invariant:
    /// `reconfig_time_s ≤ reconfig_serial_s`.
    pub reconfig_serial_s: f64,
    /// exec + grad sync + charged reconfiguration.
    pub iter_time_s: f64,
    /// Σ per-wave straggle inflation (already inside `exec_time_s`; this
    /// field attributes it). 0.0 when no rank straggled.
    pub straggle_s: f64,
    /// Total tokens processed.
    pub tokens: u64,
    /// Compute seconds discarded to mid-step fault interruption (partial
    /// waves re-executed, torn checkpoint writes). Always 0.0 on the
    /// step-granular path — only the within-step event kernel
    /// ([`crate::session::DhpSession`] with `within_step_faults(true)`)
    /// charges it, and always as `t − wave_start` per interrupted wave
    /// rather than the whole step.
    pub lost_work_s: f64,
    /// Number of in-flight waves interrupted by mid-step faults (each one
    /// contributed to `lost_work_s` and was re-executed on its survivor
    /// plan). Always 0 on the step-granular path.
    pub interrupted_waves: usize,
}

impl IterationReport {
    /// Per-NPU token throughput (the paper's tokens/s/device metric).
    pub fn tokens_per_sec_per_device(&self, npus: usize) -> f64 {
        self.tokens as f64 / self.iter_time_s / npus as f64
    }

    /// Cluster-wide token throughput (k tokens/s, Fig. 5's metric).
    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens as f64 / self.iter_time_s
    }
}

/// The simulated cluster.
#[derive(Debug, Clone)]
pub struct ClusterSim {
    /// Model being trained.
    pub preset: ModelPreset,
    /// Which parameters train (full vs frozen-vision).
    pub stage: TrainStage,
    /// Per-replica hardware spec (aggregates TP×PP member NPUs).
    pub hw: HardwareSpec,
    /// Physical replica topology (bandwidths read off actual rank sets).
    pub mesh: DeviceMesh,
    /// Cluster topology/configuration the mesh was derived from.
    pub cluster: ClusterConfig,
    /// Transient per-rank straggler slowdowns for the CURRENT step
    /// (rank, factor > 1.0). Installed by the session's fault path before
    /// execution and cleared at the next step boundary; a group's time
    /// stretches by the worst factor among its member ranks (lock-step
    /// collectives run at the slowest member's pace). Sparse: empty in
    /// the fault-free path, so that path is bit-identical to the
    /// pre-fault simulator.
    slowdowns: Vec<(RankId, f64)>,
}

impl ClusterSim {
    /// Simulator for `preset` training at `stage` on `cluster`.
    pub fn new(
        preset: ModelPreset,
        stage: TrainStage,
        cluster: ClusterConfig,
    ) -> Self {
        // One simulated "rank" is a full TP×PP replica: its compute rate
        // aggregates the FLOPs of its member NPUs.
        let tpp = (cluster.tp * cluster.pp) as f64;
        let hw = HardwareSpec {
            peak_flops: 376e12 * tpp,
            ..HardwareSpec::default()
        };
        ClusterSim {
            preset,
            stage,
            hw,
            mesh: DeviceMesh::new(&cluster),
            cluster,
            slowdowns: Vec::new(),
        }
    }

    /// Install (or update) a transient slowdown factor for `rank`,
    /// effective until [`ClusterSim::clear_slowdowns`]. Factors below 1.0
    /// are clamped to 1.0 (a straggler never speeds a group up).
    pub fn set_slowdown(&mut self, rank: RankId, factor: f64) {
        let factor = factor.max(1.0);
        match self.slowdowns.iter_mut().find(|(r, _)| *r == rank) {
            Some(entry) => entry.1 = factor,
            None => self.slowdowns.push((rank, factor)),
        }
    }

    /// Remove all installed straggler slowdowns (step boundary).
    pub fn clear_slowdowns(&mut self) {
        self.slowdowns.clear();
    }

    /// Currently installed slowdowns, as (rank, factor) pairs.
    pub fn slowdowns(&self) -> &[(RankId, f64)] {
        &self.slowdowns
    }

    /// Worst slowdown factor among `ranks` (1.0 when none straggle):
    /// lock-step collectives run at the slowest member's pace.
    fn group_stretch(&self, ranks: &[RankId]) -> f64 {
        self.slowdowns
            .iter()
            .filter(|(r, _)| ranks.contains(r))
            .map(|&(_, f)| f)
            .fold(1.0, f64::max)
    }

    /// Ground-truth execution time for one group at `degree` over the
    /// ranks the mesh assigned it.
    fn group_time(
        &self,
        seqs: &[Sequence],
        degree: usize,
        ranks: &[usize],
        comm: CommKind,
    ) -> f64 {
        let bw = self.mesh.ring_bandwidth(ranks);
        match comm {
            CommKind::RingCp => exact::group_time(
                &self.preset,
                self.stage,
                &self.hw,
                seqs,
                degree,
                bw,
            ),
            CommKind::UlyssesA2A => exact::ulysses_group_time(
                &self.preset,
                self.stage,
                &self.hw,
                seqs,
                degree,
                bw,
            ),
        }
    }

    /// Execute one PLACED wave: compute each group's ground-truth time on
    /// the rank set the scheduler committed it to, derive makespan + idle
    /// fraction. The simulator performs no placement of its own.
    pub fn execute_plan(
        &self,
        seqs: &[Sequence],
        plan: &PlacedPlan,
        comm: CommKind,
    ) -> WaveReport {
        let mut group_times = Vec::with_capacity(plan.groups.len());
        let mut base_makespan = 0.0f64;
        for g in &plan.groups {
            let group_seqs: Vec<Sequence> =
                g.seq_idxs.iter().map(|&i| seqs[i].clone()).collect();
            let base = self.group_time(&group_seqs, g.degree, &g.ranks, comm);
            base_makespan = base_makespan.max(base);
            // With no slowdowns installed the stretch is exactly 1.0 and
            // `base * 1.0 == base` bitwise — the fault-free path charges
            // identically to the pre-straggler simulator.
            group_times.push(base * self.group_stretch(&g.ranks));
        }
        let makespan = group_times.iter().fold(0.0f64, |a, &b| a.max(b));
        let straggle_s = makespan - base_makespan;
        // Rank·seconds busy vs available (idle ranks: whole wave idle).
        // "Available" means ranks this job can actually use: slots held
        // by concurrent jobs ([`DeviceMesh::occupy`]) are not idle
        // capacity, so a fragmented mesh is not charged for them.
        let total_ranks = self.mesh.free_replicas().max(1) as f64;
        let busy: f64 = group_times
            .iter()
            .zip(plan.groups.iter())
            .map(|(&t, g)| t * g.degree as f64)
            .sum();
        let idle_fraction = if makespan > 0.0 {
            1.0 - busy / (makespan * total_ranks)
        } else {
            0.0
        };
        WaveReport {
            group_times_s: group_times,
            makespan_s: makespan,
            idle_fraction,
            straggle_s,
        }
    }

    /// Execute a full micro-batch schedule (all waves, serially).
    pub fn execute_schedule(
        &self,
        seqs: &[Sequence],
        schedule: &Schedule,
        comm: CommKind,
    ) -> Vec<WaveReport> {
        schedule
            .waves
            .iter()
            .map(|p| self.execute_plan(seqs, p, comm))
            .collect()
    }

    /// ZeRO-style gradient synchronization per optimizer step: a
    /// reduce-scatter + all-gather over the slowest fabric the ring
    /// actually crosses, 2·P·(N−1)/N bytes in half precision. Identical
    /// for every policy.
    ///
    /// The ring spans this session's *free* ranks, not the raw cluster:
    /// under co-tenancy (other jobs occupying part of the shared mesh)
    /// the DP ring is exactly the free set, so both the participant
    /// count and the intra-vs-inter fabric choice must answer for that
    /// set. On an unoccupied mesh this reduces bit-identically to the
    /// old whole-cluster formula (`free == replicas`, and a multi-node
    /// free set is never intra-node).
    pub fn grad_sync_time(&self) -> f64 {
        let free: Vec<RankId> = (0..self.mesh.replicas)
            .filter(|&r| self.mesh.is_rank_free(r))
            .collect();
        let n = free.len() as f64;
        if n <= 1.0 {
            return 0.0;
        }
        let param_bytes = self.preset.params_b * 1e9 * 2.0;
        let bw = if self.mesh.is_intra_node(&free) {
            self.cluster.intra_bw
        } else {
            self.cluster.inter_bw
        };
        2.0 * param_bytes * (n - 1.0) / n / bw
    }

    /// Execute one full training iteration: a set of micro-batch
    /// schedules (each over its own sequence list) + gradient sync.
    ///
    /// Every placed group is resolved through `pool`; groups not already
    /// established pay the (simulated) HCCL creation cost, charged into
    /// `iter_time_s` as reconfiguration time. Callers persist the pool
    /// across steps (and typically prewarm it at training start), so a
    /// stationary workload's reconfiguration cost decays toward zero —
    /// the measurable form of the paper's group-reuse claim.
    pub fn execute_iteration(
        &self,
        micro_batches: &[(Vec<Sequence>, Schedule)],
        comm: CommKind,
        pool: &mut GroupPool,
    ) -> IterationReport {
        self.execute_iteration_overlapped(micro_batches, comm, pool, 0.0)
    }

    /// [`ClusterSim::execute_iteration`] with overlap-aware
    /// reconfiguration charging.
    ///
    /// The scheduling pipeline prewarms the next step's communication
    /// groups on a CPU thread while the accelerator runs the previous
    /// step (paper §5's producer–consumer overlap), so group creation is
    /// hidden up to the previous step's compute time. `prewarm_slack_s`
    /// is that hideable budget (the caller passes the previous
    /// iteration's `exec_time_s + grad_sync_s`; 0 for the first step or
    /// for a fully-serial system). The charged reconfiguration time is
    /// the non-hidden remainder `max(0, serial − slack)`; the
    /// fully-serial cost is retained in
    /// [`IterationReport::reconfig_serial_s`] so the overlap claim stays
    /// an observable, not an assumption.
    pub fn execute_iteration_overlapped(
        &self,
        micro_batches: &[(Vec<Sequence>, Schedule)],
        comm: CommKind,
        pool: &mut GroupPool,
        prewarm_slack_s: f64,
    ) -> IterationReport {
        let reconfig_before = pool.stats().create_time_s;
        let mut waves = Vec::new();
        let mut exec = 0.0;
        let mut straggle = 0.0;
        let mut tokens = 0u64;
        for (seqs, schedule) in micro_batches {
            tokens += seqs.iter().map(|s| s.len()).sum::<u64>();
            for plan in &schedule.waves {
                // One wave's groups are co-live: acquire them atomically
                // so a capacity-capped pool can only evict groups outside
                // the wave (waves execute serially, so cross-wave
                // eviction — and honest re-creation — is allowed).
                pool.acquire_wave(plan.groups.iter().map(|g| g.pool_key()));
            }
            for w in self.execute_schedule(seqs, schedule, comm) {
                exec += w.makespan_s;
                straggle += w.straggle_s;
                waves.push(w);
            }
        }
        let reconfig_serial = pool.stats().create_time_s - reconfig_before;
        let reconfig = (reconfig_serial - prewarm_slack_s.max(0.0)).max(0.0);
        let grad_sync = self.grad_sync_time();
        IterationReport {
            waves,
            exec_time_s: exec,
            grad_sync_s: grad_sync,
            reconfig_time_s: reconfig,
            reconfig_serial_s: reconfig_serial,
            iter_time_s: exec + grad_sync + reconfig,
            straggle_s: straggle,
            tokens,
            lost_work_s: 0.0,
            interrupted_waves: 0,
        }
    }

    /// Re-place a wave plan onto the surviving mesh after mid-step rank
    /// loss (the within-step event kernel's partial-wave re-execution).
    ///
    /// Groups keep their sequence assignment but drop ranks that are no
    /// longer free on the mesh (taken down by a failure or occupied by a
    /// fence/preemption). A fully-dead group's sequences are re-homed to
    /// the lowest free rank not already used by a surviving group (as a
    /// degree-1 group), or — when no spare rank exists — folded into the
    /// first surviving group. Estimate fields (`est_time_s`, `ring_bw`
    /// refreshed; `est_makespan_s` carried) are best-effort: execution
    /// uses ground-truth [`ClusterSim::execute_plan`] times, so staleness
    /// only affects telemetry, never the charged makespan.
    ///
    /// Returns `None` when every group's rank set is still fully live —
    /// the quiet common case, so callers keep the original plan (and its
    /// pool keys) untouched, preserving bit-identity with the
    /// step-granular reference path.
    pub fn survivor_plan(&self, plan: &PlacedPlan) -> Option<PlacedPlan> {
        let any_dead = plan
            .groups
            .iter()
            .any(|g| g.ranks.iter().any(|&r| !self.mesh.is_rank_free(r)));
        if !any_dead {
            return None;
        }
        let mut groups: Vec<crate::scheduler::PlacedGroup> =
            Vec::with_capacity(plan.groups.len());
        let mut orphans: Vec<(Vec<usize>, crate::cost::WorkloadAgg)> =
            Vec::new();
        for g in &plan.groups {
            let retained: Vec<RankId> = g
                .ranks
                .iter()
                .copied()
                .filter(|&r| self.mesh.is_rank_free(r))
                .collect();
            if retained.is_empty() {
                orphans.push((g.seq_idxs.clone(), g.agg));
            } else if retained.len() == g.ranks.len() {
                groups.push(g.clone());
            } else {
                let ring_bw = self.mesh.ring_bandwidth(&retained);
                groups.push(crate::scheduler::PlacedGroup {
                    degree: retained.len(),
                    seq_idxs: g.seq_idxs.clone(),
                    agg: g.agg,
                    est_time_s: g.est_time_s,
                    ranks: retained,
                    ring_bw,
                });
            }
        }
        for (seq_idxs, agg) in orphans {
            let used: std::collections::BTreeSet<RankId> = groups
                .iter()
                .flat_map(|g| g.ranks.iter().copied())
                .collect();
            let home = (0..self.mesh.replicas)
                .find(|&r| self.mesh.is_rank_free(r) && !used.contains(&r));
            if let Some(r) = home {
                let ring_bw = self.mesh.ring_bandwidth(&[r]);
                groups.push(crate::scheduler::PlacedGroup {
                    degree: 1,
                    seq_idxs,
                    agg,
                    est_time_s: 0.0,
                    ranks: vec![r],
                    ring_bw,
                });
            } else if let Some(first) = groups.first_mut() {
                first.seq_idxs.extend(seq_idxs);
            }
            // No surviving group and no spare rank: the mesh cannot run
            // this wave at all — the session marks such steps failed
            // before execution, so dropping the work here is unreachable
            // in practice.
        }
        Some(PlacedPlan {
            groups,
            est_makespan_s: plan.est_makespan_s,
            search_makespan_s: plan.search_makespan_s,
            replayed_groups: 0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::by_name;
    use crate::config::ClusterConfig;
    use crate::cost::{CostCoeffs, CostModel, MemoryModel};
    use crate::data::datasets::{DatasetKind, DatasetSampler};
    use crate::scheduler::Scheduler;

    fn sim(npus: usize) -> ClusterSim {
        ClusterSim::new(
            by_name("InternVL3-8B").unwrap(),
            TrainStage::Full,
            ClusterConfig::default().with_npus(npus),
        )
    }

    fn dhp_scheduler(s: &ClusterSim) -> Scheduler {
        let cost = CostModel {
            coeffs: CostCoeffs::analytic(&s.preset, s.stage, &s.hw),
            memory: MemoryModel {
                e_bytes: 8192.0 * s.preset.act_bytes_per_token() + 2e9,
                m_states: 2e9,
                m_token: s.preset.act_bytes_per_token(),
            },
        };
        Scheduler::new(cost, s.mesh.clone())
    }

    #[test]
    fn wave_report_consistent() {
        let s = sim(8);
        let sch = dhp_scheduler(&s);
        let mut sampler = DatasetSampler::new(DatasetKind::OpenVid, 61);
        let seqs = sampler.sample_batch(24);
        let schedule = sch.schedule(&seqs);
        for w in s.execute_schedule(&seqs, &schedule, CommKind::RingCp) {
            assert!(w.makespan_s > 0.0);
            assert!((0.0..=1.0).contains(&w.idle_fraction), "{w:?}");
            for &t in &w.group_times_s {
                assert!(t <= w.makespan_s + 1e-12);
            }
        }
    }

    #[test]
    fn estimator_close_to_simulator() {
        // The scheduler's Eq. 8–10 estimates should track the simulator's
        // ground truth within the paper's error band (Table 3: < 8%,
        // allow some slack here across random workloads).
        let s = sim(8);
        let sch = dhp_scheduler(&s);
        let mut sampler = DatasetSampler::new(DatasetKind::InternVid, 67);
        let seqs = sampler.sample_batch(32);
        let schedule = sch.schedule(&seqs);
        let reports = s.execute_schedule(&seqs, &schedule, CommKind::RingCp);
        for (plan, rep) in schedule.waves.iter().zip(&reports) {
            let err =
                (plan.est_makespan_s - rep.makespan_s).abs() / rep.makespan_s;
            assert!(
                err < 0.25,
                "estimate {} vs sim {} (err {err})",
                plan.est_makespan_s,
                rep.makespan_s
            );
        }
    }

    #[test]
    fn iteration_report_totals() {
        let s = sim(16);
        let sch = dhp_scheduler(&s);
        let mut sampler = DatasetSampler::new(DatasetKind::Msrvtt, 71);
        let mbs: Vec<(Vec<Sequence>, Schedule)> = (0..3)
            .map(|_| {
                let seqs = sampler.sample_batch(16);
                let schedule = sch.schedule(&seqs);
                (seqs, schedule)
            })
            .collect();
        let mut pool = crate::parallel::GroupPool::new();
        let rep = s.execute_iteration(&mbs, CommKind::RingCp, &mut pool);
        assert_eq!(
            rep.tokens,
            mbs.iter()
                .map(|(s, _)| s.iter().map(|q| q.len()).sum::<u64>())
                .sum::<u64>()
        );
        assert!(rep.iter_time_s > rep.exec_time_s);
        // Cold pool: every unique group charged exactly once, and with no
        // overlap slack the charged time IS the serial time.
        assert!(rep.reconfig_time_s > 0.0);
        assert_eq!(rep.reconfig_time_s, rep.reconfig_serial_s);
        assert!(
            (rep.reconfig_time_s - pool.stats().create_time_s).abs() < 1e-12
        );
        assert!(
            (rep.iter_time_s
                - (rep.exec_time_s + rep.grad_sync_s + rep.reconfig_time_s))
                .abs()
                < 1e-12
        );
        assert!(rep.tokens_per_sec() > 0.0);
        assert!(rep.tokens_per_sec_per_device(16) * 16.0 - rep.tokens_per_sec() < 1e-9);
        // A warm pool re-executing the same iteration pays nothing.
        let rep2 = s.execute_iteration(&mbs, CommKind::RingCp, &mut pool);
        assert_eq!(rep2.reconfig_time_s, 0.0);
        assert_eq!(rep2.reconfig_serial_s, 0.0);
        assert!(rep2.iter_time_s < rep.iter_time_s + 1e-12);
    }

    #[test]
    fn overlap_slack_hides_reconfiguration_up_to_prev_compute() {
        let s = sim(16);
        let sch = dhp_scheduler(&s);
        let mut sampler = DatasetSampler::new(DatasetKind::Msrvtt, 79);
        let seqs = sampler.sample_batch(16);
        let schedule = sch.schedule(&seqs);
        let mbs = vec![(seqs, schedule)];

        // Cold pool, slack larger than any creation cost: everything hides.
        let mut pool = crate::parallel::GroupPool::new();
        let hidden =
            s.execute_iteration_overlapped(&mbs, CommKind::RingCp, &mut pool, 1e9);
        assert!(hidden.reconfig_serial_s > 0.0, "cold pool must create groups");
        assert_eq!(hidden.reconfig_time_s, 0.0, "fully hidden behind slack");
        assert!(
            (hidden.iter_time_s - (hidden.exec_time_s + hidden.grad_sync_s)).abs()
                < 1e-12
        );

        // Cold pool, partial slack: charged = serial − slack exactly.
        let mut pool2 = crate::parallel::GroupPool::new();
        let probe =
            s.execute_iteration_overlapped(&mbs, CommKind::RingCp, &mut pool2, 0.0);
        let slack = probe.reconfig_serial_s / 2.0;
        let mut pool3 = crate::parallel::GroupPool::new();
        let partial = s.execute_iteration_overlapped(
            &mbs,
            CommKind::RingCp,
            &mut pool3,
            slack,
        );
        assert!(
            (partial.reconfig_time_s - (partial.reconfig_serial_s - slack)).abs()
                < 1e-12
        );
        // The invariant every caller relies on.
        assert!(partial.reconfig_time_s <= partial.reconfig_serial_s);
        // A negative slack is treated as no slack, not extra charge.
        let mut pool4 = crate::parallel::GroupPool::new();
        let clamped = s.execute_iteration_overlapped(
            &mbs,
            CommKind::RingCp,
            &mut pool4,
            -5.0,
        );
        assert_eq!(clamped.reconfig_time_s, clamped.reconfig_serial_s);
    }

    #[test]
    fn grad_sync_scales_with_model_and_cluster() {
        let small = ClusterSim::new(
            by_name("InternVL3-2B").unwrap(),
            TrainStage::Full,
            ClusterConfig::default().with_npus(16),
        );
        let big = sim(16);
        assert!(big.grad_sync_time() > small.grad_sync_time());
        // Single node uses the fast fabric.
        let single = ClusterSim::new(
            by_name("InternVL3-8B").unwrap(),
            TrainStage::Full,
            ClusterConfig::default().with_npus(8),
        );
        assert!(single.grad_sync_time() < big.grad_sync_time());
    }

    #[test]
    fn straggler_stretches_only_its_waves() {
        let s = sim(8);
        let sch = dhp_scheduler(&s);
        let mut sampler = DatasetSampler::new(DatasetKind::OpenVid, 77);
        let seqs = sampler.sample_batch(24);
        let schedule = sch.schedule(&seqs);
        let clean = s.execute_schedule(&seqs, &schedule, CommKind::RingCp);
        // Fault-free path reports exactly zero straggle.
        assert!(clean.iter().all(|w| w.straggle_s == 0.0));

        let mut slow = s.clone();
        slow.set_slowdown(0, 2.0);
        let stretched = slow.execute_schedule(&seqs, &schedule, CommKind::RingCp);
        for (cw, sw) in clean.iter().zip(&stretched) {
            assert!(sw.makespan_s >= cw.makespan_s - 1e-15);
            assert!(sw.straggle_s >= 0.0);
        }
        // Every wave placing rank 0 in its critical-path group inflates.
        let total_clean: f64 = clean.iter().map(|w| w.makespan_s).sum();
        let total_slow: f64 = stretched.iter().map(|w| w.makespan_s).sum();
        assert!(
            total_slow > total_clean,
            "a 2x straggler on rank 0 must cost wall-clock"
        );
        let total_straggle: f64 = stretched.iter().map(|w| w.straggle_s).sum();
        assert!(
            (total_slow - total_clean - total_straggle).abs() < 1e-9,
            "straggle attribution must equal the inflation"
        );
        // Clearing restores the fault-free timings bit-for-bit.
        slow.clear_slowdowns();
        let restored = slow.execute_schedule(&seqs, &schedule, CommKind::RingCp);
        for (cw, rw) in clean.iter().zip(&restored) {
            assert_eq!(cw.makespan_s.to_bits(), rw.makespan_s.to_bits());
        }
    }

    #[test]
    fn slowdown_below_one_is_clamped() {
        let mut s = sim(8);
        s.set_slowdown(2, 0.25);
        assert_eq!(s.slowdowns(), &[(2usize, 1.0)]);
        s.set_slowdown(2, 3.0);
        assert_eq!(s.slowdowns(), &[(2usize, 3.0)]);
    }

    #[test]
    fn survivor_plan_drops_dead_ranks_and_rehomes_orphans() {
        let mut s = sim(8);
        let sch = dhp_scheduler(&s);
        let mut sampler = DatasetSampler::new(DatasetKind::OpenVid, 83);
        let seqs = sampler.sample_batch(24);
        let schedule = sch.schedule(&seqs);
        let plan = &schedule.waves[0];
        // Fully-live mesh: no re-placement, callers keep the original.
        assert!(s.survivor_plan(plan).is_none());

        // Kill one rank of some multi-rank group: that group shrinks,
        // untouched groups survive verbatim, and the wave still covers
        // every sequence exactly once.
        let victim_group = plan
            .groups
            .iter()
            .position(|g| g.ranks.len() > 1)
            .expect("schedule should place at least one CP group");
        let dead = plan.groups[victim_group].ranks[0];
        s.mesh.occupy(&[dead]);
        let shrunk = s.survivor_plan(plan).expect("dead rank forces re-place");
        assert!(shrunk
            .groups
            .iter()
            .all(|g| g.ranks.iter().all(|&r| s.mesh.is_rank_free(r))));
        assert!(shrunk.groups.iter().all(|g| g.degree == g.ranks.len()));
        let mut orig_idxs: Vec<usize> =
            plan.groups.iter().flat_map(|g| g.seq_idxs.clone()).collect();
        let mut new_idxs: Vec<usize> = shrunk
            .groups
            .iter()
            .flat_map(|g| g.seq_idxs.clone())
            .collect();
        orig_idxs.sort_unstable();
        new_idxs.sort_unstable();
        assert_eq!(orig_idxs, new_idxs, "no sequence lost or duplicated");
        // The survivor plan executes (ground truth, no estimates needed).
        let w = s.execute_plan(&seqs, &shrunk, CommKind::RingCp);
        assert!(w.makespan_s > 0.0);

        // Kill an entire group: its sequences re-home to a free rank (or
        // fold into a survivor), still covering everything.
        let all_of: Vec<RankId> = plan.groups[victim_group].ranks.clone();
        for &r in &all_of[1..] {
            s.mesh.occupy(&[r]);
        }
        let rehomed = s.survivor_plan(plan).expect("whole group dead");
        let mut re_idxs: Vec<usize> = rehomed
            .groups
            .iter()
            .flat_map(|g| g.seq_idxs.clone())
            .collect();
        re_idxs.sort_unstable();
        assert_eq!(orig_idxs, re_idxs, "orphaned sequences must re-home");
        assert!(rehomed
            .groups
            .iter()
            .all(|g| g.ranks.iter().all(|&r| s.mesh.is_rank_free(r))));
    }

    #[test]
    fn ulysses_differs_from_ring() {
        let s = sim(8);
        let sch = dhp_scheduler(&s);
        let mut sampler = DatasetSampler::new(DatasetKind::OpenVid, 73);
        let seqs = sampler.sample_batch(16);
        let schedule = sch.schedule(&seqs);
        let ring: f64 = s
            .execute_schedule(&seqs, &schedule, CommKind::RingCp)
            .iter()
            .map(|w| w.makespan_s)
            .sum();
        let a2a: f64 = s
            .execute_schedule(&seqs, &schedule, CommKind::UlyssesA2A)
            .iter()
            .map(|w| w.makespan_s)
            .sum();
        assert!(ring > 0.0 && a2a > 0.0);
        assert!((ring - a2a).abs() > 1e-9, "patterns must differ");
    }

    #[test]
    fn grad_sync_answers_for_the_free_set() {
        // Unfragmented mesh: bit-identical to the whole-cluster formula
        // (free == replicas, multi-node set → inter fabric).
        let s = sim(16);
        let n = s.mesh.replicas as f64;
        let expected =
            2.0 * s.preset.params_b * 1e9 * 2.0 * (n - 1.0) / n / s.cluster.inter_bw;
        assert_eq!(s.grad_sync_time().to_bits(), expected.to_bits());

        // Co-tenants occupy everything except two ranks on node 0: the
        // surviving participants sync over the fast intra fabric with a
        // smaller (n−1)/n factor — the whole-cluster formula would keep
        // charging the 16-way inter-node all-reduce.
        let mut frag = sim(16);
        let held: Vec<RankId> = (2..frag.mesh.replicas).collect();
        frag.mesh.occupy(&held);
        let intra_expected =
            2.0 * frag.preset.params_b * 1e9 * 2.0 * (2.0 - 1.0) / 2.0
                / frag.cluster.intra_bw;
        assert_eq!(frag.grad_sync_time().to_bits(), intra_expected.to_bits());
        assert!(frag.grad_sync_time() < s.grad_sync_time() / 10.0);

        // A cross-node free pair still pays the slow fabric, but only for
        // two participants; one (or zero) free ranks sync nothing.
        frag.mesh.release(&held);
        let per_node = frag.mesh.replicas_per_node;
        let cross: Vec<RankId> = (0..frag.mesh.replicas)
            .filter(|&r| r != 0 && r != per_node)
            .collect();
        frag.mesh.occupy(&cross);
        let pair_expected =
            2.0 * frag.preset.params_b * 1e9 * 2.0 * (2.0 - 1.0) / 2.0
                / frag.cluster.inter_bw;
        assert_eq!(frag.grad_sync_time().to_bits(), pair_expected.to_bits());
        frag.mesh.occupy(&[per_node]);
        assert_eq!(frag.grad_sync_time(), 0.0, "one rank has no peers");
        frag.mesh.occupy(&[0]);
        assert_eq!(frag.grad_sync_time(), 0.0, "empty free set syncs nothing");
    }

    #[test]
    fn fabric_capacity_tracks_free_replicas_under_occupancy() {
        // Property: under random co-tenant occupancy traces, the
        // scheduler's fabric snapshot and the simulator agree on the rank
        // budget, and grad sync always answers for exactly that free set.
        use crate::scheduler::fabric::FabricModel;
        use crate::util::rng::Rng;
        for seed in 0..8u64 {
            let mut s = sim(16);
            let mut rng = Rng::new(0xFAB ^ seed);
            let mut held: Vec<RankId> = Vec::new();
            for step in 0..24 {
                if rng.bool(0.6) && held.len() + 1 < s.mesh.replicas {
                    let free: Vec<RankId> = (0..s.mesh.replicas)
                        .filter(|&r| s.mesh.is_rank_free(r))
                        .collect();
                    let pick =
                        free[rng.range_u64(0, free.len() as u64) as usize];
                    s.mesh.occupy(&[pick]);
                    held.push(pick);
                } else if let Some(back) = held.pop() {
                    s.mesh.release(&[back]);
                }
                let fabric = FabricModel::mesh_backed(&s.mesh, None);
                assert_eq!(
                    fabric.capacity(),
                    s.mesh.free_replicas(),
                    "seed {seed} step {step}: fabric capacity must equal \
                     the mesh's free replicas"
                );
                let free: Vec<RankId> = (0..s.mesh.replicas)
                    .filter(|&r| s.mesh.is_rank_free(r))
                    .collect();
                let gs = s.grad_sync_time();
                if free.len() <= 1 {
                    assert_eq!(gs, 0.0);
                } else {
                    let n = free.len() as f64;
                    let bw = if s.mesh.is_intra_node(&free) {
                        s.cluster.intra_bw
                    } else {
                        s.cluster.inter_bw
                    };
                    let expect = 2.0 * s.preset.params_b * 1e9 * 2.0
                        * (n - 1.0)
                        / n
                        / bw;
                    assert_eq!(gs.to_bits(), expect.to_bits());
                }
            }
        }
    }
}
