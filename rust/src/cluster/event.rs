//! Deterministic discrete-event kernel for within-step fault
//! interleaving (the dslab `simcore` style, ROADMAP "Discrete-event
//! `ClusterSim`").
//!
//! The step-granular simulator ([`crate::cluster::ClusterSim`]) charges
//! every fault at the next step boundary; production faults land
//! *mid-step* — a rank dies in wave 3 of 7, a checkpoint write overlaps
//! compute, a preemption lease expires halfway through an iteration.
//! This module provides the substrate the session's within-step
//! execution path ([`crate::session::SessionBuilder::within_step_faults`])
//! runs on:
//!
//! * [`EventQueue`] — a monotone virtual clock over typed events with a
//!   stable `(time, seq)` tie-break (`f64::total_cmp`, then insertion
//!   sequence), so a permuted-but-equal-time fault trace replays to the
//!   SAME event order (the golden-replay invariant).
//! * [`EventKind`]/[`EventRecord`]/[`EventTimeline`] — the typed event
//!   log a step's execution leaves behind, serializable through
//!   [`crate::util::json`] and digestible into
//!   [`crate::session::StepReport::digest`].
//!
//! Digest coverage is deliberately asymmetric: only *fault-driven*
//! records ([`EventKind::is_fault_driven`] — arrivals, interruptions,
//! recovery stalls, torn checkpoint writes) are hashed. Quiet-derivable
//! records (wave start/finish, checkpoint begin/end, gradient sync) are
//! pure functions of the schedule and would otherwise break the
//! zero-drift invariant: a quiet-injector event-kernel run must stay
//! digest-bit-identical to the step-granular reference, which logs no
//! timeline at all.

use std::cmp::Ordering;
use std::collections::{BTreeSet, BinaryHeap};
use std::hash::{Hash, Hasher};

use crate::cluster::faults::FaultEvent;
use crate::util::json::{self, Json};

/// One typed occurrence on a step's virtual timeline.
///
/// `mb`/`wave` index into the step's micro-batch list and that
/// micro-batch's wave list; times are virtual seconds from the step's
/// start.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// A placed wave began executing (or re-executing, after an
    /// interruption) at this instant.
    WaveStart {
        /// Micro-batch index within the step.
        mb: usize,
        /// Wave index within the micro-batch's schedule.
        wave: usize,
    },
    /// A wave ran to completion; its makespan is committed to
    /// `exec_time_s`.
    WaveFinish {
        /// Micro-batch index within the step.
        mb: usize,
        /// Wave index within the micro-batch's schedule.
        wave: usize,
        /// The completed run's makespan (seconds).
        makespan_s: f64,
    },
    /// An injector fault landed at this virtual instant (fault-driven).
    FaultArrival(
        /// The fault that arrived.
        FaultEvent,
    ),
    /// The in-flight wave lost a member rank and was aborted; `lost_s`
    /// is exactly `t − wave_start` — the partial-wave charge that
    /// replaces the step-granular whole-step replay (fault-driven).
    WaveInterrupted {
        /// Micro-batch index of the aborted wave.
        mb: usize,
        /// Wave index of the aborted wave.
        wave: usize,
        /// Virtual seconds of work discarded (`t − wave_start`).
        lost_s: f64,
    },
    /// The cluster stalled to recover (checkpoint-state restore and/or
    /// re-warming torn communication groups) before the interrupted
    /// wave re-executes on its survivor plan (fault-driven).
    RecoveryStall {
        /// Stall span in virtual seconds.
        stall_s: f64,
    },
    /// A checkpoint write (issued at the previous step's cadence) began
    /// streaming at this instant.
    CkptBegin {
        /// Step index the checkpoint snapshots.
        id: u64,
    },
    /// The checkpoint write completed; `id` becomes the newest restore
    /// point.
    CkptEnd {
        /// Step index the checkpoint snapshots.
        id: u64,
    },
    /// A rank failure landed inside the write window: the partial write
    /// is discarded and any restore falls back to the PREVIOUS completed
    /// checkpoint (fault-driven).
    CkptTorn {
        /// Step index of the torn (never-completed) checkpoint.
        id: u64,
        /// The newest checkpoint that HAD completed when the write tore
        /// (`None` if no write ever completed).
        restore_from: Option<u64>,
        /// Write seconds wasted on the discarded partial checkpoint.
        lost_write_s: f64,
    },
    /// Gradient synchronization started at this instant (its span closes
    /// the step's execution timeline).
    GradSync {
        /// All-reduce span in virtual seconds.
        span_s: f64,
    },
}

impl EventKind {
    /// True for records that exist ONLY because a fault landed —
    /// exactly the set [`EventTimeline::digest_into`] hashes. Quiet runs
    /// produce none, which keeps the event kernel digest-bit-identical
    /// to the (timeline-less) step-granular reference.
    pub fn is_fault_driven(&self) -> bool {
        matches!(
            self,
            EventKind::FaultArrival(_)
                | EventKind::WaveInterrupted { .. }
                | EventKind::RecoveryStall { .. }
                | EventKind::CkptTorn { .. }
        )
    }

    /// Hash the semantic content (f64 fields by bits) into a digest.
    pub fn digest_into(&self, h: &mut impl Hasher) {
        match self {
            EventKind::WaveStart { mb, wave } => {
                0u8.hash(h);
                mb.hash(h);
                wave.hash(h);
            }
            EventKind::WaveFinish { mb, wave, makespan_s } => {
                1u8.hash(h);
                mb.hash(h);
                wave.hash(h);
                makespan_s.to_bits().hash(h);
            }
            EventKind::FaultArrival(ev) => {
                2u8.hash(h);
                ev.digest_into(h);
            }
            EventKind::WaveInterrupted { mb, wave, lost_s } => {
                3u8.hash(h);
                mb.hash(h);
                wave.hash(h);
                lost_s.to_bits().hash(h);
            }
            EventKind::RecoveryStall { stall_s } => {
                4u8.hash(h);
                stall_s.to_bits().hash(h);
            }
            EventKind::CkptBegin { id } => {
                5u8.hash(h);
                id.hash(h);
            }
            EventKind::CkptEnd { id } => {
                6u8.hash(h);
                id.hash(h);
            }
            EventKind::CkptTorn { id, restore_from, lost_write_s } => {
                7u8.hash(h);
                id.hash(h);
                match restore_from {
                    None => 0u8.hash(h),
                    Some(from) => {
                        1u8.hash(h);
                        from.hash(h);
                    }
                }
                lost_write_s.to_bits().hash(h);
            }
            EventKind::GradSync { span_s } => {
                8u8.hash(h);
                span_s.to_bits().hash(h);
            }
        }
    }

    /// Stable machine-readable label (the JSON `kind` field).
    pub fn label(&self) -> &'static str {
        match self {
            EventKind::WaveStart { .. } => "wave_start",
            EventKind::WaveFinish { .. } => "wave_finish",
            EventKind::FaultArrival(_) => "fault_arrival",
            EventKind::WaveInterrupted { .. } => "wave_interrupted",
            EventKind::RecoveryStall { .. } => "recovery_stall",
            EventKind::CkptBegin { .. } => "ckpt_begin",
            EventKind::CkptEnd { .. } => "ckpt_end",
            EventKind::CkptTorn { .. } => "ckpt_torn",
            EventKind::GradSync { .. } => "grad_sync",
        }
    }

    /// Serialize to a [`crate::util::json`] value (the golden-replay
    /// log format).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![("kind", json::s(self.label()))];
        match self {
            EventKind::WaveStart { mb, wave } => {
                fields.push(("mb", json::num(*mb as f64)));
                fields.push(("wave", json::num(*wave as f64)));
            }
            EventKind::WaveFinish { mb, wave, makespan_s } => {
                fields.push(("mb", json::num(*mb as f64)));
                fields.push(("wave", json::num(*wave as f64)));
                fields.push(("makespan_s", json::num(*makespan_s)));
            }
            EventKind::FaultArrival(ev) => {
                fields.push(("fault", fault_to_json(ev)));
            }
            EventKind::WaveInterrupted { mb, wave, lost_s } => {
                fields.push(("mb", json::num(*mb as f64)));
                fields.push(("wave", json::num(*wave as f64)));
                fields.push(("lost_s", json::num(*lost_s)));
            }
            EventKind::RecoveryStall { stall_s } => {
                fields.push(("stall_s", json::num(*stall_s)));
            }
            EventKind::CkptBegin { id } | EventKind::CkptEnd { id } => {
                fields.push(("id", json::num(*id as f64)));
            }
            EventKind::CkptTorn { id, restore_from, lost_write_s } => {
                fields.push(("id", json::num(*id as f64)));
                fields.push((
                    "restore_from",
                    match restore_from {
                        Some(from) => json::num(*from as f64),
                        None => Json::Null,
                    },
                ));
                fields.push(("lost_write_s", json::num(*lost_write_s)));
            }
            EventKind::GradSync { span_s } => {
                fields.push(("span_s", json::num(*span_s)));
            }
        }
        json::obj(fields)
    }
}

/// Serialize a [`FaultEvent`] for the event log.
fn fault_to_json(ev: &FaultEvent) -> Json {
    match ev {
        FaultEvent::RankFailure { rank } => json::obj(vec![
            ("fault", json::s("rank_failure")),
            ("rank", json::num(*rank as f64)),
        ]),
        FaultEvent::Straggler { rank, slowdown } => json::obj(vec![
            ("fault", json::s("straggler")),
            ("rank", json::num(*rank as f64)),
            ("slowdown", json::num(*slowdown)),
        ]),
        FaultEvent::Preemption { ranks, duration_steps } => json::obj(vec![
            ("fault", json::s("preemption")),
            (
                "ranks",
                json::arr(ranks.iter().map(|&r| json::num(r as f64)).collect()),
            ),
            ("duration_steps", json::num(*duration_steps as f64)),
        ]),
        FaultEvent::Recovery { ranks } => json::obj(vec![
            ("fault", json::s("recovery")),
            (
                "ranks",
                json::arr(ranks.iter().map(|&r| json::num(r as f64)).collect()),
            ),
        ]),
    }
}

/// One logged event: virtual time, the kernel-assigned sequence number
/// (unique within the step, the deterministic tie-break), and the typed
/// payload.
#[derive(Debug, Clone, PartialEq)]
pub struct EventRecord {
    /// Virtual seconds from the step's start.
    pub time_s: f64,
    /// Kernel-assigned insertion sequence (total order at equal times).
    pub seq: u64,
    /// The typed event.
    pub kind: EventKind,
}

impl EventRecord {
    /// Serialize to a [`crate::util::json`] value.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("t", json::num(self.time_s)),
            ("seq", json::num(self.seq as f64)),
            ("event", self.kind.to_json()),
        ])
    }
}

/// Heap entry; ordering is REVERSED so [`BinaryHeap`] (a max-heap) pops
/// the earliest `(time, seq)` first.
#[derive(Debug)]
struct Entry {
    time_s: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}

impl Eq for Entry {}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // `total_cmp` gives a total order over f64 (no NaN panics) and
        // the seq tie-break makes equal-time pops insertion-stable.
        other
            .time_s
            .total_cmp(&self.time_s)
            .then(other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic event queue: a monotone virtual clock over typed
/// events with a stable `(time, seq)` tie-break.
///
/// * `push` clamps the requested time to the current clock — virtual
///   time never runs backwards, even if a handler schedules "in the
///   past".
/// * `pop` returns events in `(time, seq)` order and advances the
///   clock; cancelled sequence numbers are skipped silently (how an
///   interrupted wave's pending finish is withdrawn).
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    now_s: f64,
    next_seq: u64,
    cancelled: BTreeSet<u64>,
}

impl EventQueue {
    /// An empty queue at virtual time 0.
    pub fn new() -> Self {
        EventQueue::default()
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now_s(&self) -> f64 {
        self.now_s
    }

    /// Allocate a sequence number WITHOUT enqueuing — for records a
    /// handler synthesizes directly into the [`EventTimeline`] at the
    /// current instant (interruptions, stalls, torn writes), keeping one
    /// global total order across queued and synthesized records.
    pub fn alloc_seq(&mut self) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        seq
    }

    /// Schedule `kind` at `time_s` (clamped to the monotone clock).
    /// Returns the sequence number, usable with [`EventQueue::cancel`].
    pub fn push(&mut self, time_s: f64, kind: EventKind) -> u64 {
        let seq = self.alloc_seq();
        self.heap.push(Entry {
            time_s: time_s.max(self.now_s),
            seq,
            kind,
        });
        seq
    }

    /// Withdraw a scheduled event (no-op if it already popped).
    pub fn cancel(&mut self, seq: u64) {
        self.cancelled.insert(seq);
    }

    /// Pop the earliest live event, advancing the clock. `None` when
    /// the queue is exhausted.
    pub fn pop(&mut self) -> Option<EventRecord> {
        while let Some(e) = self.heap.pop() {
            if self.cancelled.remove(&e.seq) {
                continue;
            }
            self.now_s = self.now_s.max(e.time_s);
            return Some(EventRecord {
                time_s: e.time_s,
                seq: e.seq,
                kind: e.kind,
            });
        }
        None
    }
}

/// The ordered event log one step's event-driven execution leaves
/// behind ([`crate::session::StepReport::timeline`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EventTimeline {
    records: Vec<EventRecord>,
}

impl EventTimeline {
    /// An empty timeline (what every step-granular step reports).
    pub fn new() -> Self {
        EventTimeline::default()
    }

    /// Append a record (callers pass times/seqs from the step's
    /// [`EventQueue`] so the log shares its total order).
    pub fn log(&mut self, time_s: f64, seq: u64, kind: EventKind) {
        self.records.push(EventRecord { time_s, seq, kind });
    }

    /// The logged records, in execution order.
    pub fn records(&self) -> &[EventRecord] {
        &self.records
    }

    /// Number of logged records.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True when nothing was logged (every step-granular step).
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Hash ONLY the fault-driven records (count, then each record's
    /// time bits, seq, and payload). Quiet runs — on either execution
    /// path — hash an empty set, preserving the zero-drift invariant;
    /// any fault-driven divergence (a different arrival instant, a
    /// different interruption) changes the step digest.
    pub fn digest_into(&self, h: &mut impl Hasher) {
        let driven: Vec<&EventRecord> = self
            .records
            .iter()
            .filter(|r| r.kind.is_fault_driven())
            .collect();
        driven.len().hash(h);
        for r in driven {
            r.time_s.to_bits().hash(h);
            r.seq.hash(h);
            r.kind.digest_into(h);
        }
    }

    /// Serialize the full log (quiet-derivable records included) for
    /// the golden-replay test and incident dumps.
    pub fn to_json(&self) -> Json {
        json::arr(self.records.iter().map(|r| r.to_json()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::hash_map::DefaultHasher;

    fn digest(t: &EventTimeline) -> u64 {
        let mut h = DefaultHasher::new();
        t.digest_into(&mut h);
        h.finish()
    }

    #[test]
    fn pops_in_time_then_seq_order() {
        let mut q = EventQueue::new();
        q.push(2.0, EventKind::CkptBegin { id: 2 });
        q.push(1.0, EventKind::CkptBegin { id: 1 });
        q.push(1.0, EventKind::CkptBegin { id: 11 });
        q.push(0.5, EventKind::CkptBegin { id: 0 });
        let order: Vec<u64> = std::iter::from_fn(|| q.pop())
            .map(|r| match r.kind {
                EventKind::CkptBegin { id } => id,
                _ => unreachable!(),
            })
            .collect();
        // Equal times (1 and 11) pop in insertion order.
        assert_eq!(order, vec![0, 1, 11, 2]);
    }

    #[test]
    fn clock_is_monotone_and_push_clamps() {
        let mut q = EventQueue::new();
        q.push(5.0, EventKind::GradSync { span_s: 0.0 });
        assert_eq!(q.pop().unwrap().time_s, 5.0);
        assert_eq!(q.now_s(), 5.0);
        // Scheduling "in the past" lands at the current instant.
        q.push(1.0, EventKind::GradSync { span_s: 0.0 });
        let r = q.pop().unwrap();
        assert_eq!(r.time_s, 5.0);
        assert_eq!(q.now_s(), 5.0);
    }

    #[test]
    fn cancelled_events_never_pop() {
        let mut q = EventQueue::new();
        let keep = q.push(1.0, EventKind::CkptBegin { id: 1 });
        let drop = q.push(0.5, EventKind::CkptBegin { id: 99 });
        q.cancel(drop);
        let r = q.pop().unwrap();
        assert_eq!(r.seq, keep);
        assert!(q.pop().is_none());
    }

    #[test]
    fn digest_covers_only_fault_driven_records() {
        let mut quietish = EventTimeline::new();
        quietish.log(0.0, 0, EventKind::WaveStart { mb: 0, wave: 0 });
        quietish.log(
            1.0,
            1,
            EventKind::WaveFinish { mb: 0, wave: 0, makespan_s: 1.0 },
        );
        quietish.log(1.0, 2, EventKind::GradSync { span_s: 0.2 });
        // Quiet-derivable records hash like an empty log.
        assert_eq!(digest(&quietish), digest(&EventTimeline::new()));

        let mut faulty = quietish.clone();
        faulty.log(
            0.5,
            3,
            EventKind::FaultArrival(FaultEvent::RankFailure { rank: 2 }),
        );
        assert_ne!(digest(&faulty), digest(&quietish));
        // Same fault at a different instant is a different digest.
        let mut shifted = quietish.clone();
        shifted.log(
            0.6,
            3,
            EventKind::FaultArrival(FaultEvent::RankFailure { rank: 2 }),
        );
        assert_ne!(digest(&faulty), digest(&shifted));
    }

    #[test]
    fn json_round_trips_through_util_json() {
        let mut t = EventTimeline::new();
        t.log(0.0, 0, EventKind::WaveStart { mb: 0, wave: 1 });
        t.log(
            0.25,
            1,
            EventKind::FaultArrival(FaultEvent::Preemption {
                ranks: vec![1, 3],
                duration_steps: 2,
            }),
        );
        t.log(
            0.25,
            2,
            EventKind::CkptTorn { id: 4, restore_from: Some(2), lost_write_s: 0.25 },
        );
        let text = t.to_json().to_string_pretty();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(
            arr[1].get("event").unwrap().get("kind").unwrap().as_str().unwrap(),
            "fault_arrival"
        );
        assert_eq!(
            arr[2].get("event").unwrap().get("restore_from").unwrap().as_f64().unwrap(),
            2.0
        );
    }
}
