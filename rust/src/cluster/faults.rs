//! Deterministic fault injection for the simulated cluster.
//!
//! Production MLLM training at scale is dominated not by steady-state
//! throughput but by *workload resilience* — rank failures, stragglers,
//! and co-tenant preemption (MegaScale-Omni, PAPERS.md). This module
//! generates per-step fault traces from a seeded [`crate::util::rng::Rng`]
//! so every resilience experiment is bit-reproducible: same seed, same
//! trace, same goodput numbers.
//!
//! The injector is a pure event *source*. It tracks which ranks it has
//! taken down (so repairs re-admit exactly those ranks and victim draws
//! only target live ranks) but applies nothing itself — the consumer
//! ([`crate::session::DhpSession`]) owns the mesh, the group pool, and
//! the recovery cost accounting.

use std::collections::BTreeMap;
use std::hash::{Hash, Hasher};

use crate::parallel::RankId;
use crate::util::rng::Rng;

/// One fault-domain event at a step boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultEvent {
    /// A rank dies (hardware fault / kernel panic). The job loses the
    /// replica until its repair completes and pays a checkpoint restore
    /// plus the work since the last checkpoint.
    RankFailure {
        /// The rank that died.
        rank: RankId,
    },
    /// A rank runs slow this step (thermal throttling, network
    /// congestion, a noisy neighbor): its groups' critical paths stretch
    /// by `slowdown`.
    Straggler {
        /// The slow rank.
        rank: RankId,
        /// Multiplicative slowdown factor (> 1.0).
        slowdown: f64,
    },
    /// A co-tenant preempts a set of ranks for a bounded number of steps.
    /// Cheaper than a failure: no state is lost, the job just shrinks.
    Preemption {
        /// The preempted ranks (sorted).
        ranks: Vec<RankId>,
        /// How many steps the ranks stay preempted.
        duration_steps: u64,
    },
    /// Previously lost ranks return to service (repair completed or the
    /// preemption lease expired).
    Recovery {
        /// The ranks re-admitted (sorted).
        ranks: Vec<RankId>,
    },
}

impl FaultEvent {
    /// Hash the semantic content into a step digest (used by
    /// [`crate::session::StepReport::digest`]; f64 fields hash by bits).
    pub fn digest_into(&self, h: &mut impl Hasher) {
        match self {
            FaultEvent::RankFailure { rank } => {
                0u8.hash(h);
                rank.hash(h);
            }
            FaultEvent::Straggler { rank, slowdown } => {
                1u8.hash(h);
                rank.hash(h);
                slowdown.to_bits().hash(h);
            }
            FaultEvent::Preemption {
                ranks,
                duration_steps,
            } => {
                2u8.hash(h);
                ranks.hash(h);
                duration_steps.hash(h);
            }
            FaultEvent::Recovery { ranks } => {
                3u8.hash(h);
                ranks.hash(h);
            }
        }
    }
}

/// A fault event tagged with WHERE inside its step it lands: `at_frac`
/// ∈ [0, 1) positions the arrival on the step's nominal (fault-free)
/// execution span. The step-granular path ignores the tag (faults apply
/// at the boundary); the within-step event kernel multiplies it by the
/// quiet makespan to get the virtual arrival time.
#[derive(Debug, Clone, PartialEq)]
pub struct TimedFault {
    /// Arrival position as a fraction of the step's nominal span.
    pub at_frac: f64,
    /// The fault that arrives there.
    pub event: FaultEvent,
}

impl TimedFault {
    /// Hash the semantic content (fraction by bits) into a digest.
    pub fn digest_into(&self, h: &mut impl Hasher) {
        self.at_frac.to_bits().hash(h);
        self.event.digest_into(h);
    }
}

/// Standalone digest of one [`FaultEvent`] (the canonical-order
/// tie-break key for equal-`at_frac` arrivals).
fn event_digest(ev: &FaultEvent) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    ev.digest_into(&mut h);
    h.finish()
}

/// Deterministic arrival fraction for the `index`-th fault of `step`:
/// a pure hash of (step, index, event content) fed through the
/// SplitMix64 generator — NOT the injector's stochastic stream. Both
/// execution paths therefore see the SAME fault set from the same seed
/// (the stream advances identically), and the within-step path derives
/// its arrival instants without perturbing any draw.
pub fn arrival_frac(step: u64, index: usize, event: &FaultEvent) -> f64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    step.hash(&mut h);
    index.hash(&mut h);
    event.digest_into(&mut h);
    Rng::new(h.finish()).uniform()
}

/// Fault-rate configuration. All rates are per training step; zero
/// disables that fault class. [`FaultConfig::quiet`] disables everything,
/// which the session guarantees is behaviorally identical to running
/// with no injector at all (the zero-drift invariant the resilience
/// bench checks).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    /// Mean steps between rank failures, cluster-wide (geometric
    /// inter-arrival with per-step probability `1 / mtbf_steps`).
    /// `0.0` disables failures.
    pub mtbf_steps: f64,
    /// Steps until a failed rank is repaired and recovered.
    pub repair_steps: u64,
    /// Per-step probability that some live rank straggles.
    pub straggler_rate: f64,
    /// Uniform slowdown-factor range `[lo, hi)` for stragglers (> 1.0).
    pub straggler_slowdown: (f64, f64),
    /// Per-step probability of a co-tenant preemption burst.
    pub preemption_rate: f64,
    /// How many ranks one preemption burst takes (clamped so at least
    /// one rank always survives).
    pub preemption_ranks: usize,
    /// Uniform preemption-duration range `[lo, hi)` in steps.
    pub preemption_steps: (u64, u64),
    /// RNG seed: the whole trace is a pure function of this config.
    pub seed: u64,
}

impl FaultConfig {
    /// All fault classes disabled (the zero-drift reference config).
    pub fn quiet(seed: u64) -> Self {
        FaultConfig {
            mtbf_steps: 0.0,
            repair_steps: 0,
            straggler_rate: 0.0,
            straggler_slowdown: (1.0, 1.0),
            preemption_rate: 0.0,
            preemption_ranks: 0,
            preemption_steps: (0, 0),
            seed,
        }
    }

    /// Failures only, at the given MTBF, with a fixed repair lease —
    /// the configuration the MTBF-sweep resilience bench sweeps.
    pub fn mtbf(mtbf_steps: f64, seed: u64) -> Self {
        FaultConfig {
            mtbf_steps,
            repair_steps: 25,
            ..FaultConfig::quiet(seed)
        }
    }

    /// True when every fault class is disabled.
    pub fn is_quiet(&self) -> bool {
        self.mtbf_steps <= 0.0
            && self.straggler_rate <= 0.0
            && self.preemption_rate <= 0.0
    }
}

/// Deterministic, seeded per-step fault-trace generator.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    cfg: FaultConfig,
    replicas: usize,
    rng: Rng,
    /// Rank → step at which its `Recovery` fires. BTreeMap so the event
    /// and victim-draw orders are deterministic.
    down_until: BTreeMap<RankId, u64>,
    /// A fixed per-step trace overriding the stochastic draws (tests,
    /// incident replay).
    script: Option<Vec<Vec<FaultEvent>>>,
    /// A fixed per-step TIMED trace: like `script`, but each event
    /// carries its within-step arrival fraction (the within-step
    /// golden-replay tests). At most one of `script`/`script_timed` is
    /// set.
    script_timed: Option<Vec<Vec<TimedFault>>>,
}

impl FaultInjector {
    /// Injector over a cluster of `replicas` model replicas.
    pub fn new(replicas: usize, cfg: FaultConfig) -> Self {
        assert!(replicas > 0, "fault injector needs at least one replica");
        FaultInjector {
            cfg,
            replicas,
            rng: Rng::new(cfg.seed),
            down_until: BTreeMap::new(),
            script: None,
            script_timed: None,
        }
    }

    /// Injector replaying a fixed trace: `trace[s]` is emitted verbatim
    /// at step `s`; steps beyond the script are quiet. For targeted
    /// tests and reproducing recorded incidents. The scripted author is
    /// responsible for trace sanity (e.g. pairing failures with
    /// recoveries) — the session's own guards skip impossible events
    /// (dead-rank double-kill, last-rank kill) rather than panicking.
    pub fn scripted(replicas: usize, trace: Vec<Vec<FaultEvent>>) -> Self {
        let mut inj = FaultInjector::new(replicas, FaultConfig::quiet(0));
        inj.script = Some(trace);
        inj
    }

    /// Injector replaying a fixed TIMED trace: `trace[s]` is delivered
    /// at step `s` with each event's within-step arrival fraction.
    /// [`FaultInjector::advance`] on such an injector strips the
    /// fractions, so the SAME trace can drive a step-granular session —
    /// the differential comparison the within-step acceptance test runs.
    pub fn scripted_timed(replicas: usize, trace: Vec<Vec<TimedFault>>) -> Self {
        let mut inj = FaultInjector::new(replicas, FaultConfig::quiet(0));
        inj.script_timed = Some(trace);
        inj
    }

    /// The configuration this injector draws from.
    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    fn up_ranks(&self) -> Vec<RankId> {
        (0..self.replicas)
            .filter(|r| !self.down_until.contains_key(r))
            .collect()
    }

    /// Generate the fault events for step boundary `step`. Call exactly
    /// once per step, in step order: the stochastic stream advances with
    /// each call and repairs are keyed on the step numbers seen here.
    pub fn advance(&mut self, step: u64) -> Vec<FaultEvent> {
        if let Some(script) = &self.script {
            return script.get(step as usize).cloned().unwrap_or_default();
        }
        if let Some(script) = &self.script_timed {
            // Step-granular consumer of a timed trace: same events,
            // fractions stripped (faults collapse to the boundary).
            return script
                .get(step as usize)
                .map(|evs| evs.iter().map(|t| t.event.clone()).collect())
                .unwrap_or_default();
        }
        // Quiet configs touch neither the RNG nor the down-set, so a
        // quiet injector is trace-identical to no injector at all.
        if self.cfg.is_quiet() {
            return Vec::new();
        }
        let mut events = Vec::new();
        // 1. Repairs that completed by this step re-admit their ranks.
        let due: Vec<RankId> = self
            .down_until
            .iter()
            .filter(|&(_, &until)| until <= step)
            .map(|(&r, _)| r)
            .collect();
        if !due.is_empty() {
            for r in &due {
                self.down_until.remove(r);
            }
            events.push(FaultEvent::Recovery { ranks: due });
        }
        // 2. Rank failure (geometric inter-arrival at 1/MTBF per step).
        if self.cfg.mtbf_steps > 0.0
            && self.rng.bool((1.0 / self.cfg.mtbf_steps).min(1.0))
        {
            let up = self.up_ranks();
            // Never kill the last survivor: a job with zero replicas is
            // not a degraded run, it is a different experiment.
            if up.len() > 1 {
                let rank = *self.rng.choose(&up);
                self.down_until
                    .insert(rank, step + self.cfg.repair_steps.max(1));
                events.push(FaultEvent::RankFailure { rank });
            }
        }
        // 3. Co-tenant preemption burst.
        if self.cfg.preemption_rate > 0.0 && self.rng.bool(self.cfg.preemption_rate)
        {
            let mut up = self.up_ranks();
            let take = self.cfg.preemption_ranks.min(up.len().saturating_sub(1));
            if take > 0 {
                let (lo, hi) = self.cfg.preemption_steps;
                let duration_steps =
                    if hi > lo { self.rng.range_u64(lo, hi) } else { lo }.max(1);
                self.rng.shuffle(&mut up);
                let mut ranks: Vec<RankId> = up[..take].to_vec();
                ranks.sort_unstable();
                for &r in &ranks {
                    self.down_until.insert(r, step + duration_steps);
                }
                events.push(FaultEvent::Preemption {
                    ranks,
                    duration_steps,
                });
            }
        }
        // 4. Straggler (transient: one step only, no down-set entry).
        if self.cfg.straggler_rate > 0.0 && self.rng.bool(self.cfg.straggler_rate)
        {
            let up = self.up_ranks();
            if !up.is_empty() {
                let rank = *self.rng.choose(&up);
                let (lo, hi) = self.cfg.straggler_slowdown;
                let slowdown =
                    if hi > lo { self.rng.range_f64(lo, hi) } else { lo }.max(1.0);
                events.push(FaultEvent::Straggler { rank, slowdown });
            }
        }
        events
    }

    /// [`FaultInjector::advance`] with within-step arrival instants:
    /// the event source for the session's event-driven execution path.
    ///
    /// Timed scripts replay their fractions verbatim; everything else
    /// (untimed scripts and stochastic draws) is mapped through the
    /// pure [`arrival_frac`] hash, so a stochastic injector feeds BOTH
    /// execution paths the same event stream from the same seed.
    ///
    /// Events return in CANONICAL order — sorted by
    /// `(at_frac, event digest)` — so a permuted-but-equal-time scripted
    /// trace produces the identical event sequence (the tie-break
    /// stability half of the golden-replay test).
    pub fn advance_timed(&mut self, step: u64) -> Vec<TimedFault> {
        let mut timed: Vec<TimedFault> = match &self.script_timed {
            Some(script) => {
                script.get(step as usize).cloned().unwrap_or_default()
            }
            None => self
                .advance(step)
                .into_iter()
                .enumerate()
                .map(|(i, event)| TimedFault {
                    at_frac: arrival_frac(step, i, &event),
                    event,
                })
                .collect(),
        };
        timed.sort_by(|a, b| {
            a.at_frac
                .total_cmp(&b.at_frac)
                .then_with(|| event_digest(&a.event).cmp(&event_digest(&b.event)))
        });
        timed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(cfg: FaultConfig, replicas: usize, steps: u64) -> Vec<Vec<FaultEvent>> {
        let mut inj = FaultInjector::new(replicas, cfg);
        (0..steps).map(|s| inj.advance(s)).collect()
    }

    fn stormy(seed: u64) -> FaultConfig {
        FaultConfig {
            mtbf_steps: 5.0,
            repair_steps: 7,
            straggler_rate: 0.3,
            straggler_slowdown: (1.5, 3.0),
            preemption_rate: 0.1,
            preemption_ranks: 2,
            preemption_steps: (2, 6),
            seed,
        }
    }

    #[test]
    fn same_seed_same_trace() {
        let a = trace(stormy(0xBEEF), 8, 200);
        let b = trace(stormy(0xBEEF), 8, 200);
        assert_eq!(a, b);
        assert!(
            a.iter().any(|evs| !evs.is_empty()),
            "a stormy config must actually emit events"
        );
    }

    #[test]
    fn different_seeds_differ() {
        let a = trace(stormy(1), 8, 200);
        let b = trace(stormy(2), 8, 200);
        assert_ne!(a, b);
    }

    #[test]
    fn quiet_config_emits_nothing() {
        for evs in trace(FaultConfig::quiet(42), 8, 100) {
            assert!(evs.is_empty());
        }
    }

    #[test]
    fn every_failure_eventually_recovers() {
        let cfg = FaultConfig::mtbf(4.0, 0xD0E);
        let mut inj = FaultInjector::new(8, cfg);
        let mut down = std::collections::BTreeSet::new();
        let mut failures = 0u32;
        for step in 0..400 {
            for ev in inj.advance(step) {
                match ev {
                    FaultEvent::RankFailure { rank } => {
                        assert!(down.insert(rank), "double-kill of rank {rank}");
                        failures += 1;
                    }
                    FaultEvent::Recovery { ranks } => {
                        for r in ranks {
                            assert!(down.remove(&r), "recovered a live rank {r}");
                        }
                    }
                    other => panic!("mtbf config emitted {other:?}"),
                }
            }
            assert!(down.len() < 8, "injector downed the whole cluster");
        }
        assert!(failures > 10, "MTBF 4 over 400 steps saw {failures} failures");
        // Drain: with no new failures possible after the last step,
        // everything still down recovers within one repair lease.
        let mut quiet = inj.clone();
        for step in 400..400 + cfg.repair_steps + 1 {
            for ev in quiet.advance(step) {
                if let FaultEvent::Recovery { ranks } = ev {
                    for r in ranks {
                        down.remove(&r);
                    }
                }
            }
        }
        assert!(down.len() <= 1, "still down after lease: {down:?}");
    }

    #[test]
    fn never_downs_the_last_rank() {
        // One replica: failures and preemptions must never fire.
        let mut inj = FaultInjector::new(1, stormy(3));
        for step in 0..200 {
            for ev in inj.advance(step) {
                match ev {
                    FaultEvent::Straggler { .. } => {}
                    other => panic!("single-replica cluster saw {other:?}"),
                }
            }
        }
    }

    #[test]
    fn scripted_trace_replays_verbatim() {
        let script = vec![
            vec![],
            vec![FaultEvent::RankFailure { rank: 3 }],
            vec![FaultEvent::Recovery { ranks: vec![3] }],
        ];
        let mut inj = FaultInjector::scripted(4, script.clone());
        for (s, want) in script.iter().enumerate() {
            assert_eq!(&inj.advance(s as u64), want);
        }
        // Beyond the script: quiet.
        assert!(inj.advance(99).is_empty());
    }

    #[test]
    fn timed_script_strips_fractions_for_the_step_granular_path() {
        let trace = vec![
            vec![],
            vec![
                TimedFault {
                    at_frac: 0.25,
                    event: FaultEvent::RankFailure { rank: 1 },
                },
                TimedFault {
                    at_frac: 0.75,
                    event: FaultEvent::Straggler { rank: 2, slowdown: 2.0 },
                },
            ],
        ];
        let mut inj = FaultInjector::scripted_timed(4, trace.clone());
        assert!(inj.advance(0).is_empty());
        assert_eq!(
            inj.advance(1),
            vec![
                FaultEvent::RankFailure { rank: 1 },
                FaultEvent::Straggler { rank: 2, slowdown: 2.0 },
            ]
        );
        assert!(inj.advance(9).is_empty());
        // The timed view replays fractions verbatim.
        let mut timed = FaultInjector::scripted_timed(4, trace);
        assert!(timed.advance_timed(0).is_empty());
        let evs = timed.advance_timed(1);
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].at_frac, 0.25);
        assert_eq!(evs[1].at_frac, 0.75);
    }

    #[test]
    fn stochastic_timed_stream_matches_the_untimed_stream() {
        // Same seed: advance_timed must deliver exactly the events
        // advance delivers (fractions are hash-derived, not drawn).
        let cfg = stormy(0xAB1E);
        let mut a = FaultInjector::new(8, cfg);
        let mut b = FaultInjector::new(8, cfg);
        let mut saw_fault = false;
        for step in 0..100 {
            let plain = a.advance(step);
            let timed: Vec<FaultEvent> = b
                .advance_timed(step)
                .into_iter()
                .map(|t| t.event)
                .collect();
            saw_fault |= !plain.is_empty();
            // advance_timed canonicalizes order; compare as multisets
            // via the sorted digest.
            let mut plain_keys: Vec<u64> =
                plain.iter().map(event_digest).collect();
            let mut timed_keys: Vec<u64> =
                timed.iter().map(event_digest).collect();
            plain_keys.sort_unstable();
            timed_keys.sort_unstable();
            assert_eq!(plain_keys, timed_keys, "step {step} event sets differ");
            timed.clear();
        }
        assert!(saw_fault, "stormy config must emit something in 100 steps");
        // And the fraction assignment is a pure function: replay equal.
        let mut c = FaultInjector::new(8, cfg);
        let mut d = FaultInjector::new(8, cfg);
        for step in 0..100 {
            assert_eq!(c.advance_timed(step), d.advance_timed(step));
        }
    }

    #[test]
    fn equal_time_arrivals_canonicalize_regardless_of_script_order() {
        let a = TimedFault {
            at_frac: 0.5,
            event: FaultEvent::RankFailure { rank: 1 },
        };
        let b = TimedFault {
            at_frac: 0.5,
            event: FaultEvent::Preemption { ranks: vec![3], duration_steps: 2 },
        };
        let mut fwd =
            FaultInjector::scripted_timed(8, vec![vec![a.clone(), b.clone()]]);
        let mut rev = FaultInjector::scripted_timed(8, vec![vec![b, a]]);
        assert_eq!(
            fwd.advance_timed(0),
            rev.advance_timed(0),
            "equal-time events must sort canonically"
        );
    }

    #[test]
    fn arrival_frac_is_pure_and_in_range() {
        let ev = FaultEvent::RankFailure { rank: 3 };
        let f = arrival_frac(7, 0, &ev);
        assert_eq!(f, arrival_frac(7, 0, &ev), "pure function of inputs");
        assert!((0.0..1.0).contains(&f));
        // Different step/index/event → (overwhelmingly) different spot.
        assert_ne!(f, arrival_frac(8, 0, &ev));
        assert_ne!(f, arrival_frac(7, 1, &ev));
        assert_ne!(f, arrival_frac(7, 0, &FaultEvent::RankFailure { rank: 4 }));
    }

    #[test]
    fn digest_distinguishes_events() {
        use std::collections::hash_map::DefaultHasher;
        let h = |ev: &FaultEvent| {
            let mut h = DefaultHasher::new();
            ev.digest_into(&mut h);
            h.finish()
        };
        let a = FaultEvent::RankFailure { rank: 1 };
        let b = FaultEvent::Straggler { rank: 1, slowdown: 2.0 };
        let c = FaultEvent::Straggler { rank: 1, slowdown: 2.5 };
        assert_ne!(h(&a), h(&b));
        assert_ne!(h(&b), h(&c));
    }
}
