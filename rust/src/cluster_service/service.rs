//! The multi-tenant cluster service: N concurrent training jobs on one
//! shared mesh, on one deterministic virtual clock.
//!
//! [`ClusterService`] replays a [`JobTrace`] — arrivals, elastic
//! resizes, departures — through per-job [`DhpSession`]s that all view
//! the same physical cluster. The [`ClusterAllocator`] is the single
//! arbiter of who holds which ranks; its decisions reach each session
//! as [`crate::session::MeshEvent`]s through the [`MeshEventSource`] subscription
//! trait, applied between that job's steps (guarded by the session's
//! non-consuming [`DhpSession::is_idle`] check).
//!
//! Clock discipline (the PR-8 event-kernel rule, lifted to job
//! granularity): each virtual tick processes arrivals, then resizes,
//! then queued admissions, then steps every running job — every stage
//! in stable `(time, job_id)` order. Two runs of the same trace are
//! bit-identical ([`ClusterReport::digest`] is a fold of every step
//! report's digest in that global order), and a trace permuted among
//! equal-time arrivals resolves identically.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::baselines::{static_degree_candidates, MegatronStaticCp};
use crate::config::presets::ModelPreset;
use crate::config::{ClusterConfig, TrainStage};
use crate::data::datasets::DatasetSampler;
use crate::experiments::ExpContext;
use crate::session::DhpSession;

use super::allocator::{AllocPolicy, ClusterAllocator, MeshEventSource};
use super::report::{ClusterReport, ClusterSample, JobOutcome};
use super::trace::{JobSpec, JobTrace};

/// Which scheduling policy every job's session runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServiceScheduler {
    /// DHP: each session re-solves degrees per wave and absorbs elastic
    /// resizes mid-run.
    Dhp,
    /// Megatron-style static CP, sized once at admission (largest
    /// power-of-two degree dividing the grant). Static jobs cannot
    /// resize — the service skips their resize requests — which is
    /// precisely the rigidity DHP removes.
    StaticCp,
}

impl ServiceScheduler {
    /// Display name ("DHP" / "static-CP").
    pub fn name(&self) -> &'static str {
        match self {
            ServiceScheduler::Dhp => "DHP",
            ServiceScheduler::StaticCp => "static-CP",
        }
    }
}

/// Service configuration: the shared cluster, the model every job
/// trains, and the allocation/scheduling policies under comparison.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Model preset every job trains (one model zoo per cluster keeps
    /// the comparison about *scheduling*, not model mix).
    pub preset: ModelPreset,
    /// Training stage for every job.
    pub stage: TrainStage,
    /// The shared physical cluster (TP/PP grid included).
    pub cluster: ClusterConfig,
    /// Rank-placement policy for admissions and grows.
    pub alloc_policy: AllocPolicy,
    /// Per-job session scheduler.
    pub scheduler: ServiceScheduler,
    /// Virtual-clock safety cap: the service stops after this many
    /// ticks even if jobs remain (they are reported as incomplete).
    pub max_ticks: u64,
}

impl ServiceConfig {
    /// A small default service: 2-node cluster (4 replicas at TP=2 ×
    /// PP=2), InternVL3-2B, best-fit + DHP.
    pub fn small() -> Self {
        let mut cluster = ClusterConfig::default().with_npus(16);
        cluster.tp = 2;
        cluster.pp = 2;
        ServiceConfig {
            preset: crate::config::presets::by_name("InternVL3-2B")
                .expect("preset"),
            stage: TrainStage::Full,
            cluster,
            alloc_policy: AllocPolicy::BestFit,
            scheduler: ServiceScheduler::Dhp,
            max_ticks: 512,
        }
    }
}

/// One admitted job mid-flight.
struct RunningJob {
    spec: JobSpec,
    session: DhpSession,
    sampler: DatasetSampler,
    admitted_step: u64,
    useful_steps: u64,
    failed_steps: u64,
    sim_time_s: f64,
    digest: u64,
    resize_idx: usize,
}

/// The service itself. Construct with [`ClusterService::new`], then
/// either [`ClusterService::run`] a whole trace or drive
/// [`ClusterService::tick`] manually.
pub struct ClusterService {
    cfg: ServiceConfig,
    allocator: ClusterAllocator,
    /// External async event feed (channel-backed); merged after the
    /// allocator's own decisions at each job's apply point.
    external: Option<Box<dyn MeshEventSource>>,
    arrivals: Vec<JobSpec>,
    next_arrival: usize,
    queue: Vec<JobSpec>,
    running: BTreeMap<u64, RunningJob>,
    outcomes: Vec<JobOutcome>,
    samples: Vec<ClusterSample>,
    tick: u64,
    digest: u64,
}

impl ClusterService {
    /// Service over `trace` (canonicalized on ingest, so equal-time
    /// arrival order in the input never matters).
    pub fn new(cfg: ServiceConfig, mut trace: JobTrace) -> Self {
        trace.canonicalize();
        let allocator = ClusterAllocator::new(&cfg.cluster, cfg.alloc_policy);
        ClusterService {
            cfg,
            allocator,
            external: None,
            arrivals: trace.jobs,
            next_arrival: 0,
            queue: Vec::new(),
            running: BTreeMap::new(),
            outcomes: Vec::new(),
            samples: Vec::new(),
            tick: 0,
            digest: 0,
        }
    }

    /// Attach an external [`MeshEventSource`] (e.g. the channel feed
    /// from [`super::allocator::channel_source`]): its events are
    /// delivered to each job's session after the allocator's own, at
    /// the same idle-guarded apply point.
    pub fn with_external_source(
        mut self,
        source: Box<dyn MeshEventSource>,
    ) -> Self {
        self.external = Some(source);
        self
    }

    /// Replay the whole trace to completion (or the tick cap) and
    /// produce the report.
    pub fn run(mut self) -> Result<ClusterReport> {
        while !self.done() {
            self.tick_once()
                .with_context(|| format!("cluster service tick {}", self.tick))?;
        }
        Ok(self.finish())
    }

    /// All work drained, or the safety cap reached.
    pub fn done(&self) -> bool {
        self.tick >= self.cfg.max_ticks
            || (self.next_arrival >= self.arrivals.len()
                && self.queue.is_empty()
                && self.running.is_empty())
    }

    /// Advance the virtual clock by one tick: arrivals → resizes →
    /// admissions → one step per running job (job-id order) → metrics.
    pub fn tick_once(&mut self) -> Result<()> {
        let t = self.tick;

        // 1. Arrivals join the admission queue in canonical order.
        while self.next_arrival < self.arrivals.len()
            && self.arrivals[self.next_arrival].arrival_step <= t
        {
            self.queue.push(self.arrivals[self.next_arrival].clone());
            self.next_arrival += 1;
        }

        // 2. Elastic resizes for running DHP jobs (static sessions are
        // sized for life — their requests are skipped by design).
        if self.cfg.scheduler == ServiceScheduler::Dhp {
            let ids: Vec<u64> = self.running.keys().copied().collect();
            for id in ids {
                let job = self.running.get_mut(&id).expect("running job");
                while job.resize_idx < job.spec.resizes.len()
                    && job.spec.resizes[job.resize_idx].at_step
                        <= job.useful_steps
                {
                    let delta = job.spec.resizes[job.resize_idx].delta;
                    job.resize_idx += 1;
                    if delta > 0 {
                        self.allocator.grow(id, delta as usize);
                    } else if delta < 0 {
                        self.allocator.shrink(id, (-delta) as usize);
                    }
                }
            }
        }

        // 3. Admissions: first-come-first-served with backfill — scan
        // the queue in (arrival, job_id) order, admit whatever fits.
        let mut still_queued = Vec::new();
        for spec in std::mem::take(&mut self.queue) {
            match self.allocator.admit(spec.job_id, spec.replicas) {
                Some(granted) => {
                    let job = self.build_job(spec, &granted, t)?;
                    self.running.insert(job.spec.job_id, job);
                }
                None => still_queued.push(spec),
            }
        }
        self.queue = still_queued;

        // 4. One step per running job, in job-id order.
        let ids: Vec<u64> = self.running.keys().copied().collect();
        for id in ids {
            self.step_job(id)?;
        }

        // 5. Cluster telemetry for this tick.
        self.samples.push(ClusterSample {
            tick: t,
            utilization: self.allocator.utilization(),
            fragmentation: self.allocator.fragmentation(),
            running: self.running.len(),
            queued: self.queue.len(),
        });
        self.tick += 1;
        Ok(())
    }

    /// Finalize: unfinished and never-admitted jobs get incomplete
    /// outcomes, and the report is assembled in job-id order.
    pub fn finish(mut self) -> ClusterReport {
        let ids: Vec<u64> = self.running.keys().copied().collect();
        for id in ids {
            let job = self.running.remove(&id).expect("running job");
            self.outcomes.push(Self::outcome_of(&job, None));
            self.allocator.depart(id);
        }
        for spec in std::mem::take(&mut self.queue) {
            self.outcomes.push(JobOutcome {
                job_id: spec.job_id,
                requested: spec.replicas,
                arrival_step: spec.arrival_step,
                admitted_step: None,
                completed_step: None,
                queue_wait_steps: self.tick.saturating_sub(spec.arrival_step),
                useful_steps: 0,
                failed_steps: 0,
                sim_time_s: 0.0,
                goodput_steps_per_s: 0.0,
                digest: 0,
            });
        }
        self.outcomes.sort_by_key(|o| o.job_id);
        ClusterReport {
            alloc_policy: self.cfg.alloc_policy.name().to_string(),
            scheduler: self.cfg.scheduler.name().to_string(),
            replicas: self.cfg.cluster.replicas(),
            ticks: self.tick,
            jobs: std::mem::take(&mut self.outcomes),
            samples: std::mem::take(&mut self.samples),
            digest: self.digest,
        }
    }

    /// Per-job experiment context: the service's cluster and model,
    /// the job's workload, batch size, and sampler seed.
    fn job_context(&self, spec: &JobSpec) -> ExpContext {
        let mut ctx = ExpContext::new(
            self.cfg.preset.clone(),
            spec.dataset,
            self.cfg.cluster.total_npus(),
            self.cfg.stage,
        );
        ctx.cluster = self.cfg.cluster.clone();
        ctx.gbs = spec.gbs;
        ctx.seed = spec.seed;
        ctx
    }

    /// Build the session for a freshly admitted job. The session views
    /// the FULL cluster; the allocator has already queued the
    /// `Occupy(complement)` event that renders its co-tenant view, and
    /// [`ClusterService::step_job`] applies it before the first step.
    fn build_job(
        &mut self,
        spec: JobSpec,
        granted: &[usize],
        now: u64,
    ) -> Result<RunningJob> {
        let ctx = self.job_context(&spec);
        let session = match self.cfg.scheduler {
            ServiceScheduler::Dhp => ctx.session(),
            ServiceScheduler::StaticCp => {
                // Sized for the admission grant: the largest power-of-two
                // degree dividing it (Megatron cannot re-shard later).
                let k = granted.len();
                let degree =
                    *static_degree_candidates(k).last().expect("degree");
                let policy = MegatronStaticCp::new(
                    degree,
                    k,
                    ctx.cost_model(),
                    ctx.cluster.inter_bw,
                )
                .with_mesh(ctx.mesh());
                ctx.session_for(Box::new(policy))
            }
        };
        let sampler = ctx.sampler();
        Ok(RunningJob {
            spec,
            session,
            sampler,
            admitted_step: now,
            useful_steps: 0,
            failed_steps: 0,
            sim_time_s: 0.0,
            digest: 0,
            resize_idx: 0,
        })
    }

    /// Deliver pending occupancy events, run one step, account it, and
    /// retire the job if its budget is met.
    fn step_job(&mut self, id: u64) -> Result<()> {
        let mut events = self.allocator.poll(id);
        if let Some(ext) = self.external.as_mut() {
            events.extend(ext.poll(id));
        }
        let job = self.running.get_mut(&id).expect("running job");
        if !events.is_empty() {
            anyhow::ensure!(
                job.session.is_idle(),
                "job {id}: occupancy events with {} step(s) in flight",
                job.session.pending_steps()
            );
            job.session
                .apply(&events)
                .with_context(|| format!("job {id}: applying {events:?}"))?;
        }
        let batch = job.sampler.sample_batch(job.spec.gbs);
        let report = job.session.step(&batch);
        job.sim_time_s += report.iteration.iter_time_s;
        if report.failed.is_none() {
            job.useful_steps += 1;
        } else {
            job.failed_steps += 1;
        }
        let d = report.digest();
        job.digest = job.digest.rotate_left(1) ^ d;
        self.digest = self.digest.rotate_left(1) ^ d;
        if job.useful_steps >= job.spec.steps {
            let job = self.running.remove(&id).expect("running job");
            self.outcomes
                .push(Self::outcome_of(&job, Some(self.tick)));
            self.allocator.depart(id);
        }
        Ok(())
    }

    fn outcome_of(job: &RunningJob, completed: Option<u64>) -> JobOutcome {
        JobOutcome {
            job_id: job.spec.job_id,
            requested: job.spec.replicas,
            arrival_step: job.spec.arrival_step,
            admitted_step: Some(job.admitted_step),
            completed_step: completed,
            queue_wait_steps: job
                .admitted_step
                .saturating_sub(job.spec.arrival_step),
            useful_steps: job.useful_steps,
            failed_steps: job.failed_steps,
            sim_time_s: job.sim_time_s,
            goodput_steps_per_s: if job.sim_time_s > 0.0 {
                job.useful_steps as f64 / job.sim_time_s
            } else {
                0.0
            },
            digest: job.digest,
        }
    }
}

/// One-shot convenience: replay `trace` under `cfg` and return the
/// report.
pub fn run_service(cfg: ServiceConfig, trace: JobTrace) -> Result<ClusterReport> {
    ClusterService::new(cfg, trace).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster_service::allocator::channel_source;
    use crate::cluster_service::trace::{ResizeEvent, TraceConfig};
    use crate::data::datasets::DatasetKind;
    use crate::session::MeshEvent;

    fn spec(job_id: u64, arrival: u64, replicas: usize, steps: u64) -> JobSpec {
        JobSpec {
            job_id,
            arrival_step: arrival,
            replicas,
            steps,
            dataset: DatasetKind::OpenVid,
            gbs: 8,
            seed: 0xD4B ^ job_id,
            resizes: Vec::new(),
        }
    }

    fn small_trace() -> JobTrace {
        JobTrace {
            jobs: vec![spec(0, 0, 1, 2), spec(1, 0, 2, 2), spec(2, 1, 1, 2)],
        }
    }

    #[test]
    fn three_sessions_share_one_mesh_and_complete() {
        // The satellite-1 regression: N sessions interleaved on one
        // shared mesh, each stepping through its own co-tenant view.
        // Any occupancy conflict would panic inside DeviceMesh::occupy.
        let report =
            run_service(ServiceConfig::small(), small_trace()).unwrap();
        assert_eq!(report.jobs.len(), 3);
        for j in &report.jobs {
            assert!(j.completed_step.is_some(), "job {} incomplete", j.job_id);
            assert_eq!(j.useful_steps, 2);
            assert_eq!(j.failed_steps, 0);
            assert!(j.goodput_steps_per_s > 0.0);
        }
        assert!(report.mean_utilization() > 0.0);
    }

    #[test]
    fn same_trace_same_digest_and_render() {
        let a = run_service(ServiceConfig::small(), small_trace()).unwrap();
        let b = run_service(ServiceConfig::small(), small_trace()).unwrap();
        assert_eq!(a.digest, b.digest);
        assert_eq!(a.render(), b.render());
    }

    #[test]
    fn synthetic_trace_replays_deterministically() {
        let trace = JobTrace::synthetic(&TraceConfig {
            jobs: 5,
            max_replicas: 3,
            mean_steps: 3,
            ..TraceConfig::default()
        });
        let a = run_service(ServiceConfig::small(), trace.clone()).unwrap();
        let b = run_service(ServiceConfig::small(), trace).unwrap();
        assert_eq!(a.digest, b.digest);
        assert_eq!(a.render(), b.render());
    }

    #[test]
    fn permuted_equal_time_arrivals_resolve_identically() {
        let trace = small_trace();
        let mut permuted = trace.clone();
        permuted.jobs.reverse();
        let a = run_service(ServiceConfig::small(), trace).unwrap();
        let b = run_service(ServiceConfig::small(), permuted).unwrap();
        assert_eq!(a.digest, b.digest);
        assert_eq!(a.render(), b.render());
    }

    #[test]
    fn oversized_job_queues_until_departure() {
        // 4-replica cluster: job 0 takes 3 ranks for 2 steps; job 1
        // (3 ranks) must queue until job 0 departs.
        let trace = JobTrace {
            jobs: vec![spec(0, 0, 3, 2), spec(1, 0, 3, 3)],
        };
        let report = run_service(ServiceConfig::small(), trace).unwrap();
        let j1 = &report.jobs[1];
        assert!(j1.queue_wait_steps >= 2, "wait {}", j1.queue_wait_steps);
        assert!(j1.completed_step.is_some());
    }

    #[test]
    fn static_sessions_run_and_skip_resizes() {
        let mut cfg = ServiceConfig::small();
        cfg.scheduler = ServiceScheduler::StaticCp;
        let mut trace = small_trace();
        trace.jobs[1].resizes = vec![ResizeEvent { at_step: 1, delta: -1 }];
        let report = run_service(cfg, trace).unwrap();
        for j in &report.jobs {
            assert!(j.completed_step.is_some(), "job {} incomplete", j.job_id);
            assert_eq!(j.failed_steps, 0);
        }
    }

    #[test]
    fn dhp_absorbs_shrink_and_grow_mid_run() {
        let mut trace = JobTrace {
            jobs: vec![spec(0, 0, 2, 4)],
        };
        trace.jobs[0].resizes = vec![
            ResizeEvent { at_step: 1, delta: -1 },
            ResizeEvent { at_step: 2, delta: 1 },
        ];
        let report = run_service(ServiceConfig::small(), trace).unwrap();
        let j = &report.jobs[0];
        assert_eq!(j.useful_steps, 4);
        assert_eq!(j.failed_steps, 0);
    }

    #[test]
    fn external_channel_events_reach_sessions() {
        // An async external caller lends the job rank 3 and immediately
        // takes it back: from the session's view (everything outside its
        // grant is occupied at admission) that is Release then Occupy.
        // Both arrive in the same apply() as the admission complement;
        // the run must stay conflict-free and complete.
        let (feed, src) = channel_source();
        feed.push(0, MeshEvent::Release(vec![3]));
        feed.push(0, MeshEvent::Occupy(vec![3]));
        let trace = JobTrace {
            jobs: vec![spec(0, 0, 2, 2)],
        };
        let report = ClusterService::new(ServiceConfig::small(), trace)
            .with_external_source(Box::new(src))
            .run()
            .unwrap();
        assert_eq!(report.jobs[0].useful_steps, 2);
    }

    #[test]
    fn max_ticks_caps_a_stuck_service() {
        let mut cfg = ServiceConfig::small();
        cfg.max_ticks = 3;
        let trace = JobTrace {
            jobs: vec![spec(0, 0, 1, 100)],
        };
        let report = run_service(cfg, trace).unwrap();
        assert_eq!(report.ticks, 3);
        assert!(report.jobs[0].completed_step.is_none());
        assert_eq!(report.jobs[0].useful_steps, 3);
    }
}
