//! Elastic cluster allocation: one arbiter for the shared mesh.
//!
//! The [`ClusterAllocator`] owns the cluster's master occupancy map (a
//! [`DeviceMesh`] whose occupied set is exactly the union of every
//! job's grant) and converts job admission / growth / shrink /
//! departure into the per-job [`MeshEvent`] feeds each
//! [`crate::session::DhpSession`] already consumes. Each job's session
//! is built over the *full* cluster topology; the allocator renders the
//! job's view by occupying the complement of its grant, so disjoint
//! grants across jobs can never conflict — `DeviceMesh::occupy` panics
//! on double-claims, and the allocator is the single caller allowed to
//! decide who holds what.
//!
//! Decisions reach sessions through the [`MeshEventSource`] trait (the
//! async event-subscription source the session façade's `apply()` was
//! built for): the allocator implements it over its internal per-job
//! queues, and [`channel_source`] provides a channel-backed
//! implementation so external callers can push events asynchronously.

use std::collections::BTreeMap;
use std::sync::mpsc;

use crate::config::ClusterConfig;
use crate::parallel::mesh::DeviceMesh;
use crate::parallel::RankId;
use crate::session::MeshEvent;

/// Allocation policy for picking which free ranks a job receives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocPolicy {
    /// Lowest-index free ranks, regardless of topology.
    FirstFit,
    /// Locality-aware best-fit: the tightest single-node fit first (an
    /// exact or near-exact node fills up, whole nodes stay free, and the
    /// grant rides the intra-node fabric whenever any one node can hold
    /// it); when no single node suffices, consume the largest free
    /// blocks. All ties break toward the lowest node index.
    BestFit,
}

impl AllocPolicy {
    /// Display name ("first-fit" / "best-fit").
    pub fn name(&self) -> &'static str {
        match self {
            AllocPolicy::FirstFit => "first-fit",
            AllocPolicy::BestFit => "best-fit",
        }
    }
}

/// An asynchronous feed of occupancy events for one job's session —
/// the PR-5 follow-on subscription source. Implementations must be
/// deterministic given the same call sequence: `poll` returns every
/// event destined for `job_id` that has been produced since the last
/// poll, in production order.
pub trait MeshEventSource {
    /// Drain the pending events for `job_id`.
    fn poll(&mut self, job_id: u64) -> Vec<MeshEvent>;
}

/// The shared-cluster arbiter. See the module docs for the ownership
/// model.
#[derive(Debug, Clone)]
pub struct ClusterAllocator {
    mesh: DeviceMesh,
    policy: AllocPolicy,
    owners: Vec<Option<u64>>,
    queues: BTreeMap<u64, Vec<MeshEvent>>,
}

impl ClusterAllocator {
    /// Allocator over `cluster`'s replica topology with the given
    /// placement policy. All ranks start free.
    pub fn new(cluster: &ClusterConfig, policy: AllocPolicy) -> Self {
        let mesh = DeviceMesh::new(cluster);
        let replicas = mesh.replicas;
        ClusterAllocator {
            mesh,
            policy,
            owners: vec![None; replicas],
            queues: BTreeMap::new(),
        }
    }

    /// The master occupancy map (occupied = granted to some job).
    pub fn mesh(&self) -> &DeviceMesh {
        &self.mesh
    }

    /// The active placement policy.
    pub fn policy(&self) -> AllocPolicy {
        self.policy
    }

    /// Ranks currently granted to `job_id`, ascending.
    pub fn owned(&self, job_id: u64) -> Vec<RankId> {
        self.owners
            .iter()
            .enumerate()
            .filter(|(_, o)| **o == Some(job_id))
            .map(|(r, _)| r)
            .collect()
    }

    /// Free replica slots cluster-wide.
    pub fn free_replicas(&self) -> usize {
        self.mesh.free_replicas()
    }

    /// Fraction of the cluster currently granted to jobs.
    pub fn utilization(&self) -> f64 {
        self.mesh.occupied_replicas() as f64 / self.mesh.replicas.max(1) as f64
    }

    /// External fragmentation: the fraction of *free* ranks stranded on
    /// partially-occupied nodes (a whole-node-hungry job cannot use
    /// them without crossing the slow fabric). 0.0 when every free rank
    /// sits on a fully-free node — and when nothing is free.
    pub fn fragmentation(&self) -> f64 {
        let rpn = self.mesh.replicas_per_node;
        let free_per_node = self.mesh.free_per_node();
        let free: usize = free_per_node.iter().sum();
        if free == 0 {
            return 0.0;
        }
        let stranded: usize = free_per_node
            .iter()
            .enumerate()
            .map(|(node, &f)| {
                let node_size =
                    rpn.min(self.mesh.replicas - (node * rpn).min(self.mesh.replicas));
                if f == node_size {
                    0
                } else {
                    f
                }
            })
            .sum();
        stranded as f64 / free as f64
    }

    /// Try to admit `job_id` at `want` replicas. On success the grant is
    /// recorded, the job's event feed receives the `Occupy(complement)`
    /// event that renders its session's view of the shared mesh, and the
    /// granted ranks are returned. `None` when the cluster cannot hold
    /// the job right now (caller queues it).
    pub fn admit(&mut self, job_id: u64, want: usize) -> Option<Vec<RankId>> {
        assert!(want >= 1, "admit: job {job_id} wants 0 replicas");
        assert!(
            self.owned(job_id).is_empty(),
            "admit: job {job_id} is already admitted"
        );
        let ranks = self.select(want)?;
        self.grant(job_id, &ranks);
        let complement: Vec<RankId> = (0..self.mesh.replicas)
            .filter(|r| !ranks.contains(r))
            .collect();
        if !complement.is_empty() {
            self.queues
                .entry(job_id)
                .or_default()
                .push(MeshEvent::Occupy(complement));
        }
        Some(ranks)
    }

    /// Grow `job_id` by up to `extra` replicas; returns the ranks
    /// actually granted (possibly empty — partial grows are refused so
    /// the decision stays all-or-nothing and deterministic). The job's
    /// feed receives `Release(granted)`: from its session's point of
    /// view those co-tenant ranks just freed up.
    pub fn grow(&mut self, job_id: u64, extra: usize) -> Vec<RankId> {
        assert!(
            !self.owned(job_id).is_empty(),
            "grow: job {job_id} is not admitted"
        );
        let Some(ranks) = self.select(extra) else {
            return Vec::new();
        };
        self.grant(job_id, &ranks);
        self.queues
            .entry(job_id)
            .or_default()
            .push(MeshEvent::Release(ranks.clone()));
        ranks
    }

    /// Shrink `job_id` by up to `count` replicas (always keeping one),
    /// returning the ranks taken back. Highest-index owned ranks go
    /// first — deterministic, and it unwinds first-fit growth. The job's
    /// feed receives `Occupy(taken)`.
    pub fn shrink(&mut self, job_id: u64, count: usize) -> Vec<RankId> {
        let owned = self.owned(job_id);
        assert!(!owned.is_empty(), "shrink: job {job_id} is not admitted");
        let give_up = count.min(owned.len().saturating_sub(1));
        if give_up == 0 {
            return Vec::new();
        }
        let taken: Vec<RankId> =
            owned[owned.len() - give_up..].to_vec();
        self.mesh.release(&taken);
        for &r in &taken {
            self.owners[r] = None;
        }
        self.queues
            .entry(job_id)
            .or_default()
            .push(MeshEvent::Occupy(taken.clone()));
        taken
    }

    /// Remove `job_id` entirely: its grant returns to the free pool and
    /// its (now meaningless) event feed is dropped. Returns the freed
    /// ranks.
    pub fn depart(&mut self, job_id: u64) -> Vec<RankId> {
        let owned = self.owned(job_id);
        assert!(!owned.is_empty(), "depart: job {job_id} is not admitted");
        self.mesh.release(&owned);
        for &r in &owned {
            self.owners[r] = None;
        }
        self.queues.remove(&job_id);
        owned
    }

    fn grant(&mut self, job_id: u64, ranks: &[RankId]) {
        self.mesh.occupy(ranks);
        for &r in ranks {
            self.owners[r] = Some(job_id);
        }
    }

    /// Pick `want` free ranks under the policy, or `None` if the
    /// cluster cannot hold them.
    fn select(&self, want: usize) -> Option<Vec<RankId>> {
        if want == 0 || self.mesh.free_replicas() < want {
            return None;
        }
        match self.policy {
            AllocPolicy::FirstFit => Some(
                (0..self.mesh.replicas)
                    .filter(|&r| self.mesh.is_rank_free(r))
                    .take(want)
                    .collect(),
            ),
            AllocPolicy::BestFit => Some(self.select_best_fit(want)),
        }
    }

    /// Greedy best-fit: repeatedly pick the node with the SMALLEST free
    /// count that still covers the remaining need (tightest fit); when
    /// no single node covers it, the node with the LARGEST free count
    /// (fewest fabric crossings). Ties break toward the lowest node
    /// index; within a node, lowest-index free ranks. Total free ≥ want
    /// is guaranteed by the caller, so this always terminates with a
    /// full grant.
    fn select_best_fit(&self, want: usize) -> Vec<RankId> {
        let rpn = self.mesh.replicas_per_node;
        let mut free_per_node = self.mesh.free_per_node();
        let mut picked = Vec::with_capacity(want);
        let mut remaining = want;
        while remaining > 0 {
            let tightest = free_per_node
                .iter()
                .enumerate()
                .filter(|(_, &f)| f >= remaining)
                .min_by_key(|(node, &f)| (f, *node))
                .map(|(node, _)| node);
            let node = tightest.unwrap_or_else(|| {
                free_per_node
                    .iter()
                    .enumerate()
                    .max_by_key(|(node, &f)| (f, usize::MAX - *node))
                    .map(|(node, _)| node)
                    .expect("best-fit: no nodes")
            });
            let take = free_per_node[node].min(remaining);
            let start = node * rpn;
            let end = ((node + 1) * rpn).min(self.mesh.replicas);
            let mut got = 0;
            for r in start..end {
                if got == take {
                    break;
                }
                if self.mesh.is_rank_free(r) && !picked.contains(&r) {
                    picked.push(r);
                    got += 1;
                }
            }
            debug_assert_eq!(got, take, "best-fit node census out of sync");
            free_per_node[node] -= take;
            remaining -= take;
        }
        picked.sort_unstable();
        picked
    }
}

impl MeshEventSource for ClusterAllocator {
    fn poll(&mut self, job_id: u64) -> Vec<MeshEvent> {
        self.queues.remove(&job_id).unwrap_or_default()
    }
}

/// The sending half of a [`channel_source`] feed: external callers
/// (another thread, an RPC handler) push `(job_id, event)` pairs
/// through it asynchronously.
#[derive(Debug, Clone)]
pub struct ChannelEventFeed {
    tx: mpsc::Sender<(u64, MeshEvent)>,
}

impl ChannelEventFeed {
    /// Queue `event` for `job_id`'s next poll. Fails silently if the
    /// receiving source was dropped (the service shut down).
    pub fn push(&self, job_id: u64, event: MeshEvent) {
        let _ = self.tx.send((job_id, event));
    }
}

/// The polling half of a [`channel_source`] feed. Events for jobs other
/// than the polled one are buffered (in arrival order) until that job
/// polls.
#[derive(Debug)]
pub struct ChannelEventSource {
    rx: mpsc::Receiver<(u64, MeshEvent)>,
    buffered: BTreeMap<u64, Vec<MeshEvent>>,
}

/// A channel-backed [`MeshEventSource`]: the feed half is cloneable and
/// `Send`, so asynchronous external callers can push occupancy events
/// into a running service.
pub fn channel_source() -> (ChannelEventFeed, ChannelEventSource) {
    let (tx, rx) = mpsc::channel();
    (
        ChannelEventFeed { tx },
        ChannelEventSource {
            rx,
            buffered: BTreeMap::new(),
        },
    )
}

impl MeshEventSource for ChannelEventSource {
    fn poll(&mut self, job_id: u64) -> Vec<MeshEvent> {
        while let Ok((id, ev)) = self.rx.try_recv() {
            self.buffered.entry(id).or_default().push(ev);
        }
        self.buffered.remove(&job_id).unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster(nodes: usize) -> ClusterConfig {
        // 8 NPUs/node at TP=2 × PP=2 ⇒ 2 replicas per node.
        let mut c = ClusterConfig::default().with_npus(nodes * 8);
        c.tp = 2;
        c.pp = 2;
        c
    }

    #[test]
    fn first_fit_takes_lowest_ranks() {
        let mut a = ClusterAllocator::new(&cluster(4), AllocPolicy::FirstFit);
        assert_eq!(a.admit(0, 1), Some(vec![0]));
        assert_eq!(a.admit(1, 2), Some(vec![1, 2]));
        assert_eq!(a.owned(1), vec![1, 2]);
        assert!(!a.mesh().is_intra_node(&[1, 2]));
    }

    #[test]
    fn best_fit_prefers_whole_nodes() {
        let mut a = ClusterAllocator::new(&cluster(4), AllocPolicy::BestFit);
        assert_eq!(a.admit(0, 1), Some(vec![0]));
        // A 2-replica job gets the tightest whole node, not the
        // fragment on node 0 plus a crossing.
        let got = a.admit(1, 2).unwrap();
        assert_eq!(got, vec![2, 3]);
        assert!(a.mesh().is_intra_node(&got));
    }

    #[test]
    fn best_fit_spills_over_largest_blocks() {
        let mut a = ClusterAllocator::new(&cluster(2), AllocPolicy::BestFit);
        assert_eq!(a.admit(0, 1), Some(vec![0]));
        // want=3 > any single node: take the whole free node 1 first,
        // then the fragment.
        assert_eq!(a.admit(1, 3), Some(vec![1, 2, 3]));
        assert_eq!(a.free_replicas(), 0);
    }

    #[test]
    fn admission_feeds_complement_and_lifecycle_events() {
        let mut a = ClusterAllocator::new(&cluster(2), AllocPolicy::FirstFit);
        a.admit(7, 2).unwrap();
        assert_eq!(a.poll(7), vec![MeshEvent::Occupy(vec![2, 3])]);
        assert!(a.poll(7).is_empty(), "poll drains");
        let grown = a.grow(7, 1);
        assert_eq!(grown, vec![2]);
        assert_eq!(a.poll(7), vec![MeshEvent::Release(vec![2])]);
        let taken = a.shrink(7, 2);
        assert_eq!(taken, vec![2, 3]);
        assert_eq!(a.poll(7), vec![MeshEvent::Occupy(vec![2, 3])]);
        // Shrink never takes the last replica.
        assert!(a.shrink(7, 5).is_empty());
        assert_eq!(a.depart(7), vec![0]);
        assert_eq!(a.free_replicas(), 4);
    }

    #[test]
    fn refuses_when_full_and_recovers_on_departure() {
        let mut a = ClusterAllocator::new(&cluster(1), AllocPolicy::BestFit);
        a.admit(0, 2).unwrap();
        assert_eq!(a.admit(1, 1), None);
        assert!((a.utilization() - 1.0).abs() < 1e-12);
        a.depart(0);
        assert_eq!(a.admit(1, 1), Some(vec![0]));
    }

    #[test]
    fn fragmentation_counts_stranded_free_ranks() {
        let mut a = ClusterAllocator::new(&cluster(2), AllocPolicy::FirstFit);
        assert_eq!(a.fragmentation(), 0.0);
        a.admit(0, 1).unwrap(); // node 0 now half-occupied
        // Free ranks: 1 (stranded on node 0), 2, 3 (whole node 1).
        assert!((a.fragmentation() - 1.0 / 3.0).abs() < 1e-12);
        a.admit(1, 3).unwrap();
        assert_eq!(a.fragmentation(), 0.0, "nothing free, nothing stranded");
    }

    #[test]
    fn channel_source_buffers_per_job() {
        let (feed, mut src) = channel_source();
        feed.push(1, MeshEvent::Occupy(vec![0]));
        feed.push(2, MeshEvent::Occupy(vec![1]));
        feed.push(1, MeshEvent::Release(vec![0]));
        assert_eq!(
            src.poll(1),
            vec![MeshEvent::Occupy(vec![0]), MeshEvent::Release(vec![0])]
        );
        assert_eq!(src.poll(2), vec![MeshEvent::Occupy(vec![1])]);
        assert!(src.poll(1).is_empty());
    }
}
