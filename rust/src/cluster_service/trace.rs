//! Job-arrival traces for the multi-tenant cluster service.
//!
//! A [`JobTrace`] is the service's entire input: which training jobs
//! arrive at which virtual step, how many replicas each requests, how
//! long it runs, and any mid-life resize requests. Traces come from two
//! sources — a seeded synthetic generator (Poisson arrivals with
//! heavy-tailed sizes and durations, the Azure-Functions-style shape
//! the dslab FaaS experiments replay) so no external dataset download
//! is ever required, and a small CSV format for hand-written or
//! externally produced traces.
//!
//! Determinism contract: [`JobTrace::synthetic`] is a pure function of
//! its [`TraceConfig`] (same config ⇒ byte-identical
//! [`JobTrace::to_csv`]), and the canonical job order is
//! `(arrival_step, job_id)` — the same tie-break the service's virtual
//! clock uses, so a permuted trace replays identically.

use anyhow::{bail, Context, Result};

use crate::data::datasets::DatasetKind;
use crate::util::rng::Rng;

/// A mid-life elastic resize request: at `at_step` steps *after
/// admission*, the job asks to grow (`delta > 0`) or shrink
/// (`delta < 0`) by `|delta|` replicas.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResizeEvent {
    /// Steps after admission at which the request fires.
    pub at_step: u64,
    /// Signed replica delta (grow when positive, shrink when negative).
    pub delta: i64,
}

/// One training job in the trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobSpec {
    /// Stable identity; also the virtual-clock tie-break key.
    pub job_id: u64,
    /// Virtual step at which the job arrives (joins the admission queue).
    pub arrival_step: u64,
    /// Replicas requested at admission.
    pub replicas: usize,
    /// Useful training steps the job must complete before departing.
    pub steps: u64,
    /// Workload the job's sequences are drawn from.
    pub dataset: DatasetKind,
    /// Global batch size per step.
    pub gbs: usize,
    /// Sampler seed (per-job, so co-tenant batches are independent).
    pub seed: u64,
    /// Elastic resize requests, sorted by `at_step`.
    pub resizes: Vec<ResizeEvent>,
}

/// An ordered collection of job specs — the service's input.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct JobTrace {
    /// Jobs in canonical `(arrival_step, job_id)` order.
    pub jobs: Vec<JobSpec>,
}

/// Knobs for the synthetic generator. Defaults model a busy shared
/// cluster: jobs arrive a little faster than they finish, so the
/// admission queue is exercised.
#[derive(Debug, Clone, Copy)]
pub struct TraceConfig {
    /// Generator seed; the sole source of randomness.
    pub seed: u64,
    /// Number of jobs to generate.
    pub jobs: usize,
    /// Poisson arrival rate in jobs per virtual step (inter-arrival
    /// times are exponential with mean `1/arrival_rate`).
    pub arrival_rate: f64,
    /// Median requested replicas (sizes are lognormal around this).
    pub mean_replicas: usize,
    /// Hard cap on a job's requested replicas (clamp of the heavy tail;
    /// set this at or below the cluster size so every job is admissible).
    pub max_replicas: usize,
    /// Median step budget (durations are lognormal around this).
    pub mean_steps: u64,
    /// Probability a job carries one elastic resize request.
    pub resize_prob: f64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            seed: 0xC1_D4B,
            jobs: 8,
            arrival_rate: 0.25,
            mean_replicas: 2,
            max_replicas: 4,
            mean_steps: 12,
            resize_prob: 0.25,
        }
    }
}

impl JobTrace {
    /// Seeded synthetic trace: exponential inter-arrivals (Poisson
    /// process), lognormal (heavy-tailed) sizes and step budgets, and
    /// occasional resize requests. Pure in `cfg` — the same config
    /// yields a byte-identical [`JobTrace::to_csv`].
    pub fn synthetic(cfg: &TraceConfig) -> JobTrace {
        let mut rng = Rng::new(cfg.seed ^ 0x7261_6365); // "race"
        let rate = cfg.arrival_rate.max(1e-9);
        let mut clock = 0.0f64;
        let mut jobs = Vec::with_capacity(cfg.jobs);
        let datasets = [
            DatasetKind::OpenVid,
            DatasetKind::InternVid,
            DatasetKind::Msrvtt,
        ];
        for job_id in 0..cfg.jobs as u64 {
            // Exponential inter-arrival via inverse CDF; uniform() is in
            // [0, 1), so 1-u is in (0, 1] and the log is finite.
            clock += -(1.0 - rng.uniform()).ln() / rate;
            let arrival_step = clock.floor() as u64;

            let mu_r = (cfg.mean_replicas.max(1) as f64).ln();
            let replicas = (rng.lognormal(mu_r, 0.6).round() as usize)
                .clamp(1, cfg.max_replicas.max(1));

            let mu_s = (cfg.mean_steps.max(1) as f64).ln();
            let steps = (rng.lognormal(mu_s, 0.8).round() as u64).max(1);

            let dataset = *rng.choose(&datasets);
            // Batch scales with the grant so per-replica load stays
            // comparable across sizes.
            let gbs = 8 * replicas;
            let seed = rng.next_u64();

            let mut resizes = Vec::new();
            if rng.bool(cfg.resize_prob) && steps >= 4 {
                let at_step = rng.range_u64(1, steps.saturating_sub(1).max(2));
                // Grow by one when below the cap, else shed one.
                let delta = if replicas < cfg.max_replicas && rng.bool(0.5) {
                    1
                } else if replicas > 1 {
                    -1
                } else {
                    1
                };
                resizes.push(ResizeEvent { at_step, delta });
            }

            jobs.push(JobSpec {
                job_id,
                arrival_step,
                replicas,
                steps,
                dataset,
                gbs,
                seed,
                resizes,
            });
        }
        let mut trace = JobTrace { jobs };
        trace.canonicalize();
        trace
    }

    /// Sort into the canonical `(arrival_step, job_id)` order — the same
    /// tie-break the service's virtual clock uses, so two traces that
    /// differ only in the order of equal-time arrivals are identical
    /// after canonicalization.
    pub fn canonicalize(&mut self) {
        self.jobs
            .sort_by_key(|j| (j.arrival_step, j.job_id));
        for j in &mut self.jobs {
            j.resizes.sort_by_key(|r| r.at_step);
        }
    }

    /// Serialize to the CSV trace format (stable field order; `#`
    /// comment header). Round-trips through [`JobTrace::from_csv`].
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "# dhp cluster trace v1\n# job,<id>,<arrival_step>,<replicas>,<steps>,<dataset>,<gbs>,<seed>\n# resize,<job_id>,<at_step>,<delta>\n",
        );
        for j in &self.jobs {
            out.push_str(&format!(
                "job,{},{},{},{},{},{},{}\n",
                j.job_id,
                j.arrival_step,
                j.replicas,
                j.steps,
                j.dataset.name(),
                j.gbs,
                j.seed
            ));
        }
        for j in &self.jobs {
            for r in &j.resizes {
                out.push_str(&format!(
                    "resize,{},{},{}\n",
                    j.job_id, r.at_step, r.delta
                ));
            }
        }
        out
    }

    /// Parse the CSV trace format: `job,...` and `resize,...` records,
    /// blank lines and `#` comments ignored. The result is
    /// canonicalized, so record order in the file does not matter.
    pub fn from_csv(text: &str) -> Result<JobTrace> {
        let mut jobs: Vec<JobSpec> = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split(',').map(str::trim).collect();
            let ctx = || format!("trace line {}: {raw:?}", lineno + 1);
            match fields[0] {
                "job" => {
                    if fields.len() != 8 {
                        bail!("{}: expected 8 fields, got {}", ctx(), fields.len());
                    }
                    jobs.push(JobSpec {
                        job_id: fields[1].parse().with_context(ctx)?,
                        arrival_step: fields[2].parse().with_context(ctx)?,
                        replicas: fields[3].parse().with_context(ctx)?,
                        steps: fields[4].parse().with_context(ctx)?,
                        dataset: DatasetKind::by_name(fields[5])
                            .with_context(ctx)?,
                        gbs: fields[6].parse().with_context(ctx)?,
                        seed: fields[7].parse().with_context(ctx)?,
                        resizes: Vec::new(),
                    });
                }
                "resize" => {
                    if fields.len() != 4 {
                        bail!("{}: expected 4 fields, got {}", ctx(), fields.len());
                    }
                    let job_id: u64 = fields[1].parse().with_context(ctx)?;
                    let ev = ResizeEvent {
                        at_step: fields[2].parse().with_context(ctx)?,
                        delta: fields[3].parse().with_context(ctx)?,
                    };
                    let job = jobs
                        .iter_mut()
                        .find(|j| j.job_id == job_id)
                        .ok_or_else(|| {
                            anyhow::anyhow!("{}: resize before its job record", ctx())
                        })?;
                    job.resizes.push(ev);
                }
                other => bail!("{}: unknown record kind {other:?}", ctx()),
            }
        }
        let mut trace = JobTrace { jobs };
        trace.validate()?;
        trace.canonicalize();
        Ok(trace)
    }

    /// Structural checks: unique job ids, nonzero sizes and budgets.
    pub fn validate(&self) -> Result<()> {
        let mut seen = std::collections::HashSet::new();
        for j in &self.jobs {
            if !seen.insert(j.job_id) {
                bail!("duplicate job_id {} in trace", j.job_id);
            }
            if j.replicas == 0 {
                bail!("job {} requests 0 replicas", j.job_id);
            }
            if j.steps == 0 {
                bail!("job {} has a 0-step budget", j.job_id);
            }
            if j.gbs == 0 {
                bail!("job {} has gbs 0", j.job_id);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_is_byte_identical() {
        let cfg = TraceConfig::default();
        let a = JobTrace::synthetic(&cfg);
        let b = JobTrace::synthetic(&cfg);
        assert_eq!(a, b);
        assert_eq!(a.to_csv(), b.to_csv());
    }

    #[test]
    fn different_seed_differs() {
        let a = JobTrace::synthetic(&TraceConfig::default());
        let b = JobTrace::synthetic(&TraceConfig {
            seed: 0xBEEF,
            ..TraceConfig::default()
        });
        assert_ne!(a.to_csv(), b.to_csv());
    }

    #[test]
    fn synthetic_respects_caps() {
        let cfg = TraceConfig {
            jobs: 64,
            max_replicas: 3,
            ..TraceConfig::default()
        };
        let t = JobTrace::synthetic(&cfg);
        assert_eq!(t.jobs.len(), 64);
        assert!(t.jobs.iter().all(|j| (1..=3).contains(&j.replicas)));
        assert!(t.jobs.iter().all(|j| j.steps >= 1));
        t.validate().unwrap();
        // Arrivals are non-decreasing in canonical order.
        assert!(t
            .jobs
            .windows(2)
            .all(|w| w[0].arrival_step <= w[1].arrival_step));
    }

    #[test]
    fn csv_round_trips() {
        let t = JobTrace::synthetic(&TraceConfig {
            jobs: 12,
            resize_prob: 0.8,
            ..TraceConfig::default()
        });
        let parsed = JobTrace::from_csv(&t.to_csv()).unwrap();
        assert_eq!(t, parsed);
        assert_eq!(t.to_csv(), parsed.to_csv());
    }

    #[test]
    fn permuted_equal_time_arrivals_canonicalize_identically() {
        let mut t = JobTrace::synthetic(&TraceConfig::default());
        // Force a tie: give the first three jobs the same arrival step.
        for j in t.jobs.iter_mut().take(3) {
            j.arrival_step = 5;
        }
        t.canonicalize();
        let mut permuted = t.clone();
        permuted.jobs.reverse();
        permuted.canonicalize();
        assert_eq!(t, permuted);
        assert_eq!(t.to_csv(), permuted.to_csv());
    }

    #[test]
    fn from_csv_rejects_garbage() {
        assert!(JobTrace::from_csv("job,1,2\n").is_err());
        assert!(JobTrace::from_csv("frob,1,2,3,4,5,6,7\n").is_err());
        assert!(JobTrace::from_csv("resize,9,1,1\n").is_err());
        let dup = "job,1,0,2,4,openvid,16,7\njob,1,0,2,4,openvid,16,7\n";
        assert!(JobTrace::from_csv(dup).is_err());
    }
}
