//! Trace-driven multi-tenant cluster service.
//!
//! Production MLLM training is not one job on one mesh — it is many
//! jobs arriving, growing, shrinking, and finishing against one shared
//! cluster (the MegaScale-Omni operating regime). This layer closes
//! that gap over the single-job [`crate::session::DhpSession`] façade:
//!
//! - [`trace`] — job-arrival traces: a seeded synthetic generator
//!   (Poisson arrivals, heavy-tailed sizes/durations) and a CSV loader.
//! - [`allocator`] — the single arbiter of the shared mesh: admission,
//!   elastic grow/shrink, departure, queueing when full, under
//!   first-fit or locality-aware best-fit placement; decisions become
//!   per-job [`crate::session::MeshEvent`] feeds via the
//!   [`MeshEventSource`] subscription trait (also implemented by a
//!   channel-backed feed for asynchronous external callers).
//! - [`service`] — [`ClusterService`]: N concurrent sessions stepping
//!   round-robin on one deterministic virtual clock with stable
//!   `(time, job_id)` ordering and bit-reproducible digests.
//! - [`report`] — per-job SLO metrics (queue wait, goodput,
//!   completion) and cluster metrics (utilization, fragmentation).
//!
//! Entry points: `dhp reproduce cluster_day` and
//! `cargo bench --bench cluster_day` replay the same seeded trace
//! under every allocator-policy × scheduler combination.

pub mod allocator;
pub mod report;
pub mod service;
pub mod trace;

pub use allocator::{
    channel_source, AllocPolicy, ChannelEventFeed, ChannelEventSource,
    ClusterAllocator, MeshEventSource,
};
pub use report::{ClusterReport, ClusterSample, JobOutcome};
pub use service::{
    run_service, ClusterService, ServiceConfig, ServiceScheduler,
};
pub use trace::{JobSpec, JobTrace, ResizeEvent, TraceConfig};
