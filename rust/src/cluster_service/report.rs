//! Per-job SLO metrics and cluster-level telemetry for a
//! [`crate::cluster_service::ClusterService`] run.
//!
//! The report is pure data plus deterministic rendering: two
//! bit-identical service runs produce byte-identical
//! [`ClusterReport::render`] output (the trace-determinism tests pin
//! exactly that), and [`ClusterReport::to_json`] feeds the
//! `BENCH_cluster_day.json` artifact.

use crate::report::Table;
use crate::util::json::{self, Json};

/// What one job experienced, end to end.
#[derive(Debug, Clone, PartialEq)]
pub struct JobOutcome {
    /// Trace job id.
    pub job_id: u64,
    /// Replicas the job asked for at admission.
    pub requested: usize,
    /// Virtual tick the job arrived at.
    pub arrival_step: u64,
    /// Tick the job was admitted, `None` if it never left the queue.
    pub admitted_step: Option<u64>,
    /// Tick the job finished its step budget, `None` if the run ended
    /// first.
    pub completed_step: Option<u64>,
    /// Ticks spent waiting in the admission queue (the SLO headline).
    pub queue_wait_steps: u64,
    /// Steps that trained successfully.
    pub useful_steps: u64,
    /// Steps refused by the policy (e.g. a static grid under capacity
    /// loss).
    pub failed_steps: u64,
    /// Simulated seconds the job's steps consumed.
    pub sim_time_s: f64,
    /// Useful steps per simulated second — goodput (0 when nothing ran).
    pub goodput_steps_per_s: f64,
    /// Fold of the job's step-report digests (bit-reproducibility
    /// anchor).
    pub digest: u64,
}

/// Cluster-wide telemetry sampled once per virtual tick.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSample {
    /// The tick this sample describes.
    pub tick: u64,
    /// Fraction of replicas granted to jobs.
    pub utilization: f64,
    /// Fraction of free replicas stranded on partially-occupied nodes.
    pub fragmentation: f64,
    /// Jobs running at the end of the tick.
    pub running: usize,
    /// Jobs still queued at the end of the tick.
    pub queued: usize,
}

/// The full outcome of one service run.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterReport {
    /// Allocation policy name ("first-fit" / "best-fit").
    pub alloc_policy: String,
    /// Session scheduler name ("DHP" / "static-CP").
    pub scheduler: String,
    /// Cluster size in replicas.
    pub replicas: usize,
    /// Virtual ticks the run spanned.
    pub ticks: u64,
    /// Per-job outcomes, in job-id order.
    pub jobs: Vec<JobOutcome>,
    /// Per-tick cluster telemetry.
    pub samples: Vec<ClusterSample>,
    /// Fold of every step digest in global `(tick, job_id)` order.
    pub digest: u64,
}

impl ClusterReport {
    /// Mean cluster utilization over the run's ticks.
    pub fn mean_utilization(&self) -> f64 {
        mean(self.samples.iter().map(|s| s.utilization))
    }

    /// Mean fragmentation over the run's ticks.
    pub fn mean_fragmentation(&self) -> f64 {
        mean(self.samples.iter().map(|s| s.fragmentation))
    }

    /// Mean admission-queue wait over all jobs that were admitted.
    pub fn mean_queue_wait_steps(&self) -> f64 {
        mean(
            self.jobs
                .iter()
                .filter(|j| j.admitted_step.is_some())
                .map(|j| j.queue_wait_steps as f64),
        )
    }

    /// Jobs that finished their full step budget.
    pub fn completed_jobs(&self) -> usize {
        self.jobs
            .iter()
            .filter(|j| j.completed_step.is_some())
            .count()
    }

    /// Aggregate goodput: useful steps per simulated second, summed
    /// over jobs (each job's wall-clock is its own session's — jobs run
    /// concurrently, so the sum is the cluster's service rate).
    pub fn total_goodput_steps_per_s(&self) -> f64 {
        self.jobs.iter().map(|j| j.goodput_steps_per_s).sum()
    }

    /// Per-job SLO table.
    pub fn job_table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "Per-job SLO — {} / {} ({} replicas, {} ticks)",
                self.alloc_policy, self.scheduler, self.replicas, self.ticks
            ),
            &[
                "job", "req", "arrive", "admit", "done", "wait", "useful",
                "failed", "sim time (s)", "goodput (steps/s)",
            ],
        );
        for j in &self.jobs {
            let opt = |v: Option<u64>| {
                v.map(|x| x.to_string()).unwrap_or_else(|| "-".into())
            };
            t.row(vec![
                j.job_id.to_string(),
                j.requested.to_string(),
                j.arrival_step.to_string(),
                opt(j.admitted_step),
                opt(j.completed_step),
                j.queue_wait_steps.to_string(),
                j.useful_steps.to_string(),
                j.failed_steps.to_string(),
                format!("{:.3}", j.sim_time_s),
                format!("{:.4}", j.goodput_steps_per_s),
            ]);
        }
        t
    }

    /// Cluster utilization/fragmentation summary table (one row per
    /// tick would swamp long runs, so this reports the run mean plus
    /// the peak-queue tick).
    pub fn cluster_table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "Cluster — {} / {}",
                self.alloc_policy, self.scheduler
            ),
            &["metric", "value"],
        );
        t.row(vec![
            "mean utilization".into(),
            format!("{:.4}", self.mean_utilization()),
        ]);
        t.row(vec![
            "mean fragmentation".into(),
            format!("{:.4}", self.mean_fragmentation()),
        ]);
        t.row(vec![
            "mean queue wait (steps)".into(),
            format!("{:.3}", self.mean_queue_wait_steps()),
        ]);
        t.row(vec![
            "completed jobs".into(),
            format!("{}/{}", self.completed_jobs(), self.jobs.len()),
        ]);
        t.row(vec![
            "total goodput (steps/s)".into(),
            format!("{:.4}", self.total_goodput_steps_per_s()),
        ]);
        let peak = self
            .samples
            .iter()
            .max_by_key(|s| (s.queued, u64::MAX - s.tick));
        if let Some(p) = peak {
            t.row(vec![
                "peak queue (jobs @ tick)".into(),
                format!("{} @ {}", p.queued, p.tick),
            ]);
        }
        t.row(vec![
            "digest".into(),
            format!("{:016x}", self.digest),
        ]);
        t
    }

    /// Deterministic full rendering (both tables). Byte-identical across
    /// identical runs — the report half of the trace-determinism tests.
    pub fn render(&self) -> String {
        format!("{}\n{}", self.job_table().render(), self.cluster_table().render())
    }

    /// JSON form for the cluster-day bench artifact.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("alloc_policy", json::s(&self.alloc_policy)),
            ("scheduler", json::s(&self.scheduler)),
            ("replicas", json::num(self.replicas as f64)),
            ("ticks", json::num(self.ticks as f64)),
            ("mean_utilization", json::num(self.mean_utilization())),
            ("mean_fragmentation", json::num(self.mean_fragmentation())),
            (
                "mean_queue_wait_steps",
                json::num(self.mean_queue_wait_steps()),
            ),
            ("completed_jobs", json::num(self.completed_jobs() as f64)),
            (
                "total_goodput_steps_per_s",
                json::num(self.total_goodput_steps_per_s()),
            ),
            ("digest", json::s(&format!("{:016x}", self.digest))),
            (
                "jobs",
                json::arr(
                    self.jobs
                        .iter()
                        .map(|j| {
                            json::obj(vec![
                                ("job_id", json::num(j.job_id as f64)),
                                ("requested", json::num(j.requested as f64)),
                                (
                                    "queue_wait_steps",
                                    json::num(j.queue_wait_steps as f64),
                                ),
                                (
                                    "useful_steps",
                                    json::num(j.useful_steps as f64),
                                ),
                                (
                                    "failed_steps",
                                    json::num(j.failed_steps as f64),
                                ),
                                (
                                    "goodput_steps_per_s",
                                    json::num(j.goodput_steps_per_s),
                                ),
                                (
                                    "completed",
                                    Json::Bool(j.completed_step.is_some()),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

fn mean(xs: impl Iterator<Item = f64>) -> f64 {
    let mut sum = 0.0;
    let mut n = 0usize;
    for x in xs {
        sum += x;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> ClusterReport {
        ClusterReport {
            alloc_policy: "best-fit".into(),
            scheduler: "DHP".into(),
            replicas: 4,
            ticks: 3,
            jobs: vec![
                JobOutcome {
                    job_id: 0,
                    requested: 2,
                    arrival_step: 0,
                    admitted_step: Some(0),
                    completed_step: Some(2),
                    queue_wait_steps: 0,
                    useful_steps: 3,
                    failed_steps: 0,
                    sim_time_s: 6.0,
                    goodput_steps_per_s: 0.5,
                    digest: 0xABC,
                },
                JobOutcome {
                    job_id: 1,
                    requested: 4,
                    arrival_step: 1,
                    admitted_step: None,
                    completed_step: None,
                    queue_wait_steps: 2,
                    useful_steps: 0,
                    failed_steps: 0,
                    sim_time_s: 0.0,
                    goodput_steps_per_s: 0.0,
                    digest: 0,
                },
            ],
            samples: vec![
                ClusterSample {
                    tick: 0,
                    utilization: 0.5,
                    fragmentation: 0.0,
                    running: 1,
                    queued: 0,
                },
                ClusterSample {
                    tick: 1,
                    utilization: 0.5,
                    fragmentation: 0.5,
                    running: 1,
                    queued: 1,
                },
            ],
            digest: 0xD1D1,
        }
    }

    #[test]
    fn means_and_counts() {
        let r = report();
        assert!((r.mean_utilization() - 0.5).abs() < 1e-12);
        assert!((r.mean_fragmentation() - 0.25).abs() < 1e-12);
        // Only the admitted job counts toward mean queue wait.
        assert!((r.mean_queue_wait_steps() - 0.0).abs() < 1e-12);
        assert_eq!(r.completed_jobs(), 1);
        assert!((r.total_goodput_steps_per_s() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn render_is_deterministic_and_mentions_jobs() {
        let r = report();
        assert_eq!(r.render(), r.render());
        let text = r.render();
        assert!(text.contains("best-fit"));
        assert!(text.contains("goodput"));
        assert!(text.contains("digest"));
    }

    #[test]
    fn json_shape_has_slo_and_utilization_cells() {
        let j = report().to_json();
        assert!(j.get("mean_utilization").is_ok());
        assert!(j.get("mean_fragmentation").is_ok());
        assert!(j.get("mean_queue_wait_steps").is_ok());
        let jobs = j.get("jobs").unwrap().as_arr().unwrap();
        assert_eq!(jobs.len(), 2);
        assert!(jobs[0].get("goodput_steps_per_s").is_ok());
        let text = j.to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("scheduler").unwrap().as_str().unwrap(), "DHP");
    }

    #[test]
    fn empty_report_renders_without_panicking() {
        let r = ClusterReport {
            alloc_policy: "first-fit".into(),
            scheduler: "DHP".into(),
            replicas: 0,
            ticks: 0,
            jobs: vec![],
            samples: vec![],
            digest: 0,
        };
        assert_eq!(r.mean_utilization(), 0.0);
        assert_eq!(r.mean_queue_wait_steps(), 0.0);
        let _ = r.render();
    }
}
