//! Configuration system: model presets (paper Table 5), cluster topology,
//! training/run options, and a TOML-subset file format.

pub mod parser;
pub mod presets;

pub use presets::{ModelPreset, PRESETS};

use anyhow::{bail, Context, Result};

use crate::data::datasets::DatasetKind;
use crate::parallel::PoolCapacity;

/// Cluster hardware description (paper §6.1: 8 nodes × 8 Ascend 910B,
/// HCCS intra-node, 100 Gbps InfiniBand inter-node).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Physical node count.
    pub nodes: usize,
    /// NPUs per node.
    pub npus_per_node: usize,
    /// Per-NPU memory budget in bytes (910B: 64 GB).
    pub mem_bytes: u64,
    /// Intra-node link bandwidth, bytes/s (HCCS class).
    pub intra_bw: f64,
    /// Inter-node link bandwidth, bytes/s (100 Gbps IB ≈ 12.5 GB/s).
    pub inter_bw: f64,
    /// Static tensor-parallel degree (never reconfigured at runtime).
    pub tp: usize,
    /// Static pipeline-parallel degree (never reconfigured at runtime).
    pub pp: usize,
    /// Modeled per-member-rank communicator buffer footprint in bytes
    /// (`HCCL_BUFFSIZE`-class; default 64 MiB). Threaded to every
    /// budgeted [`crate::parallel::GroupPool`] so
    /// [`crate::parallel::PoolCapacity::BufferBytes`] budgets count the
    /// cluster's actual buffer size, not a hard-coded constant.
    pub group_buffer_bytes: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            nodes: 8,
            npus_per_node: 8,
            mem_bytes: 64 << 30,
            intra_bw: 196e9,
            inter_bw: 12.5e9,
            tp: 1,
            pp: 1,
            group_buffer_bytes:
                crate::parallel::group::GROUP_BUFFER_BYTES_PER_RANK,
        }
    }
}

impl ClusterConfig {
    /// Total physical NPUs.
    pub fn total_npus(&self) -> usize {
        self.nodes * self.npus_per_node
    }

    /// N in the paper: complete model replicas (one "rank" = TP×PP NPUs).
    pub fn replicas(&self) -> usize {
        self.total_npus() / (self.tp * self.pp)
    }

    /// Replica ranks per node (a replica never spans nodes for TP).
    pub fn replicas_per_node(&self) -> usize {
        self.npus_per_node / (self.tp * self.pp).min(self.npus_per_node)
    }

    /// Rescale the cluster to `total` NPUs, keeping the per-node shape
    /// (clusters smaller than one node collapse to a single node).
    pub fn with_npus(mut self, total: usize) -> Self {
        assert!(total % self.npus_per_node == 0 || total < self.npus_per_node);
        if total < self.npus_per_node {
            self.nodes = 1;
            self.npus_per_node = total;
        } else {
            self.nodes = total / self.npus_per_node;
        }
        self
    }

    /// Reject impossible topologies (zero devices, non-dividing TP×PP,
    /// non-positive bandwidths).
    pub fn validate(&self) -> Result<()> {
        if self.nodes == 0 || self.npus_per_node == 0 {
            bail!("cluster must have at least one NPU");
        }
        if self.tp * self.pp == 0 {
            bail!("tp and pp must be >= 1");
        }
        if self.total_npus() % (self.tp * self.pp) != 0 {
            bail!(
                "tp*pp = {} must divide total NPUs {}",
                self.tp * self.pp,
                self.total_npus()
            );
        }
        if self.intra_bw <= 0.0 || self.inter_bw <= 0.0 {
            bail!("bandwidths must be positive");
        }
        if self.group_buffer_bytes == 0 {
            bail!(
                "group_buffer_bytes must be positive (a zero footprint \
                 makes every BufferBytes pool budget vacuous)"
            );
        }
        Ok(())
    }
}

/// Which training stage is being measured (paper Fig. 6 vs Fig. 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TrainStage {
    /// Full end-to-end training (vision encoder trained).
    Full,
    /// Vision encoder frozen (Fig. 4's generalization experiment).
    FrozenVision,
}

/// Top-level run configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Model under training (paper Table 5 preset).
    pub model: ModelPreset,
    /// Workload dataset.
    pub dataset: DatasetKind,
    /// Cluster topology.
    pub cluster: ClusterConfig,
    /// Which parameters train.
    pub stage: TrainStage,
    /// Global batch size in sequences (paper fixes 512).
    pub gbs: usize,
    /// Data-sampling seed.
    pub seed: u64,
    /// Warmup steps excluded from measurement (paper: 5).
    pub warmup_steps: usize,
    /// Measured steps (paper: 10).
    pub measure_steps: usize,
    /// Communication-group pool budget of the run's session (TOML
    /// `[train] pool_cap_groups = <n>` or `pool_cap_buffer_mb = <mb>`,
    /// mutually exclusive; default unbounded — the seed behavior).
    /// Flows into every session built from this config via
    /// [`crate::experiments::ExpContext::from_train_config`].
    pub pool_capacity: PoolCapacity,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: PRESETS[2].clone(), // InternVL3-8B
            dataset: DatasetKind::OpenVid,
            cluster: ClusterConfig::default(),
            stage: TrainStage::Full,
            gbs: 512,
            seed: 0xD4B,
            warmup_steps: 5,
            measure_steps: 10,
            pool_capacity: PoolCapacity::Unbounded,
        }
    }
}

impl TrainConfig {
    /// Validate the cluster topology, batch, and pool-budget settings.
    pub fn validate(&self) -> Result<()> {
        self.cluster.validate()?;
        if self.gbs == 0 {
            bail!("gbs must be positive");
        }
        match self.pool_capacity {
            PoolCapacity::MaxGroups(0) => {
                bail!(
                    "pool_cap_groups must be >= 1 (a zero-group budget \
                     cannot establish any communicator)"
                )
            }
            PoolCapacity::BufferBytes(0) => {
                bail!(
                    "pool_cap_buffer_mb must be positive (a zero-byte \
                     budget cannot establish any communicator)"
                )
            }
            _ => {}
        }
        Ok(())
    }

    /// Load from a TOML-subset file (see [`parser`]).
    pub fn from_toml_file(path: &str) -> Result<TrainConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        Self::from_toml(&text)
    }

    /// Parse from TOML-subset text (see [`parser`]), validating the
    /// result.
    pub fn from_toml(text: &str) -> Result<TrainConfig> {
        let doc = parser::parse(text)?;
        let mut cfg = TrainConfig::default();

        if let Some(t) = doc.section("train") {
            if let Some(v) = t.get("gbs") {
                cfg.gbs = v.as_int()? as usize;
            }
            if let Some(v) = t.get("seed") {
                cfg.seed = v.as_int()? as u64;
            }
            if let Some(v) = t.get("model") {
                cfg.model = presets::by_name(v.as_str()?)
                    .with_context(|| format!("unknown model {:?}", v.as_str()))?;
            }
            if let Some(v) = t.get("dataset") {
                cfg.dataset = DatasetKind::by_name(v.as_str()?)?;
            }
            if let Some(v) = t.get("stage") {
                cfg.stage = match v.as_str()? {
                    "full" => TrainStage::Full,
                    "frozen_vision" => TrainStage::FrozenVision,
                    other => bail!("unknown stage {other:?}"),
                };
            }
            if let Some(v) = t.get("warmup_steps") {
                cfg.warmup_steps = v.as_int()? as usize;
            }
            if let Some(v) = t.get("measure_steps") {
                cfg.measure_steps = v.as_int()? as usize;
            }
            let cap_groups = t.get("pool_cap_groups");
            let cap_bytes = t.get("pool_cap_buffer_mb");
            if cap_groups.is_some() && cap_bytes.is_some() {
                bail!(
                    "set at most one of pool_cap_groups / pool_cap_buffer_mb \
                     (one pool, one budget)"
                );
            }
            if let Some(v) = cap_groups {
                let n = v.as_int()?;
                if n < 0 {
                    bail!("pool_cap_groups must be >= 1, got {n}");
                }
                cfg.pool_capacity = PoolCapacity::MaxGroups(n as usize);
            }
            if let Some(v) = cap_bytes {
                let mb = v.as_float()?;
                if mb < 0.0 {
                    bail!("pool_cap_buffer_mb must be positive, got {mb}");
                }
                cfg.pool_capacity =
                    PoolCapacity::BufferBytes((mb * (1u64 << 20) as f64) as u64);
            }
        }
        if let Some(c) = doc.section("cluster") {
            if let Some(v) = c.get("nodes") {
                cfg.cluster.nodes = v.as_int()? as usize;
            }
            if let Some(v) = c.get("npus_per_node") {
                cfg.cluster.npus_per_node = v.as_int()? as usize;
            }
            if let Some(v) = c.get("mem_gb") {
                cfg.cluster.mem_bytes = (v.as_float()? * (1u64 << 30) as f64) as u64;
            }
            if let Some(v) = c.get("intra_bw_gbps") {
                cfg.cluster.intra_bw = v.as_float()? * 1e9;
            }
            if let Some(v) = c.get("inter_bw_gbps") {
                cfg.cluster.inter_bw = v.as_float()? * 1e9;
            }
            if let Some(v) = c.get("tp") {
                cfg.cluster.tp = v.as_int()? as usize;
            }
            if let Some(v) = c.get("pp") {
                cfg.cluster.pp = v.as_int()? as usize;
            }
            if let Some(v) = c.get("group_buffer_mb") {
                cfg.cluster.group_buffer_bytes =
                    (v.as_float()? * (1u64 << 20) as f64) as u64;
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_cluster_matches_paper() {
        let c = ClusterConfig::default();
        assert_eq!(c.total_npus(), 64);
        assert_eq!(c.replicas(), 64);
        assert_eq!(c.mem_bytes, 64 << 30);
        c.validate().unwrap();
    }

    #[test]
    fn replicas_account_for_tp_pp() {
        let c = ClusterConfig {
            tp: 2,
            pp: 2,
            ..Default::default()
        };
        assert_eq!(c.replicas(), 16);
    }

    #[test]
    fn with_npus_scales_nodes() {
        let c = ClusterConfig::default().with_npus(16);
        assert_eq!(c.nodes, 2);
        assert_eq!(c.total_npus(), 16);
    }

    #[test]
    fn invalid_tp_rejected() {
        let c = ClusterConfig {
            tp: 3,
            ..Default::default()
        };
        assert!(c.validate().is_err()); // 3 does not divide 64
    }

    #[test]
    fn toml_roundtrip() {
        let cfg = TrainConfig::from_toml(
            r#"
            [train]
            gbs = 256
            model = "Qwen3VL-4B"
            dataset = "msrvtt"
            stage = "frozen_vision"

            [cluster]
            nodes = 4
            npus_per_node = 8
            mem_gb = 32.0
            tp = 2
            "#,
        )
        .unwrap();
        assert_eq!(cfg.gbs, 256);
        assert_eq!(cfg.model.name, "Qwen3VL-4B");
        assert_eq!(cfg.dataset, DatasetKind::Msrvtt);
        assert_eq!(cfg.stage, TrainStage::FrozenVision);
        assert_eq!(cfg.cluster.nodes, 4);
        assert_eq!(cfg.cluster.mem_bytes, 32 << 30);
        assert_eq!(cfg.cluster.replicas(), 16);
    }

    #[test]
    fn unknown_model_is_error() {
        assert!(TrainConfig::from_toml("[train]\nmodel = \"GPT-9\"\n").is_err());
    }

    #[test]
    fn pool_capacity_round_trips_and_rejects_zero() {
        // Group-count form.
        let cfg =
            TrainConfig::from_toml("[train]\npool_cap_groups = 12\n").unwrap();
        assert_eq!(cfg.pool_capacity, PoolCapacity::MaxGroups(12));
        // Buffer-byte form (MB → bytes).
        let cfg = TrainConfig::from_toml("[train]\npool_cap_buffer_mb = 256\n")
            .unwrap();
        assert_eq!(cfg.pool_capacity, PoolCapacity::BufferBytes(256 << 20));
        // Fractional MB budgets survive the conversion.
        let cfg = TrainConfig::from_toml("[train]\npool_cap_buffer_mb = 0.5\n")
            .unwrap();
        assert_eq!(cfg.pool_capacity, PoolCapacity::BufferBytes(512 << 10));
        // Unset ⇒ the seed's unbounded default.
        assert_eq!(
            TrainConfig::from_toml("[train]\ngbs = 8\n").unwrap().pool_capacity,
            PoolCapacity::Unbounded
        );
        // The validate reject-0 paths — and negatives must not wrap
        // through the integer cast into an accidental unbounded budget.
        assert!(TrainConfig::from_toml("[train]\npool_cap_groups = 0\n").is_err());
        assert!(
            TrainConfig::from_toml("[train]\npool_cap_buffer_mb = 0\n").is_err()
        );
        assert!(TrainConfig::from_toml("[train]\npool_cap_groups = -1\n").is_err());
        assert!(
            TrainConfig::from_toml("[train]\npool_cap_buffer_mb = -4\n").is_err()
        );
        // Mutually exclusive budgets.
        assert!(TrainConfig::from_toml(
            "[train]\npool_cap_groups = 2\npool_cap_buffer_mb = 64\n"
        )
        .is_err());
    }

    #[test]
    fn group_buffer_zero_rejected_through_toml() {
        // The validate reject-0 path exercised end-to-end through the
        // parser, not just on a hand-built struct.
        assert!(TrainConfig::from_toml("[cluster]\ngroup_buffer_mb = 0\n").is_err());
        let cfg =
            TrainConfig::from_toml("[cluster]\ngroup_buffer_mb = 128\n").unwrap();
        assert_eq!(cfg.cluster.group_buffer_bytes, 128 << 20);
    }

    #[test]
    fn group_buffer_bytes_defaults_and_parses() {
        let c = ClusterConfig::default();
        assert_eq!(
            c.group_buffer_bytes,
            crate::parallel::group::GROUP_BUFFER_BYTES_PER_RANK
        );
        let cfg = TrainConfig::from_toml(
            "[cluster]\ngroup_buffer_mb = 16\n",
        )
        .unwrap();
        assert_eq!(cfg.cluster.group_buffer_bytes, 16 << 20);
        let zero = ClusterConfig {
            group_buffer_bytes: 0,
            ..Default::default()
        };
        assert!(zero.validate().is_err());
    }
}
