//! Model presets — the exact configurations of paper Table 5, plus the
//! derived quantities the cost model needs (per-token FLOPs and activation
//! bytes).

use std::sync::OnceLock;

/// One evaluated model configuration (paper Table 5).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelPreset {
    /// Table 5 model name (e.g. "InternVL3-8B").
    pub name: &'static str,
    /// Model family ("InternVL3" / "Qwen3VL").
    pub family: &'static str,
    /// Nominal parameter count in billions (from the model name).
    pub params_b: f64,
    /// LM transformer layers.
    pub layers: usize,
    /// LM attention heads.
    pub heads: usize,
    /// GQA key/value groups.
    pub kv_groups: usize,
    /// LM hidden dim.
    pub hidden: usize,
    /// Vision encoder hidden dim.
    pub vision_hidden: usize,
    /// Vision encoder layers (ViT-300M-class towers; not in Table 5 —
    /// fixed at 24 as in InternViT/Qwen-ViT).
    pub vision_layers: usize,
}

impl ModelPreset {
    /// Dense FLOPs per token for one LM forward pass, excluding the
    /// attention O(L²) term (that term is carried separately by the cost
    /// model's α₁ coefficient): QKV/O projections + MLP.
    pub fn linear_flops_per_token(&self) -> f64 {
        let h = self.hidden as f64;
        let l = self.layers as f64;
        // q + o full size, kv scaled by GQA groups/heads, mlp ratio 4 (up+down).
        let kv_frac = self.kv_groups as f64 / self.heads as f64;
        let attn_proj = 2.0 * h * h * (2.0 + 2.0 * kv_frac);
        let mlp = 2.0 * h * (4.0 * h) * 2.0;
        l * (attn_proj + mlp)
    }

    /// FLOPs per token² for the attention score/value matmuls (the
    /// coefficient of the quadratic |s|² term, causal base cost).
    pub fn attn_flops_per_token_sq(&self) -> f64 {
        let h = self.hidden as f64;
        let l = self.layers as f64;
        // QK^T + PV: 2 * 2 * h per (query, key) pair, halved by causality.
        l * 2.0 * 2.0 * h * 0.5
    }

    /// Vision-encoder FLOPs per vision-token (linear part).
    pub fn vision_linear_flops_per_token(&self) -> f64 {
        let h = self.vision_hidden as f64;
        let l = self.vision_layers as f64;
        l * (2.0 * h * h * 4.0 + 2.0 * h * (4.0 * h) * 2.0)
    }

    /// Vision-encoder quadratic FLOPs (full attention: no causal halving).
    pub fn vision_attn_flops_per_token_sq(&self) -> f64 {
        let h = self.vision_hidden as f64;
        let l = self.vision_layers as f64;
        l * 2.0 * 2.0 * h
    }

    /// Activation bytes per token (the paper's M_token in Eq. 7): the
    /// classic Megatron accounting of ~34·h bytes per token per layer
    /// (residual + attention + MLP activations, mixed precision, flash
    /// attention removing the L² term) — see Korthikanti et al. 2022.
    pub fn act_bytes_per_token(&self) -> f64 {
        34.0 * self.hidden as f64 * self.layers as f64
    }

    /// Model-state bytes per rank under ZeRO-3 over `n_ranks` (Eq. 7's
    /// M_ms, constant per rank): params + grads + Adam moments in mixed
    /// precision = 16 bytes/param, sharded.
    pub fn model_state_bytes(&self, zero_shards: usize) -> f64 {
        16.0 * self.params_b * 1e9 / zero_shards.max(1) as f64
    }
}

/// Lazily-built preset table (std `OnceLock`; `once_cell` is not
/// vendored offline). Derefs to a slice so call sites read naturally:
/// `PRESETS.iter()`, `PRESETS[2]`, `&PRESETS` as `&[ModelPreset]`.
pub struct Presets(OnceLock<Vec<ModelPreset>>);

impl std::ops::Deref for Presets {
    type Target = [ModelPreset];

    fn deref(&self) -> &[ModelPreset] {
        self.0.get_or_init(build_presets)
    }
}

/// All six evaluated models (paper Table 5).
pub static PRESETS: Presets = Presets(OnceLock::new());

fn build_presets() -> Vec<ModelPreset> {
    vec![
        ModelPreset {
            name: "InternVL3-2B",
            family: "InternVL3",
            params_b: 2.0,
            layers: 28,
            heads: 12,
            kv_groups: 2,
            hidden: 1536,
            vision_hidden: 1024,
            vision_layers: 24,
        },
        ModelPreset {
            name: "InternVL2.5-4B",
            family: "InternVL3",
            params_b: 4.0,
            layers: 36,
            heads: 16,
            kv_groups: 8,
            hidden: 2048,
            vision_hidden: 1024,
            vision_layers: 24,
        },
        ModelPreset {
            name: "InternVL3-8B",
            family: "InternVL3",
            params_b: 8.0,
            layers: 28,
            heads: 28,
            kv_groups: 4,
            hidden: 3584,
            vision_hidden: 1024,
            vision_layers: 24,
        },
        ModelPreset {
            name: "Qwen3VL-2B",
            family: "Qwen3VL",
            params_b: 2.0,
            layers: 28,
            heads: 16,
            kv_groups: 8,
            hidden: 2048,
            vision_hidden: 1024,
            vision_layers: 24,
        },
        ModelPreset {
            name: "Qwen3VL-4B",
            family: "Qwen3VL",
            params_b: 4.0,
            layers: 36,
            heads: 32,
            kv_groups: 8,
            hidden: 2560,
            vision_hidden: 1024,
            vision_layers: 24,
        },
        ModelPreset {
            name: "Qwen3VL-8B",
            family: "Qwen3VL",
            params_b: 8.0,
            layers: 36,
            heads: 32,
            kv_groups: 8,
            hidden: 4096,
            vision_hidden: 1152,
            vision_layers: 24,
        },
    ]
}

/// Look up a preset by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<ModelPreset> {
    let lower = name.to_lowercase();
    PRESETS
        .iter()
        .find(|p| p.name.to_lowercase() == lower)
        .cloned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_presets_match_table5() {
        assert_eq!(PRESETS.len(), 6);
        let q8 = by_name("Qwen3VL-8B").unwrap();
        assert_eq!(q8.layers, 36);
        assert_eq!(q8.heads, 32);
        assert_eq!(q8.kv_groups, 8);
        assert_eq!(q8.hidden, 4096);
        assert_eq!(q8.vision_hidden, 1152);
        let i2 = by_name("internvl3-2b").unwrap();
        assert_eq!(i2.hidden, 1536);
        assert_eq!(i2.kv_groups, 2);
    }

    #[test]
    fn flops_scale_with_model_size() {
        let small = by_name("InternVL3-2B").unwrap();
        let big = by_name("InternVL3-8B").unwrap();
        assert!(big.linear_flops_per_token() > 3.0 * small.linear_flops_per_token());
        assert!(big.attn_flops_per_token_sq() > small.attn_flops_per_token_sq());
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn memory_model_sane() {
        let m = by_name("InternVL3-8B").unwrap();
        // 8B params × 16 B sharded 64 ways = 2 GB/rank.
        let per_rank = m.model_state_bytes(64);
        assert!((per_rank - 2e9).abs() < 1e8);
        // Activation bytes/token positive and grows with hidden.
        assert!(m.act_bytes_per_token() > by_name("InternVL3-2B").unwrap().act_bytes_per_token());
    }
}
