//! TOML-subset parser for config files (serde/toml unavailable offline).
//!
//! Supported: `[section]` headers, `key = value` with string, integer,
//! float, boolean and flat array values, `#` comments, blank lines.
//! Unsupported (rejected): nested tables, multi-line strings, dates.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// A parsed scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// A quoted string.
    Str(String),
    /// A signed integer.
    Int(i64),
    /// A float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// A flat `[v, v, ...]` array.
    Array(Vec<Value>),
}

impl Value {
    /// String accessor (errors on any other variant).
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => Err(anyhow!("expected string, got {other:?}")),
        }
    }

    /// Integer accessor (errors on any other variant).
    pub fn as_int(&self) -> Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            other => Err(anyhow!("expected integer, got {other:?}")),
        }
    }

    /// Float accessor that also accepts integers.
    pub fn as_float(&self) -> Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            other => Err(anyhow!("expected number, got {other:?}")),
        }
    }

    /// Boolean accessor (errors on any other variant).
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => Err(anyhow!("expected bool, got {other:?}")),
        }
    }

    /// Array accessor (errors on any other variant).
    pub fn as_array(&self) -> Result<&[Value]> {
        match self {
            Value::Array(v) => Ok(v),
            other => Err(anyhow!("expected array, got {other:?}")),
        }
    }
}

/// One `[section]` of key/value pairs.
pub type Section = BTreeMap<String, Value>;

/// A parsed document: named sections plus a root section for keys that
/// appear before any header.
#[derive(Debug, Default, Clone)]
pub struct Document {
    /// Keys appearing before any `[section]` header.
    pub root: Section,
    /// Named sections in declaration order-independent storage.
    pub sections: BTreeMap<String, Section>,
}

impl Document {
    /// Look up a named `[section]`.
    pub fn section(&self, name: &str) -> Option<&Section> {
        self.sections.get(name)
    }
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Document> {
    let mut doc = Document::default();
    let mut current: Option<String> = None;
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| anyhow!("line {}: unterminated section header", lineno + 1))?
                .trim();
            if name.is_empty() || name.contains('[') || name.contains('.') {
                bail!("line {}: invalid section name {name:?}", lineno + 1);
            }
            doc.sections.entry(name.to_string()).or_default();
            current = Some(name.to_string());
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let value = parse_value(value.trim())
            .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
        let section = match &current {
            Some(name) => doc.sections.get_mut(name).unwrap(),
            None => &mut doc.root,
        };
        if section.insert(key.to_string(), value).is_some() {
            bail!("line {}: duplicate key {key:?}", lineno + 1);
        }
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // A `#` outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<Value> {
    if text.is_empty() {
        bail!("missing value");
    }
    if let Some(inner) = text.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("unterminated string"))?;
        if inner.contains('"') {
            bail!("embedded quotes not supported");
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items = inner
            .split(',')
            .map(|s| parse_value(s.trim()))
            .collect::<Result<Vec<_>>>()?;
        return Ok(Value::Array(items));
    }
    match text {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = text.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = text.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value {text:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_document() {
        let doc = parse(
            r#"
            # top comment
            title = "dhp"

            [cluster]
            nodes = 8            # trailing comment
            mem_gb = 64.0
            fast = true
            npus = [8, 16, 32]

            [train]
            dataset = "openvid"
            "#,
        )
        .unwrap();
        assert_eq!(doc.root["title"].as_str().unwrap(), "dhp");
        let c = doc.section("cluster").unwrap();
        assert_eq!(c["nodes"].as_int().unwrap(), 8);
        assert_eq!(c["mem_gb"].as_float().unwrap(), 64.0);
        assert!(c["fast"].as_bool().unwrap());
        let arr = c["npus"].as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_int().unwrap(), 16);
        assert_eq!(
            doc.section("train").unwrap()["dataset"].as_str().unwrap(),
            "openvid"
        );
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = parse("x = 3").unwrap();
        assert_eq!(doc.root["x"].as_float().unwrap(), 3.0);
    }

    #[test]
    fn hash_in_string_is_not_comment() {
        let doc = parse("x = \"a#b\"").unwrap();
        assert_eq!(doc.root["x"].as_str().unwrap(), "a#b");
    }

    #[test]
    fn errors() {
        assert!(parse("[unterminated").is_err());
        assert!(parse("novalue =").is_err());
        assert!(parse("bare").is_err());
        assert!(parse("x = 1\nx = 2").is_err());
        assert!(parse("[a.b]\n").is_err());
        assert!(parse("x = \"unterminated").is_err());
    }

    #[test]
    fn empty_array() {
        let doc = parse("xs = []").unwrap();
        assert!(doc.root["xs"].as_array().unwrap().is_empty());
    }
}
