//! Synthetic dataset generators matching the paper's three corpora.
//!
//! The real MSRVTT / InternVid / OpenVid videos are unavailable here; DHP
//! is sensitive only to the *length and mask distribution* of the data
//! (DESIGN.md §2), so each generator reproduces the published duration
//! statistics:
//!
//! * **MSRVTT** — 10k clips, 10–30 s, "relatively uniform yet spanning a
//!   certain range" (paper §6.5 case 2).
//! * **InternVid** — 10M clips, mean ≈ 13 s with a moderate long tail.
//! * **OpenVid** — "long-tailed and highly diverse" (§6.5 case 1): most
//!   clips short, heavy tail past 64 s.
//!
//! Durations are converted to vision tokens at `fps × tokens_per_frame`,
//! and each sample carries a text span, mirroring interleaved video-text
//! training batches.

use anyhow::{bail, Result};

use super::distribution::Distribution;
use super::sequence::Sequence;
use crate::util::rng::Rng;

/// Which corpus to emulate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DatasetKind {
    /// MSRVTT: 10–30 s clips, relatively uniform durations.
    Msrvtt,
    /// InternVid: mean ≈ 13 s with a moderate long tail.
    InternVid,
    /// OpenVid: long-tailed and highly diverse (the paper's hard case).
    OpenVid,
}

impl DatasetKind {
    /// Display name used in reports.
    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::Msrvtt => "MSRVTT",
            DatasetKind::InternVid => "InternVid",
            DatasetKind::OpenVid => "OpenVid",
        }
    }

    /// Parse a CLI dataset name (case-insensitive).
    pub fn by_name(name: &str) -> Result<DatasetKind> {
        match name.to_lowercase().as_str() {
            "msrvtt" | "msr-vtt" => Ok(DatasetKind::Msrvtt),
            "internvid" => Ok(DatasetKind::InternVid),
            "openvid" => Ok(DatasetKind::OpenVid),
            other => bail!("unknown dataset {other:?}"),
        }
    }

    /// All three corpora, in paper order.
    pub fn all() -> [DatasetKind; 3] {
        [
            DatasetKind::Msrvtt,
            DatasetKind::InternVid,
            DatasetKind::OpenVid,
        ]
    }

    /// The duration distribution (seconds) for this corpus.
    pub fn duration_dist(&self) -> Distribution {
        match self {
            // 10–30 s, mildly peaked mid-range.
            DatasetKind::Msrvtt => Distribution::Mixture(vec![
                (0.8, Distribution::Uniform { lo: 10.0, hi: 30.0 }),
                (
                    0.2,
                    Distribution::LogNormal {
                        mu: 2.9,
                        sigma: 0.25,
                        min_s: 10.0,
                        max_s: 32.0,
                    },
                ),
            ]),
            // Mean ~13 s, moderate tail to ~3 min.
            DatasetKind::InternVid => Distribution::LogNormal {
                mu: 2.1,
                sigma: 0.85,
                min_s: 1.0,
                max_s: 180.0,
            },
            // Most < 8 s, heavy tail past 64 s (Fig. 1's skew).
            DatasetKind::OpenVid => Distribution::Mixture(vec![
                (
                    0.85,
                    Distribution::LogNormal {
                        mu: 1.35,
                        sigma: 0.75,
                        min_s: 0.5,
                        max_s: 48.0,
                    },
                ),
                (
                    0.15,
                    Distribution::LogNormal {
                        mu: 3.9,
                        sigma: 0.7,
                        min_s: 16.0,
                        max_s: 360.0,
                    },
                ),
            ]),
        }
    }
}

/// Video → token conversion and text-span parameters.
#[derive(Debug, Clone)]
pub struct TokenizerSpec {
    /// Sampled frames per second of video.
    pub fps: f64,
    /// Vision tokens per frame (patches after merging).
    pub tokens_per_frame: f64,
    /// Text span lower bound (tokens).
    pub text_min: u64,
    /// Text span upper bound (tokens).
    pub text_max: u64,
}

impl Default for TokenizerSpec {
    fn default() -> Self {
        // 2 fps × 64 tokens/frame: an 8 s clip ⇒ 1024 vision tokens,
        // a 64 s clip ⇒ 8192 — long-context territory.
        TokenizerSpec {
            fps: 2.0,
            tokens_per_frame: 64.0,
            text_min: 32,
            text_max: 512,
        }
    }
}

/// Streaming sampler over one corpus.
#[derive(Debug, Clone)]
pub struct DatasetSampler {
    /// Corpus being emulated.
    pub kind: DatasetKind,
    /// Video→token conversion parameters.
    pub spec: TokenizerSpec,
    dist: Distribution,
    rng: Rng,
    next_id: u64,
}

impl DatasetSampler {
    /// Deterministic sampler over `kind` seeded with `seed`.
    pub fn new(kind: DatasetKind, seed: u64) -> Self {
        DatasetSampler {
            kind,
            spec: TokenizerSpec::default(),
            dist: kind.duration_dist(),
            rng: Rng::new(seed ^ kind as u64),
            next_id: 0,
        }
    }

    /// Override the tokenizer spec (fps, tokens/frame, text bounds).
    pub fn with_spec(mut self, spec: TokenizerSpec) -> Self {
        self.spec = spec;
        self
    }

    /// Draw one interleaved video-text sequence.
    pub fn sample(&mut self) -> Sequence {
        let duration = self.dist.sample(&mut self.rng);
        let vision =
            (duration * self.spec.fps * self.spec.tokens_per_frame).round() as u64;
        let text = self
            .rng
            .range_u64(self.spec.text_min, self.spec.text_max + 1);
        let id = self.next_id;
        self.next_id += 1;
        Sequence {
            id,
            vision_tokens: vision.max(1),
            text_tokens: text,
            duration_s: duration,
        }
    }

    /// Draw a full global batch.
    pub fn sample_batch(&mut self, n: usize) -> Vec<Sequence> {
        (0..n).map(|_| self.sample()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::distribution::{tail_ratio, Histogram};

    #[test]
    fn names_roundtrip() {
        for kind in DatasetKind::all() {
            assert_eq!(
                DatasetKind::by_name(kind.name()).unwrap(),
                kind
            );
        }
        assert!(DatasetKind::by_name("imagenet").is_err());
    }

    #[test]
    fn msrvtt_durations_bounded() {
        let mut s = DatasetSampler::new(DatasetKind::Msrvtt, 1);
        for seq in s.sample_batch(2000) {
            assert!(
                (10.0..=32.0).contains(&seq.duration_s),
                "duration {}",
                seq.duration_s
            );
        }
    }

    #[test]
    fn openvid_is_most_skewed() {
        // Paper §6.5: OpenVid is "long-tailed and highly diverse",
        // MSRVTT "more uniform". Verify the generators reproduce the
        // ordering of skewness.
        let ratios: Vec<f64> = DatasetKind::all()
            .iter()
            .map(|&k| {
                let mut s = DatasetSampler::new(k, 7);
                let d: Vec<f64> =
                    s.sample_batch(8000).iter().map(|q| q.duration_s).collect();
                tail_ratio(&d)
            })
            .collect();
        let (msrvtt, internvid, openvid) = (ratios[0], ratios[1], ratios[2]);
        assert!(openvid > internvid, "openvid {openvid} internvid {internvid}");
        assert!(internvid > msrvtt, "internvid {internvid} msrvtt {msrvtt}");
    }

    #[test]
    fn openvid_fig1_shape() {
        // Fig. 1: most videos under 8 s, few exceed 64 s — but not none.
        let mut s = DatasetSampler::new(DatasetKind::OpenVid, 3);
        let mut h = Histogram::fig1_buckets();
        for seq in s.sample_batch(10_000) {
            h.add(seq.duration_s);
        }
        let f = h.fractions();
        let under8 = f[0] + f[1] + f[2];
        let over64 = f[6];
        assert!(under8 > 0.5, "under-8s mass {under8}");
        assert!(over64 > 0.005 && over64 < 0.15, "over-64s mass {over64}");
    }

    #[test]
    fn token_conversion() {
        let mut s = DatasetSampler::new(DatasetKind::InternVid, 5);
        let seq = s.sample();
        let expect = (seq.duration_s * 2.0 * 64.0).round() as u64;
        assert_eq!(seq.vision_tokens, expect.max(1));
        assert!((32..=512).contains(&seq.text_tokens));
    }

    #[test]
    fn sampler_is_deterministic_per_seed() {
        let a: Vec<u64> = DatasetSampler::new(DatasetKind::OpenVid, 42)
            .sample_batch(32)
            .iter()
            .map(|s| s.len())
            .collect();
        let b: Vec<u64> = DatasetSampler::new(DatasetKind::OpenVid, 42)
            .sample_batch(32)
            .iter()
            .map(|s| s.len())
            .collect();
        assert_eq!(a, b);
    }

    #[test]
    fn ids_are_unique_and_increasing() {
        let mut s = DatasetSampler::new(DatasetKind::Msrvtt, 9);
        let batch = s.sample_batch(100);
        for (i, seq) in batch.iter().enumerate() {
            assert_eq!(seq.id, i as u64);
        }
    }
}
