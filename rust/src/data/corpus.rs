//! Synthetic trainable corpus for the REAL end-to-end training run.
//!
//! The e2e example trains the ~100M-param JAX MLLM via PJRT, so it needs
//! actual tensor data with learnable structure (not just lengths):
//!
//! * each sample belongs to a latent "video class" `c`;
//! * vision patches are a class prototype plus noise;
//! * text is a class-conditioned first-order Markov chain over the vocab.
//!
//! The LM can therefore reduce loss substantially below `ln(vocab)` by
//! learning the bigram structure, and further by attending to the vision
//! prefix — the loss curve in EXPERIMENTS.md §E2E demonstrates both.

use crate::util::rng::Rng;

/// One realized training sample (tensors laid out for the AOT artifact).
#[derive(Debug, Clone)]
pub struct CorpusItem {
    /// Latent class (for diagnostics).
    pub class: usize,
    /// [lv × patch_dim] row-major patch features.
    pub vis: Vec<f32>,
    /// [lt] input token ids.
    pub tok: Vec<i32>,
    /// [lt] next-token targets.
    pub tgt: Vec<i32>,
}

/// Deterministic generator of class-structured multimodal samples.
pub struct CorpusGenerator {
    /// Model vocabulary size (token-id space of the artifact).
    pub vocab: usize,
    /// Tokens actually used by the corpus (≤ vocab): keeping the active
    /// vocabulary small makes the bigram structure learnable within a few
    /// hundred streaming steps — the point of the e2e loss curve.
    pub active_vocab: usize,
    /// Vision patch feature dimension.
    pub patch_dim: usize,
    /// Number of latent classes in the synthetic corpus.
    pub num_classes: usize,
    /// Per-class patch prototypes, [num_classes × patch_dim].
    prototypes: Vec<f32>,
    /// Per-class Markov transition tables: for each class and source
    /// token, a small set of likely successors.
    successors: Vec<Vec<[u32; 4]>>,
    rng: Rng,
}

impl CorpusGenerator {
    /// Deterministic generator over `vocab` tokens and `patch_dim`
    /// features.
    pub fn new(vocab: usize, patch_dim: usize, seed: u64) -> Self {
        let num_classes = 2;
        let active_vocab = vocab.min(256);
        let mut rng = Rng::new(seed);
        let mut prototypes = Vec::with_capacity(num_classes * patch_dim);
        for _ in 0..num_classes * patch_dim {
            prototypes.push(rng.normal() as f32);
        }
        // Sparse per-class bigram structure over the ACTIVE vocab: each
        // token has 4 plausible successors, drawn with skewed probability
        // (0.7/0.1/0.1/0.1 — conditional entropy ≈ 1.16 nats, far below
        // ln(vocab)), so a fitted model shows a clear loss drop.
        let mut successors = Vec::with_capacity(num_classes);
        for _ in 0..num_classes {
            let mut table = Vec::with_capacity(active_vocab);
            for _ in 0..active_vocab {
                table.push([
                    rng.range_u64(0, active_vocab as u64) as u32,
                    rng.range_u64(0, active_vocab as u64) as u32,
                    rng.range_u64(0, active_vocab as u64) as u32,
                    rng.range_u64(0, active_vocab as u64) as u32,
                ]);
            }
            successors.push(table);
        }
        CorpusGenerator {
            vocab,
            active_vocab,
            patch_dim,
            num_classes,
            prototypes,
            successors,
            rng,
        }
    }

    /// Sample one item with `lv` vision patches and `lt` text tokens.
    pub fn sample(&mut self, lv: usize, lt: usize) -> CorpusItem {
        let class = self.rng.range_usize(0, self.num_classes);
        let proto = &self.prototypes[class * self.patch_dim..(class + 1) * self.patch_dim];
        let mut vis = Vec::with_capacity(lv * self.patch_dim);
        for _ in 0..lv {
            for &p in proto {
                vis.push(p + 0.3 * self.rng.normal() as f32);
            }
        }
        // Chain of lt+1 tokens: inputs are [0..lt], targets are [1..lt+1].
        // Successor choice is skewed 0.7/0.1/0.1/0.1.
        let table = &self.successors[class];
        let mut chain = Vec::with_capacity(lt + 1);
        chain.push(self.rng.range_u64(0, self.active_vocab as u64) as u32);
        for i in 0..lt {
            let prev = chain[i] as usize;
            let u = self.rng.uniform();
            let slot = if u < 0.7 {
                0
            } else {
                1 + self.rng.range_usize(0, 3)
            };
            let next = table[prev][slot];
            chain.push(next);
        }
        let tok = chain[..lt].iter().map(|&t| t as i32).collect();
        let tgt = chain[1..].iter().map(|&t| t as i32).collect();
        CorpusItem {
            class,
            vis,
            tok,
            tgt,
        }
    }

    /// Sample a batch of `n` items, concatenated per-field for the AOT
    /// artifact's [B, ...] inputs.
    pub fn sample_flat_batch(
        &mut self,
        n: usize,
        lv: usize,
        lt: usize,
    ) -> (Vec<f32>, Vec<i32>, Vec<i32>) {
        let mut vis = Vec::with_capacity(n * lv * self.patch_dim);
        let mut tok = Vec::with_capacity(n * lt);
        let mut tgt = Vec::with_capacity(n * lt);
        for _ in 0..n {
            let item = self.sample(lv, lt);
            vis.extend_from_slice(&item.vis);
            tok.extend_from_slice(&item.tok);
            tgt.extend_from_slice(&item.tgt);
        }
        (vis, tok, tgt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        let mut g = CorpusGenerator::new(512, 16, 1);
        let item = g.sample(8, 24);
        assert_eq!(item.vis.len(), 8 * 16);
        assert_eq!(item.tok.len(), 24);
        assert_eq!(item.tgt.len(), 24);
        assert!(item.tok.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let mut g = CorpusGenerator::new(128, 4, 2);
        let item = g.sample(2, 16);
        // tgt[i] must equal tok[i+1] for i < lt-1 (same underlying chain).
        for i in 0..15 {
            assert_eq!(item.tgt[i], item.tok[i + 1]);
        }
    }

    #[test]
    fn bigram_structure_exists() {
        // Successor sets are small: the empirical conditional entropy of
        // next|prev must be far below uniform.
        let mut g = CorpusGenerator::new(256, 4, 3);
        let mut seen: std::collections::HashMap<i32, std::collections::HashSet<i32>> =
            Default::default();
        for _ in 0..200 {
            let item = g.sample(1, 64);
            if item.class != 0 {
                continue; // per-class tables differ
            }
            for i in 0..63 {
                seen.entry(item.tok[i]).or_default().insert(item.tok[i + 1]);
            }
        }
        let max_succ = seen.values().map(|s| s.len()).max().unwrap_or(0);
        assert!(max_succ <= 4, "successor fan-out {max_succ} > 4");
    }

    #[test]
    fn flat_batch_layout() {
        let mut g = CorpusGenerator::new(64, 8, 4);
        let (vis, tok, tgt) = g.sample_flat_batch(3, 4, 12);
        assert_eq!(vis.len(), 3 * 4 * 8);
        assert_eq!(tok.len(), 3 * 12);
        assert_eq!(tgt.len(), 3 * 12);
    }

    #[test]
    fn deterministic() {
        let a = CorpusGenerator::new(64, 8, 9).sample(2, 8);
        let b = CorpusGenerator::new(64, 8, 9).sample(2, 8);
        assert_eq!(a.tok, b.tok);
        assert_eq!(a.vis, b.vis);
    }
}
