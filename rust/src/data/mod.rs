//! Multimodal data substrate: heterogeneous sequences, the long-tail
//! video-length distributions of the paper's three datasets (Fig. 1),
//! global-batch / micro-batch structures, and a synthetic trainable corpus
//! for the real end-to-end run.

pub mod batch;
pub mod corpus;
pub mod datasets;
pub mod distribution;
pub mod sequence;

pub use batch::{GlobalBatch, MicroBatch, MicroBatchPlanner};
pub use datasets::{DatasetKind, DatasetSampler};
pub use sequence::Sequence;
