//! Length distributions: the statistical shapes behind paper Fig. 1.
//!
//! Real multimodal corpora have long-tail video-duration distributions —
//! "most videos are under 8 seconds, while few exceed 64 seconds" (§4.1).
//! We model durations with (mixtures of) log-normals plus a bounded
//! uniform component, parameterized per dataset in [`super::datasets`].

use crate::util::rng::Rng;

/// A duration distribution in seconds.
#[derive(Debug, Clone)]
pub enum Distribution {
    /// exp(N(mu, sigma)), clamped to [min_s, max_s].
    LogNormal {
        /// Mean of the underlying normal.
        mu: f64,
        /// Std-dev of the underlying normal.
        sigma: f64,
        /// Lower clamp (seconds).
        min_s: f64,
        /// Upper clamp (seconds).
        max_s: f64,
    },
    /// Uniform in [lo, hi).
    Uniform {
        /// Inclusive lower bound (seconds).
        lo: f64,
        /// Exclusive upper bound (seconds).
        hi: f64,
    },
    /// Weighted mixture of components.
    Mixture(Vec<(f64, Distribution)>),
}

impl Distribution {
    /// Draw one duration (seconds).
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match self {
            Distribution::LogNormal {
                mu,
                sigma,
                min_s,
                max_s,
            } => rng.lognormal(*mu, *sigma).clamp(*min_s, *max_s),
            Distribution::Uniform { lo, hi } => rng.range_f64(*lo, *hi),
            Distribution::Mixture(parts) => {
                let weights: Vec<f64> = parts.iter().map(|(w, _)| *w).collect();
                let idx = rng.weighted(&weights);
                parts[idx].1.sample(rng)
            }
        }
    }

    /// Draw `n` samples.
    pub fn sample_n(&self, rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.sample(rng)).collect()
    }
}

/// Histogram over fixed duration buckets, for Fig. 1-style reports.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Bucket upper edges (seconds); the last bucket is open-ended.
    pub edges: Vec<f64>,
    /// Per-bucket sample counts (len = edges.len() + 1).
    pub counts: Vec<usize>,
    /// Total samples added.
    pub total: usize,
}

impl Histogram {
    /// Empty histogram over the given bucket edges.
    pub fn new(edges: Vec<f64>) -> Self {
        let n = edges.len() + 1;
        Histogram {
            edges,
            counts: vec![0; n],
            total: 0,
        }
    }

    /// The paper's Fig. 1 buckets: 0-2, 2-4, 4-8, 8-16, 16-32, 32-64, 64+.
    pub fn fig1_buckets() -> Self {
        Histogram::new(vec![2.0, 4.0, 8.0, 16.0, 32.0, 64.0])
    }

    /// Count one sample into its bucket.
    pub fn add(&mut self, x: f64) {
        let idx = self
            .edges
            .iter()
            .position(|&e| x < e)
            .unwrap_or(self.edges.len());
        self.counts[idx] += 1;
        self.total += 1;
    }

    /// Count a batch of samples.
    pub fn add_all(&mut self, xs: &[f64]) {
        for &x in xs {
            self.add(x);
        }
    }

    /// Fraction of mass in each bucket.
    pub fn fractions(&self) -> Vec<f64> {
        if self.total == 0 {
            return vec![0.0; self.counts.len()];
        }
        self.counts
            .iter()
            .map(|&c| c as f64 / self.total as f64)
            .collect()
    }

    /// Bucket label strings ("0-2s", ..., ">64s").
    pub fn labels(&self) -> Vec<String> {
        let mut labels = Vec::with_capacity(self.counts.len());
        let mut lo = 0.0;
        for &e in &self.edges {
            labels.push(format!("{lo:.0}-{e:.0}s"));
            lo = e;
        }
        labels.push(format!(">{lo:.0}s"));
        labels
    }
}

/// Skewness diagnostic used in reports: mean / median. ≫ 1 ⇒ long tail.
pub fn tail_ratio(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let med = crate::util::stats::median(xs);
    if med == 0.0 {
        1.0
    } else {
        mean / med
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lognormal_clamped() {
        let d = Distribution::LogNormal {
            mu: 2.0,
            sigma: 1.0,
            min_s: 1.0,
            max_s: 30.0,
        };
        let mut rng = Rng::new(1);
        for x in d.sample_n(&mut rng, 5000) {
            assert!((1.0..=30.0).contains(&x));
        }
    }

    #[test]
    fn mixture_hits_both_components() {
        let d = Distribution::Mixture(vec![
            (
                0.5,
                Distribution::Uniform { lo: 0.0, hi: 1.0 },
            ),
            (
                0.5,
                Distribution::Uniform {
                    lo: 100.0,
                    hi: 101.0,
                },
            ),
        ]);
        let mut rng = Rng::new(2);
        let xs = d.sample_n(&mut rng, 2000);
        let low = xs.iter().filter(|&&x| x < 1.0).count();
        let high = xs.iter().filter(|&&x| x > 100.0).count();
        assert!(low > 800 && high > 800, "low={low} high={high}");
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Histogram::fig1_buckets();
        h.add_all(&[1.0, 3.0, 5.0, 9.0, 20.0, 40.0, 100.0]);
        assert_eq!(h.counts, vec![1, 1, 1, 1, 1, 1, 1]);
        assert_eq!(h.total, 7);
        assert_eq!(h.labels().len(), 7);
        assert_eq!(h.labels()[0], "0-2s");
        assert_eq!(h.labels()[6], ">64s");
    }

    #[test]
    fn fractions_sum_to_one() {
        let mut h = Histogram::fig1_buckets();
        let d = Distribution::LogNormal {
            mu: 1.5,
            sigma: 1.2,
            min_s: 0.5,
            max_s: 256.0,
        };
        let mut rng = Rng::new(3);
        h.add_all(&d.sample_n(&mut rng, 10_000));
        let sum: f64 = h.fractions().iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn lognormal_is_long_tailed() {
        let d = Distribution::LogNormal {
            mu: 1.5,
            sigma: 1.2,
            min_s: 0.5,
            max_s: 512.0,
        };
        let mut rng = Rng::new(4);
        let xs = d.sample_n(&mut rng, 20_000);
        assert!(tail_ratio(&xs) > 1.3, "tail ratio {}", tail_ratio(&xs));
    }
}
