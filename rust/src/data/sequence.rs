//! The scheduling unit: one interleaved multimodal training sequence.

/// One training sequence: interleaved vision tokens (full attention inside
/// the vision encoder → the paper's η mask-efficiency surcharge) and text
/// tokens (causal attention).
#[derive(Debug, Clone, PartialEq)]
pub struct Sequence {
    /// Stable sample id (sampler-assigned).
    pub id: u64,
    /// Vision tokens (video frames × patches, or image patches).
    pub vision_tokens: u64,
    /// Text tokens.
    pub text_tokens: u64,
    /// Source video duration in seconds (0 for image/text-only): kept for
    /// the Fig. 1 distribution reports.
    pub duration_s: f64,
}

impl Sequence {
    /// A sequence with the given modality token counts (duration 0).
    pub fn new(id: u64, vision_tokens: u64, text_tokens: u64) -> Self {
        Sequence {
            id,
            vision_tokens,
            text_tokens,
            duration_s: 0.0,
        }
    }

    /// |s_k| in the paper: total context length.
    pub fn len(&self) -> u64 {
        self.vision_tokens + self.text_tokens
    }

    /// True when the sequence has no tokens at all.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The paper's mask-efficiency factor η_k (Eq. 8), determined by the
    /// shape of the attention mask. The causal LM costs α₁·|s|²
    /// (the causal half is already folded into α₁); the vision encoder
    /// additionally runs FULL attention over the |v| vision tokens, which
    /// costs 2× per token pair. Expressing the total as
    /// α₁·(1 + η)·|s|² gives η = 2·(|v|/|s|)².
    pub fn eta(&self) -> f64 {
        let l = self.len();
        if l == 0 {
            return 0.0;
        }
        let fv = self.vision_tokens as f64 / l as f64;
        2.0 * fv * fv
    }

    /// Activation memory footprint in bytes for a model with the given
    /// per-token activation cost (Eq. 7's |s_k|·M_token term).
    pub fn act_bytes(&self, m_token: f64) -> f64 {
        self.len() as f64 * m_token
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn length_and_eta() {
        let s = Sequence::new(0, 300, 100);
        assert_eq!(s.len(), 400);
        let fv: f64 = 0.75;
        assert!((s.eta() - 2.0 * fv * fv).abs() < 1e-12);
    }

    #[test]
    fn eta_bounds() {
        // Text-only: no full-attention surcharge.
        assert_eq!(Sequence::new(0, 0, 128).eta(), 0.0);
        // Vision-only: maximal surcharge of 2×.
        assert!((Sequence::new(0, 128, 0).eta() - 2.0).abs() < 1e-12);
        // Empty: defined as 0.
        assert_eq!(Sequence::new(0, 0, 0).eta(), 0.0);
    }

    #[test]
    fn act_bytes_linear_in_tokens() {
        let s = Sequence::new(1, 100, 100);
        assert_eq!(s.act_bytes(10.0), 2000.0);
    }
}
