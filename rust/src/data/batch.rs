//! Global-batch and micro-batch structures + the micro-batch planner
//! (step 1 of the paper's workflow, Fig. 3).

use super::sequence::Sequence;

/// One optimizer step's worth of sequences (paper: GBS = 512).
#[derive(Debug, Clone)]
pub struct GlobalBatch {
    /// Optimizer step this batch belongs to.
    pub step: u64,
    /// The batch's sequences in arrival order.
    pub sequences: Vec<Sequence>,
}

impl GlobalBatch {
    /// Total tokens across the batch.
    pub fn total_tokens(&self) -> u64 {
        self.sequences.iter().map(|s| s.len()).sum()
    }
}

/// One scheduling unit handed to the DHP scheduler: a subset of the global
/// batch whose memory demand fits the cluster in a single wave.
#[derive(Debug, Clone)]
pub struct MicroBatch {
    /// Position within the parent global batch.
    pub index: usize,
    /// The micro-batch's sequences (order preserved from the batch).
    pub sequences: Vec<Sequence>,
}

impl MicroBatch {
    /// Total tokens across the micro-batch.
    pub fn total_tokens(&self) -> u64 {
        self.sequences.iter().map(|s| s.len()).sum()
    }
}

/// Splits a global batch into micro-batches that each fit cluster memory
/// (paper Fig. 3 step 1).
#[derive(Debug, Clone)]
pub struct MicroBatchPlanner {
    /// Model replicas in the cluster (paper's N).
    pub replicas: usize,
    /// Usable activation bytes per rank (E − M_ms in Eq. 3/7).
    pub rank_act_budget: f64,
    /// Activation bytes per token (M_token).
    pub m_token: f64,
    /// Fill fraction: target at most this share of cluster memory per
    /// micro-batch so the packer has headroom (default 0.9).
    pub fill: f64,
}

impl MicroBatchPlanner {
    /// Planner for `replicas` ranks at the given per-rank activation
    /// budget, with the default 0.9 fill fraction.
    pub fn new(replicas: usize, rank_act_budget: f64, m_token: f64) -> Self {
        MicroBatchPlanner {
            replicas,
            rank_act_budget,
            m_token,
            fill: 0.9,
        }
    }

    /// Cluster-wide activation capacity targeted per micro-batch.
    pub fn capacity_bytes(&self) -> f64 {
        self.replicas as f64 * self.rank_act_budget * self.fill
    }

    /// Chunk `batch` into feasible micro-batches.
    ///
    /// Greedy first-fit in arrival order (preserving data order matters
    /// for training semantics); any sequence too large for even a whole
    /// dedicated wave is still emitted alone — the packer will then clamp
    /// its CP degree to N and rely on the memory constraint check.
    pub fn plan(&self, batch: &GlobalBatch) -> Vec<MicroBatch> {
        let cap = self.capacity_bytes();
        let mut out: Vec<MicroBatch> = Vec::new();
        let mut current: Vec<Sequence> = Vec::new();
        let mut used = 0.0;
        for seq in &batch.sequences {
            let need = seq.act_bytes(self.m_token);
            if !current.is_empty() && used + need > cap {
                out.push(MicroBatch {
                    index: out.len(),
                    sequences: std::mem::take(&mut current),
                });
                used = 0.0;
            }
            used += need;
            current.push(seq.clone());
        }
        if !current.is_empty() {
            out.push(MicroBatch {
                index: out.len(),
                sequences: current,
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets::{DatasetKind, DatasetSampler};

    fn gb(seqs: Vec<Sequence>) -> GlobalBatch {
        GlobalBatch {
            step: 0,
            sequences: seqs,
        }
    }

    #[test]
    fn single_small_batch_stays_whole() {
        let planner = MicroBatchPlanner::new(8, 1e9, 1e3);
        let batch = gb((0..10).map(|i| Sequence::new(i, 100, 100)).collect());
        let mbs = planner.plan(&batch);
        assert_eq!(mbs.len(), 1);
        assert_eq!(mbs[0].sequences.len(), 10);
    }

    #[test]
    fn splits_when_over_capacity() {
        // Capacity: 2 ranks × 1000 bytes × 0.9 = 1800; each seq = 1000.
        let planner = MicroBatchPlanner::new(2, 1000.0, 1.0);
        let batch = gb((0..5).map(|i| Sequence::new(i, 500, 500)).collect());
        let mbs = planner.plan(&batch);
        assert_eq!(mbs.len(), 5); // one per micro-batch: 2×1000 > 1800
        for (i, mb) in mbs.iter().enumerate() {
            assert_eq!(mb.index, i);
        }
    }

    #[test]
    fn all_sequences_preserved_in_order() {
        let planner = MicroBatchPlanner::new(4, 1e6, 100.0);
        let mut sampler = DatasetSampler::new(DatasetKind::OpenVid, 11);
        let batch = gb(sampler.sample_batch(128));
        let mbs = planner.plan(&batch);
        let flat: Vec<u64> = mbs
            .iter()
            .flat_map(|mb| mb.sequences.iter().map(|s| s.id))
            .collect();
        let orig: Vec<u64> = batch.sequences.iter().map(|s| s.id).collect();
        assert_eq!(flat, orig);
    }

    #[test]
    fn each_microbatch_fits_capacity_unless_singleton() {
        let planner = MicroBatchPlanner::new(8, 64.0 * 1024.0, 16.0);
        let mut sampler = DatasetSampler::new(DatasetKind::OpenVid, 13);
        let batch = gb(sampler.sample_batch(256));
        for mb in planner.plan(&batch) {
            let bytes: f64 = mb
                .sequences
                .iter()
                .map(|s| s.act_bytes(planner.m_token))
                .sum();
            assert!(
                bytes <= planner.capacity_bytes() || mb.sequences.len() == 1,
                "over-capacity micro-batch with {} seqs",
                mb.sequences.len()
            );
        }
    }

    #[test]
    fn oversized_sequence_emitted_alone() {
        let planner = MicroBatchPlanner::new(2, 100.0, 1.0);
        let batch = gb(vec![
            Sequence::new(0, 50, 0),
            Sequence::new(1, 100_000, 0), // way over any capacity
            Sequence::new(2, 50, 0),
        ]);
        let mbs = planner.plan(&batch);
        assert!(mbs.iter().any(|mb| mb.sequences.len() == 1
            && mb.sequences[0].id == 1));
    }
}
