//! The within-step discrete-event execution path of [`DhpSession`]
//! (builder opt-in [`super::SessionBuilder::within_step_faults`]).
//!
//! The step-granular reference path (`execute_iteration_overlapped`)
//! executes a step as an opaque span and applies faults at the next
//! boundary, charging a failure the whole `work_since_ckpt` replay.
//! This module replays the SAME execution as a discrete-event timeline —
//! wave start/finish per placed plan, fault arrivals at hash-derived
//! virtual times, an overlapped checkpoint write window, gradient sync —
//! so a `RankFailure` at virtual time `t` interrupts exactly the wave
//! in flight, re-executes only that wave on its survivor plan
//! ([`crate::cluster::ClusterSim::survivor_plan`]), and charges lost
//! work as `t − wave_start`. Completed waves and steps persist in
//! sharded survivor state (the MegaScale-style wave-commit model), so
//! they are never replayed — the source of the strictly-smaller lost
//! work this PR's acceptance regression pins down.
//!
//! Bit-identity with the reference under a quiet injector is BY
//! CONSTRUCTION: the kernel replicates the reference path's pool
//! acquisition order (all of a micro-batch's waves acquired, in wave
//! order, before its first wave executes), its `exec += makespan` fold
//! order, and its reconfiguration measurement (pool create-time delta),
//! and performs no extra arithmetic on the quiet path. The differential
//! property test in `tests/property_invariants.rs` enforces it.

use crate::cluster::{
    EventKind, EventQueue, EventTimeline, FaultEvent, IterationReport,
    TimedFault, WaveReport,
};
use crate::data::sequence::Sequence;
use crate::parallel::group::GROUP_CREATE_COST_S;
use crate::parallel::RankId;
use crate::scheduler::{PlacedPlan, Schedule};

use super::DhpSession;

/// What one within-step execution produced, beyond the iteration report
/// itself: the virtual-time event log, the recovery wall charge accrued
/// at fault arrivals, whether a checkpoint write was torn (and must be
/// re-issued), and whether any rank failure landed (which zeroes the
/// prewarm-overlap budget, as on the boundary path).
pub(super) struct WithinStepOutcome {
    /// The executed iteration (reconfig fields pre-recharge, exactly as
    /// `execute_iteration_overlapped` returns them).
    pub(super) iteration: IterationReport,
    /// Every event the kernel processed or synthesized, in pop order.
    pub(super) timeline: EventTimeline,
    /// Restore + re-warm stalls + re-done partial work, charged into the
    /// step's `recovery_time_s`.
    pub(super) recovery_s: f64,
    /// `Some(id)` when a failure tore the in-flight checkpoint write:
    /// the session re-issues that save after this step.
    pub(super) torn_ckpt: Option<u64>,
    /// A `RankFailure` was applied (mesh shrank and state was restored).
    pub(super) had_failure: bool,
}

/// The wave currently executing on the virtual timeline.
struct InFlight {
    mb: usize,
    wave: usize,
    start_s: f64,
    finish_seq: u64,
    report: WaveReport,
}

impl DhpSession {
    /// Execute one scheduled step through the discrete-event kernel.
    /// `timed` are this step's injector draws with hash-derived arrival
    /// fractions ([`crate::cluster::FaultInjector::advance_timed`]),
    /// mapped onto the quiet nominal span of the step.
    pub(super) fn execute_within_step(
        &mut self,
        scheduled: &[(Vec<Sequence>, Schedule)],
        timed: &[TimedFault],
    ) -> WithinStepOutcome {
        let reconfig_before = self.mpu.pool_stats().create_time_s;
        // Live plans: start as the schedule's placed plans, re-placed by
        // survivor_plan when a mid-step fault kills ranks they use.
        let mut live: Vec<Vec<PlacedPlan>> = scheduled
            .iter()
            .map(|(_, s)| s.waves.clone())
            .collect();
        let order: Vec<(usize, usize)> = scheduled
            .iter()
            .enumerate()
            .flat_map(|(mi, (_, s))| (0..s.waves.len()).map(move |wi| (mi, wi)))
            .collect();
        let tokens: u64 = scheduled
            .iter()
            .map(|(seqs, _)| seqs.iter().map(|s| s.len()).sum::<u64>())
            .sum();

        let mut queue = EventQueue::new();
        let mut timeline = EventTimeline::new();

        // A checkpoint save issued at the previous step's cadence
        // physically writes during THIS step's virtual timeline.
        let mut window: Option<(u64, u64)> = None; // (id, end event seq)
        if let Some((id, write_s)) = self.pending_ckpt_write.take() {
            queue.push(0.0, EventKind::CkptBegin { id });
            let end_seq = queue.push(write_s, EventKind::CkptEnd { id });
            window = Some((id, end_seq));
        }

        // Map arrival fractions onto the quiet nominal span. Computed
        // only when faults are pending, so the quiet path performs no
        // extra execute_plan calls (cost parity with the reference).
        if !timed.is_empty() {
            let mut nominal = self.sim.grad_sync_time();
            for &(mi, wi) in &order {
                nominal += self
                    .sim
                    .execute_plan(&scheduled[mi].0, &live[mi][wi], self.comm)
                    .makespan_s;
            }
            for t in timed {
                queue.push(
                    t.at_frac * nominal,
                    EventKind::FaultArrival(t.event.clone()),
                );
            }
        }

        if let Some(&(mi, wi)) = order.first() {
            queue.push(0.0, EventKind::WaveStart { mb: mi, wave: wi });
        } else {
            let span = self.sim.grad_sync_time();
            queue.push(0.0, EventKind::GradSync { span_s: span });
        }

        let mut in_flight: Option<InFlight> = None;
        let mut acquired_mb = 0usize;
        let mut pos = 0usize;
        let (mut exec, mut straggle) = (0.0f64, 0.0f64);
        let mut waves: Vec<WaveReport> = Vec::new();
        let (mut lost, mut recovery) = (0.0f64, 0.0f64);
        let mut interrupted = 0usize;
        let mut torn_ckpt: Option<u64> = None;
        let mut had_failure = false;

        while let Some(rec) = queue.pop() {
            let now = rec.time_s;
            timeline.log(rec.time_s, rec.seq, rec.kind.clone());
            match rec.kind {
                EventKind::WaveStart { mb, wave } => {
                    if mb == acquired_mb {
                        // First wave of this micro-batch starting:
                        // refresh every wave against the (possibly
                        // shrunken) mesh FIRST — acquiring a dead-rank
                        // plan would re-create invalidated groups — then
                        // acquire the whole micro-batch's groups in wave
                        // order. Quiet, this is byte-for-byte the
                        // reference path's acquisition pattern.
                        for plan in live[mb].iter_mut() {
                            if let Some(new) = self.sim.survivor_plan(plan) {
                                *plan = new;
                            }
                        }
                        for plan in &live[mb] {
                            self.mpu.pool_mut().acquire_wave(
                                plan.groups.iter().map(|g| g.pool_key()),
                            );
                        }
                        acquired_mb += 1;
                    } else if let Some(new) =
                        self.sim.survivor_plan(&live[mb][wave])
                    {
                        // A fault since this micro-batch's acquisition
                        // killed ranks this wave uses: re-place and
                        // establish the survivor groups (a charged pool
                        // miss — honest re-creation) before executing.
                        self.mpu.pool_mut().acquire_wave(
                            new.groups.iter().map(|g| g.pool_key()),
                        );
                        live[mb][wave] = new;
                    }
                    let report = self.sim.execute_plan(
                        &scheduled[mb].0,
                        &live[mb][wave],
                        self.comm,
                    );
                    let finish_seq = queue.push(
                        now + report.makespan_s,
                        EventKind::WaveFinish {
                            mb,
                            wave,
                            makespan_s: report.makespan_s,
                        },
                    );
                    in_flight = Some(InFlight {
                        mb,
                        wave,
                        start_s: now,
                        finish_seq,
                        report,
                    });
                }
                EventKind::WaveFinish { .. } => {
                    let fl = in_flight
                        .take()
                        .expect("wave finish without an in-flight wave");
                    exec += fl.report.makespan_s;
                    straggle += fl.report.straggle_s;
                    waves.push(fl.report);
                    pos += 1;
                    if let Some(&(mi, wi)) = order.get(pos) {
                        queue.push(
                            now,
                            EventKind::WaveStart { mb: mi, wave: wi },
                        );
                    } else {
                        let span = self.sim.grad_sync_time();
                        queue.push(now, EventKind::GradSync { span_s: span });
                    }
                }
                EventKind::FaultArrival(ev) => {
                    let (taken, stall, was_failure) =
                        self.apply_fault_state(&ev);
                    had_failure |= was_failure;
                    recovery += stall;
                    if was_failure {
                        // The failed rank's checkpoint shard dies with
                        // it: the in-flight write can never complete, so
                        // any restore falls back to the previous
                        // COMPLETED checkpoint and the partial write is
                        // wasted wall.
                        if let Some((id, end_seq)) = window.take() {
                            queue.cancel(end_seq);
                            let seq = queue.alloc_seq();
                            timeline.log(
                                now,
                                seq,
                                EventKind::CkptTorn {
                                    id,
                                    restore_from: self.last_ckpt_done,
                                    lost_write_s: now,
                                },
                            );
                            lost += now;
                            recovery += now;
                            torn_ckpt = Some(id);
                        }
                    }
                    // Interrupt the in-flight wave iff the fault took
                    // ranks it is executing on; unrelated repair runs
                    // asynchronously and does not displace the timeline.
                    let hit = in_flight.as_ref().is_some_and(|fl| {
                        live[fl.mb][fl.wave].groups.iter().any(|g| {
                            g.ranks.iter().any(|r| taken.contains(r))
                        })
                    });
                    if hit {
                        let fl = in_flight.take().expect("hit checked Some");
                        queue.cancel(fl.finish_seq);
                        let lost_w = now - fl.start_s;
                        lost += lost_w;
                        // The discarded partial run is wall the cluster
                        // actually spent: charge it (plus the stall)
                        // into recovery, mirroring how the boundary path
                        // charges replayed work.
                        recovery += lost_w;
                        interrupted += 1;
                        let seq = queue.alloc_seq();
                        timeline.log(
                            now,
                            seq,
                            EventKind::WaveInterrupted {
                                mb: fl.mb,
                                wave: fl.wave,
                                lost_s: lost_w,
                            },
                        );
                        let seq = queue.alloc_seq();
                        timeline.log(
                            now,
                            seq,
                            EventKind::RecoveryStall { stall_s: stall },
                        );
                        queue.push(
                            now + stall,
                            EventKind::WaveStart {
                                mb: fl.mb,
                                wave: fl.wave,
                            },
                        );
                    }
                }
                EventKind::CkptEnd { id } => {
                    self.last_ckpt_done = Some(id);
                    window = None;
                }
                // Already logged above; no state transition.
                EventKind::CkptBegin { .. }
                | EventKind::GradSync { .. }
                | EventKind::WaveInterrupted { .. }
                | EventKind::RecoveryStall { .. }
                | EventKind::CkptTorn { .. } => {}
            }
        }

        let reconfig_serial =
            self.mpu.pool_stats().create_time_s - reconfig_before;
        let grad_sync = self.sim.grad_sync_time();
        let iteration = IterationReport {
            waves,
            exec_time_s: exec,
            grad_sync_s: grad_sync,
            reconfig_time_s: reconfig_serial,
            reconfig_serial_s: reconfig_serial,
            iter_time_s: exec + grad_sync + reconfig_serial,
            straggle_s: straggle,
            tokens,
            lost_work_s: lost,
            interrupted_waves: interrupted,
        };
        WithinStepOutcome {
            iteration,
            timeline,
            recovery_s: recovery,
            torn_ckpt,
            had_failure,
        }
    }

    /// Apply one fault's STATE transition (mesh shrink/re-admit, pool
    /// invalidation, fencing, slowdown install) and return
    /// `(ranks taken, stall seconds, was a rank failure)`. Shared by the
    /// event kernel (which applies it at the arrival instant) and the
    /// degenerate failed-step path (which applies it at t = 0). The
    /// transitions mirror the boundary path's `apply_faults` exactly,
    /// EXCEPT that a failure does not replay `work_since_ckpt`:
    /// wave-commit semantics keep completed work alive in sharded
    /// survivor state, so only restore + re-warm stall here (the
    /// interrupted partial wave is charged by the caller).
    fn apply_fault_state(
        &mut self,
        ev: &FaultEvent,
    ) -> (Vec<RankId>, f64, bool) {
        let mut taken: Vec<RankId> = Vec::new();
        let mut stall = 0.0f64;
        let mut was_failure = false;
        match ev {
            FaultEvent::Recovery { ranks } => {
                let back: Vec<RankId> = ranks
                    .iter()
                    .copied()
                    .filter(|&r| {
                        self.downed.remove(&r)
                            && !self.mpu.mesh.is_rank_free(r)
                    })
                    .collect();
                if !back.is_empty() {
                    self.commit_occupancy(&[], &back);
                }
            }
            FaultEvent::RankFailure { rank } => {
                if self.take_down(*rank) {
                    let torn = self.commit_occupancy(&[*rank], &[]);
                    self.downed.insert(*rank);
                    taken.push(*rank);
                    was_failure = true;
                    stall += self.ckpt_cost.restore_time_s()
                        + torn as f64 * GROUP_CREATE_COST_S;
                }
            }
            FaultEvent::Preemption { ranks, .. } => {
                for &r in ranks {
                    if self.take_down(r) {
                        let torn = self.commit_occupancy(&[r], &[]);
                        self.downed.insert(r);
                        taken.push(r);
                        stall += torn as f64 * GROUP_CREATE_COST_S;
                    }
                }
            }
            FaultEvent::Straggler { rank, slowdown } => {
                let r = *rank;
                if r < self.mpu.mesh.replicas && self.mpu.mesh.is_rank_free(r)
                {
                    self.straggle_counts[r] += 1;
                    let chronic = match self.fence_threshold {
                        Some(t) => self.straggle_counts[r] >= t,
                        None => false,
                    };
                    if chronic && self.mpu.mesh.free_replicas() > 1 {
                        let torn = self.commit_occupancy(&[r], &[]);
                        self.fenced.insert(r);
                        taken.push(r);
                        stall += torn as f64 * GROUP_CREATE_COST_S;
                    } else {
                        // Installed mid-step: stretches waves that START
                        // after this instant (in-flight waves committed
                        // their makespan at start).
                        self.sim.set_slowdown(r, *slowdown);
                    }
                }
            }
        }
        (taken, stall, was_failure)
    }

    /// Failed-step fallback: nothing executes, so there is no virtual
    /// timeline to land the faults on — apply each one's state change at
    /// t = 0 (arrival order) so the next solve sees the post-fault mesh
    /// and the restore/re-warm stalls are not lost. An open checkpoint
    /// write window is left pending (the write makes no progress while
    /// nothing executes). Returns the (arrivals-only) timeline and the
    /// recovery charge.
    pub(super) fn apply_timed_faults_degenerate(
        &mut self,
        timed: &[TimedFault],
    ) -> (EventTimeline, f64) {
        let mut timeline = EventTimeline::new();
        let mut queue = EventQueue::new(); // seq allocator only
        let mut recovery = 0.0f64;
        for t in timed {
            let seq = queue.alloc_seq();
            timeline.log(0.0, seq, EventKind::FaultArrival(t.event.clone()));
            let (_taken, stall, _was_failure) =
                self.apply_fault_state(&t.event);
            recovery += stall;
        }
        (timeline, recovery)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{DhpSession, SessionBuilder, StepReport};
    use crate::cluster::{
        ClusterSim, EventKind, FaultConfig, FaultEvent, FaultInjector,
        TimedFault,
    };
    use crate::config::presets::by_name;
    use crate::config::{ClusterConfig, TrainStage};
    use crate::cost::{CostCoeffs, CostModel, HardwareSpec, MemoryModel};
    use crate::data::datasets::{DatasetKind, DatasetSampler, TokenizerSpec};
    use crate::scheduler::Scheduler;
    use crate::train::CheckpointCostModel;

    /// High-res video tokenization (matches the session tests' regime).
    fn sampler(kind: DatasetKind, seed: u64) -> DatasetSampler {
        DatasetSampler::new(kind, seed).with_spec(TokenizerSpec {
            fps: 2.0,
            tokens_per_frame: 256.0,
            text_min: 32,
            text_max: 512,
        })
    }

    /// Paper regime: one replica = TP×PP = 4 NPUs, 2 replicas/node.
    fn paper_regime(replicas: usize) -> (CostModel, ClusterConfig) {
        let mut cluster = ClusterConfig::default().with_npus(replicas * 4);
        cluster.tp = 2;
        cluster.pp = 2;
        let preset = by_name("InternVL3-8B").unwrap();
        let hw = HardwareSpec {
            peak_flops: 376e12 * 4.0,
            ..HardwareSpec::default()
        };
        let cost = CostModel {
            coeffs: CostCoeffs::analytic(&preset, TrainStage::Full, &hw),
            memory: MemoryModel {
                e_bytes: 8192.0 * preset.act_bytes_per_token() + 2e9,
                m_states: 2e9,
                m_token: preset.act_bytes_per_token(),
            },
        };
        (cost, cluster)
    }

    fn dhp_builder(replicas: usize) -> SessionBuilder {
        let (cost, cluster) = paper_regime(replicas);
        let preset = by_name("InternVL3-8B").unwrap();
        let scheduler =
            Scheduler::new(cost, crate::parallel::DeviceMesh::new(&cluster));
        let sim = ClusterSim::new(preset, TrainStage::Full, cluster);
        DhpSession::builder(Box::new(scheduler), sim)
    }

    fn batches(n: usize, gbs: usize, seed: u64) -> Vec<Vec<crate::data::sequence::Sequence>> {
        let mut s = sampler(DatasetKind::OpenVid, seed);
        (0..n).map(|_| s.sample_batch(gbs)).collect()
    }

    fn digests(reports: &[StepReport]) -> Vec<u64> {
        reports.iter().map(|r| r.digest()).collect()
    }

    #[test]
    fn quiet_within_step_is_bit_identical_to_step_granular() {
        // The backbone invariant: a quiet injector through the event
        // kernel reproduces the step-granular path's digests bit for
        // bit — makespan, reconfig charging, pool counters, everything.
        let bats = batches(4, 24, 0xD1FF);
        let quiet = FaultInjector::new(8, FaultConfig::quiet(7));
        let mut ev = dhp_builder(8)
            .fault_injector(quiet.clone())
            .within_step_faults(true)
            .build();
        let mut refr = dhp_builder(8).fault_injector(quiet).build();
        for b in &bats {
            let re = ev.step(b);
            let rr = refr.step(b);
            assert!(
                !re.timeline.is_empty(),
                "event kernel must log the quiet timeline"
            );
            assert!(rr.timeline.is_empty(), "reference logs no timeline");
            assert_eq!(re.iteration.interrupted_waves, 0);
            assert_eq!(re.lost_work_s, 0.0);
            assert_eq!(
                re.digest(),
                rr.digest(),
                "quiet event kernel drifted from the reference at step {}",
                re.step
            );
        }
    }

    #[test]
    fn golden_replay_same_trace_and_permuted_trace_match() {
        // Deterministic replay: same seed + same scripted trace ⇒
        // identical serialized event logs and digest sequences across
        // fresh sessions; a permuted-but-equal-time trace also matches
        // (the queue's (time, seq) tie-break + canonical arrival sort).
        let a = TimedFault {
            at_frac: 0.4,
            event: FaultEvent::RankFailure { rank: 2 },
        };
        let b = TimedFault {
            at_frac: 0.4,
            event: FaultEvent::Straggler { rank: 5, slowdown: 1.8 },
        };
        let trace = vec![vec![], vec![a.clone(), b.clone()], vec![]];
        let permuted = vec![vec![], vec![b, a], vec![]];
        let bats = batches(3, 24, 0x601D);
        let run = |trace: Vec<Vec<TimedFault>>| {
            let mut s = dhp_builder(8)
                .fault_injector(FaultInjector::scripted_timed(8, trace))
                .within_step_faults(true)
                .build();
            let reports: Vec<StepReport> =
                bats.iter().map(|b| s.step(b)).collect();
            let logs: Vec<String> = reports
                .iter()
                .map(|r| r.timeline.to_json().to_string_pretty())
                .collect();
            (digests(&reports), logs)
        };
        let (d1, l1) = run(trace.clone());
        let (d2, l2) = run(trace);
        let (d3, l3) = run(permuted);
        assert_eq!(d1, d2, "same trace must replay bit-identically");
        assert_eq!(l1, l2, "same trace must serialize identically");
        assert_eq!(d1, d3, "equal-time permutation must not change digests");
        assert_eq!(l1, l3, "equal-time permutation must not change the log");
    }

    #[test]
    fn mid_wave_failure_charges_strictly_less_than_boundary_replay() {
        // THE acceptance regression: on the same scripted trace, the
        // event kernel's partial-wave charge must be strictly below the
        // PR 6 whole-step `work_since_ckpt` replay.
        let trace = vec![
            vec![],
            vec![TimedFault {
                at_frac: 0.45,
                event: FaultEvent::RankFailure { rank: 2 },
            }],
        ];
        let bats = batches(2, 24, 0xACCE);
        let mut ev = dhp_builder(8)
            .fault_injector(FaultInjector::scripted_timed(8, trace.clone()))
            .within_step_faults(true)
            .build();
        let mut bd = dhp_builder(8)
            .fault_injector(FaultInjector::scripted_timed(8, trace))
            .build();
        let ev_reports: Vec<StepReport> = bats.iter().map(|b| ev.step(b)).collect();
        let bd_reports: Vec<StepReport> = bats.iter().map(|b| bd.step(b)).collect();
        // Both saw the same fault set on their step-1 report.
        assert_eq!(ev_reports[1].faults, bd_reports[1].faults);
        let ev_lost = ev_reports[1].lost_work_s;
        let bd_lost = bd_reports[1].lost_work_s;
        assert!(bd_lost > 0.0, "boundary mode must replay work since ckpt");
        assert!(ev_lost > 0.0, "a mid-wave kill must lose the partial wave");
        assert!(
            ev_lost < bd_lost,
            "partial-wave charge ({ev_lost}) must be strictly below the \
             whole-step replay ({bd_lost})"
        );
        // And the event kernel actually interrupted a wave mid-flight.
        assert!(ev_reports[1].iteration.interrupted_waves >= 1);
        assert!(ev_reports[1]
            .timeline
            .records()
            .iter()
            .any(|r| matches!(r.kind, EventKind::WaveInterrupted { .. })));
        // Both modes still make progress afterwards (mesh shrank by 1).
        assert_eq!(ev.downed_ranks(), vec![2]);
        assert_eq!(bd.downed_ranks(), vec![2]);
    }

    #[test]
    fn recovery_at_same_instant_as_failure_is_deterministic() {
        // Edge: a preemption's repair (Recovery) expiring the same
        // virtual instant a failure lands. Canonical equal-time ordering
        // makes the outcome a pure function of the trace content.
        let p = TimedFault {
            at_frac: 0.2,
            event: FaultEvent::Preemption { ranks: vec![3], duration_steps: 1 },
        };
        let same_t_recover = TimedFault {
            at_frac: 0.6,
            event: FaultEvent::Recovery { ranks: vec![3] },
        };
        let same_t_fail = TimedFault {
            at_frac: 0.6,
            event: FaultEvent::RankFailure { rank: 1 },
        };
        let trace = vec![
            vec![p],
            vec![same_t_recover.clone(), same_t_fail.clone()],
            vec![],
        ];
        let permuted_step: Vec<TimedFault> = vec![same_t_fail, same_t_recover];
        let bats = batches(3, 24, 0x7155);
        let run = |t1: Vec<TimedFault>| {
            let mut s = dhp_builder(8)
                .fault_injector(FaultInjector::scripted_timed(
                    8,
                    vec![trace[0].clone(), t1, vec![]],
                ))
                .within_step_faults(true)
                .build();
            let reports: Vec<StepReport> =
                bats.iter().map(|b| s.step(b)).collect();
            (digests(&reports), s.downed_ranks())
        };
        let (d1, down1) = run(trace[1].clone());
        let (d2, down2) = run(permuted_step);
        assert_eq!(d1, d2, "same-instant events must order canonically");
        assert_eq!(down1, down2);
        // Rank 3 recovered (preempted then repaired), rank 1 stayed down.
        assert_eq!(down1, vec![1]);
    }

    #[test]
    fn fenced_rank_is_not_readmitted_by_midwave_recovery() {
        // Edge: Recovery arriving mid-wave for a rank that was fenced as
        // a chronic straggler must NOT re-admit it.
        let slow = |frac: f64| TimedFault {
            at_frac: frac,
            event: FaultEvent::Straggler { rank: 4, slowdown: 2.5 },
        };
        let trace = vec![
            vec![slow(0.3)],
            vec![slow(0.3)], // second strike → fenced at threshold 2
            vec![TimedFault {
                at_frac: 0.5,
                event: FaultEvent::Recovery { ranks: vec![4] },
            }],
        ];
        let bats = batches(3, 24, 0xFE2C);
        let mut s = dhp_builder(8)
            .fault_injector(FaultInjector::scripted_timed(8, trace))
            .within_step_faults(true)
            .straggler_fence_threshold(2)
            .build();
        for b in &bats {
            s.step(b);
        }
        assert_eq!(s.fenced_ranks(), vec![4], "chronic straggler fenced");
        assert!(
            !s.mesh().is_rank_free(4),
            "mid-wave Recovery must not re-admit a fenced rank"
        );
        assert!(s.downed_ranks().is_empty());
    }

    #[test]
    fn back_to_back_failures_within_one_repair_window() {
        // Edge: two failures inside one step (same repair window) — the
        // second interrupts the re-executed survivor wave again; both
        // charge partial-wave lost work and the session survives.
        let trace = vec![
            vec![],
            vec![
                TimedFault {
                    at_frac: 0.3,
                    event: FaultEvent::RankFailure { rank: 1 },
                },
                TimedFault {
                    at_frac: 0.7,
                    event: FaultEvent::RankFailure { rank: 2 },
                },
            ],
        ];
        let bats = batches(2, 24, 0xB2B);
        let mut s = dhp_builder(8)
            .fault_injector(FaultInjector::scripted_timed(8, trace))
            .within_step_faults(true)
            .build();
        let r0 = s.step(&bats[0]);
        let r1 = s.step(&bats[1]);
        assert!(r0.failed.is_none() && r1.failed.is_none());
        assert_eq!(s.downed_ranks(), vec![1, 2]);
        let arrivals = r1
            .timeline
            .records()
            .iter()
            .filter(|r| matches!(r.kind, EventKind::FaultArrival(_)))
            .count();
        assert_eq!(arrivals, 2, "both failures must land on the timeline");
        assert!(r1.iteration.interrupted_waves >= 1);
        assert!(r1.lost_work_s > 0.0);
        assert!(r1.recovery_time_s > r1.lost_work_s, "restore + re-warm on top");
        // The step still commits all its work on survivor plans.
        assert!(r1.iteration.exec_time_s > 0.0);
        assert_eq!(
            r1.iteration.waves.len(),
            r1.schedules.iter().map(|s| s.waves.len()).sum::<usize>(),
            "every scheduled wave eventually commits"
        );
    }

    #[test]
    fn torn_checkpoint_restores_from_previous_completed_write() {
        // Edge: a failure lands while a checkpoint write is streaming.
        // The torn write must fall back to the PREVIOUS completed
        // checkpoint and be re-issued.
        let trace = vec![
            vec![],
            vec![],
            vec![],
            vec![],
            // Step 4: the step-3 cadence checkpoint (id 3) is writing;
            // tear it right away.
            vec![TimedFault {
                at_frac: 0.0,
                event: FaultEvent::RankFailure { rank: 2 },
            }],
            vec![],
        ];
        let bats = batches(6, 24, 0xC4B7);
        let mut s = dhp_builder(8)
            .fault_injector(FaultInjector::scripted_timed(8, trace))
            .within_step_faults(true)
            .checkpoint_interval(2)
            // A long write so the window is still open when the fault
            // lands (and spans enough of the step to be realistic).
            .checkpoint_cost(CheckpointCostModel {
                state_bytes: 96e9,
                write_bw: 40e9,
                read_bw: 40e9,
                restart_overhead_s: 5.0,
            })
            .build();
        let reports: Vec<StepReport> = bats.iter().map(|b| s.step(b)).collect();
        // Step 1 fires the cadence (2 executed steps): id 1 writes over
        // step 2 and completes; step 3 fires cadence again: id 3 writes
        // over step 4 where the failure tears it.
        let torn: Vec<&StepReport> = reports
            .iter()
            .filter(|r| {
                r.timeline
                    .records()
                    .iter()
                    .any(|e| matches!(e.kind, EventKind::CkptTorn { .. }))
            })
            .collect();
        assert_eq!(torn.len(), 1, "exactly one torn write");
        let rec = torn[0]
            .timeline
            .records()
            .iter()
            .find(|e| matches!(e.kind, EventKind::CkptTorn { .. }))
            .unwrap();
        match rec.kind {
            EventKind::CkptTorn { id, restore_from, .. } => {
                assert_eq!(id, 3, "the step-3 checkpoint tore");
                assert_eq!(
                    restore_from,
                    Some(1),
                    "restore falls back to the completed step-1 write"
                );
            }
            _ => unreachable!(),
        }
        // The torn save is re-issued: step 4 charges a save outside the
        // cadence, and the re-issued write completes during step 5.
        assert!(torn[0].checkpoint_time_s > 0.0, "re-issued save charged");
        let last = &reports[5];
        assert!(
            last.timeline.records().iter().any(
                |e| matches!(e.kind, EventKind::CkptEnd { id } if id == 3)
            ),
            "the re-issued step-3 checkpoint completes in step 5"
        );
    }

    #[test]
    fn quiet_timeline_serializes_and_orders_monotonically() {
        // The timeline is a valid, monotone event log: times never go
        // backwards, wave starts/finishes alternate per position, and
        // the JSON serialization round-trips through util/json.
        let bats = batches(1, 24, 0x0DE2);
        let mut s = dhp_builder(8)
            .fault_injector(FaultInjector::new(8, FaultConfig::quiet(7)))
            .within_step_faults(true)
            .build();
        let r = s.step(&bats[0]);
        let recs = r.timeline.records();
        assert!(!recs.is_empty());
        for pair in recs.windows(2) {
            assert!(
                pair[1].time_s >= pair[0].time_s,
                "virtual clock must be monotone"
            );
        }
        let starts = recs
            .iter()
            .filter(|e| matches!(e.kind, EventKind::WaveStart { .. }))
            .count();
        let finishes = recs
            .iter()
            .filter(|e| matches!(e.kind, EventKind::WaveFinish { .. }))
            .count();
        assert_eq!(starts, finishes, "quiet: every start commits");
        assert_eq!(
            starts,
            r.schedules.iter().map(|s| s.waves.len()).sum::<usize>()
        );
        assert_eq!(
            recs.iter()
                .filter(|e| matches!(e.kind, EventKind::GradSync { .. }))
                .count(),
            1
        );
        let json = r.timeline.to_json().to_string_pretty();
        let parsed = crate::util::json::Json::parse(&json).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), recs.len());
    }
}
